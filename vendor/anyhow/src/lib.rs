//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so this
//! in-tree shim provides the (small) subset of anyhow's API the crate
//! uses: [`Error`] with a context chain, the [`Context`] extension
//! trait for `Result` and `Option`, the [`Result`] alias, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Swapping back to the real
//! crate is a one-line Cargo.toml change — no source edits.
//!
//! Display follows anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain joined by `": "`, and `{:?}`
//! prints the message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a chain of context messages
/// (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap the error in an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Capture a `std::error::Error` and its source chain.
    pub fn from_std<E: StdError + ?Sized>(error: &E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message (what `{}` prints).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket `From` coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::from_std(&error)
    }
}

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

// Separate impl for results that already carry an `anyhow` Error —
// non-overlapping with the blanket impl above because `Error` does not
// implement `std::error::Error`.
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outermost_only() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
    }

    #[test]
    fn alternate_display_joins_chain() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: no such file");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("value missing").unwrap_err();
        assert_eq!(format!("{e}"), "value missing");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(7);
        let mut called = false;
        let v = ok
            .with_context(|| {
                called = true;
                "must not evaluate on Ok"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called, "with_context must be lazy on Ok");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        let r: Result<()> = Err(anyhow!("inner {}", 1));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 1");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
