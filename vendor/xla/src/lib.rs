//! Typed stub of the `xla` crate surface that `www_cim::runtime::pjrt`
//! compiles against.
//!
//! The real xla/PJRT toolchain is not present in the offline build
//! image, so this stub lets `cargo build --features xla` typecheck the
//! real PJRT engine code; every entry point fails at runtime with a
//! clear message. Deploying against real PJRT means pointing the `xla`
//! path dependency in the root Cargo.toml at the actual crate — no
//! source changes in www_cim.

use std::fmt;

/// Error type mirroring the real crate's (it implements
/// `std::error::Error`, so `?` converts into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (vendor/xla is the offline stub; \
         point the `xla` path dependency at the real crate)"
    )))
}

/// Element types accepted by [`Literal::create_from_shape_and_untyped_data`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
}

/// Stub of the PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors the real signature: generic over the argument kind,
    /// returns per-device, per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::S8, &[2, 2], &[0; 4])
            .unwrap_err();
        assert!(err.to_string().contains("PJRT is unavailable"));
    }
}
