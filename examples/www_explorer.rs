//! WWW explorer: answer What / When / Where for a whole ML workload by
//! sweeping every (primitive × level) system over its layers — the
//! paper's Table V in executable form.
//!
//! ```sh
//! cargo run --release --example www_explorer -- [bert|gptj|resnet50|dlrm]
//! ```

use www_cim::arch::{Architecture, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::{Grid, SystemSpec};
use www_cim::util::stats::geomean;
use www_cim::util::table::Table;
use www_cim::workload::{models, Gemm};

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "bert".into());
    let wl = match which.as_str() {
        "bert" => models::bert_large(),
        "gptj" => models::gpt_j(),
        "resnet50" => models::resnet50(),
        "dlrm" => models::dlrm(),
        other => {
            eprintln!("unknown workload {other}; using bert");
            models::bert_large()
        }
    };
    let gemms: Vec<Gemm> = wl.unique_with_counts().into_iter().map(|(g, _)| g).collect();
    println!("workload: {} ({} unique GEMMs)\n", wl.name, gemms.len());

    let arch = Architecture::default_sm();
    let grid = Grid::new(arch.clone());

    // The full system matrix: baseline + every primitive at RF and SMEM.
    let mut specs = vec![SystemSpec::Baseline];
    for p in CimPrimitive::all() {
        specs.push(SystemSpec::CimAtRf(p.clone()));
        specs.push(SystemSpec::CimAtSmem(p, SmemConfig::ConfigB));
    }

    let jobs = grid.cross(&[(wl.name.clone(), gemms)], &specs);
    let results = grid.run(&jobs);

    let mut table = Table::new(vec![
        "system", "geomean TOPS/W", "geomean GFLOPS", "mean util",
    ]);
    let mut best_energy: Option<(f64, String)> = None;
    let mut best_perf: Option<(f64, String)> = None;
    for spec in &specs {
        let label = spec.label(&arch);
        let rows: Vec<_> = results.iter().filter(|r| r.system == label).collect();
        let t: Vec<f64> = rows.iter().map(|r| r.metrics.tops_per_watt).collect();
        let f: Vec<f64> = rows.iter().map(|r| r.metrics.gflops).collect();
        let u = rows.iter().map(|r| r.metrics.utilization).sum::<f64>() / rows.len() as f64;
        let (gt, gf) = (geomean(&t), geomean(&f));
        if best_energy.as_ref().map_or(true, |(b, _)| gt > *b) {
            best_energy = Some((gt, label.clone()));
        }
        if best_perf.as_ref().map_or(true, |(b, _)| gf > *b) {
            best_perf = Some((gf, label.clone()));
        }
        table.row(vec![
            label,
            format!("{gt:.3}"),
            format!("{gf:.0}"),
            format!("{u:.2}"),
        ]);
    }
    print!("{table}");

    let (et, el) = best_energy.unwrap();
    let (pf, pl) = best_perf.unwrap();
    println!("\nWHAT/WHERE for {}:", wl.name);
    println!("  best energy efficiency: {el} ({et:.3} TOPS/W geomean)");
    println!("  best throughput:        {pl} ({pf:.0} GFLOPS geomean)");
    println!(
        "  WHEN: layers with M=1 (GEMVs) defeat CiM weight reuse — \
         {} of them in this workload.",
        wl.gemms().iter().filter(|g| g.is_gemv()).count()
    );
}
