//! Quickstart: evaluate one GEMM on a CiM-integrated SM and on the
//! tensor-core baseline, and print the What/When/Where story for it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use www_cim::prelude::*;
use www_cim::cost::BaselineModel;
use www_cim::roofline::Roofline;

fn main() {
    // The architecture of paper §V-A: one SM, 4x4 KB RF, 256 KB SMEM.
    let arch = Architecture::default_sm();

    // A BERT-Large projection GEMM (Table VI row 1).
    let gemm = Gemm::new(512, 1024, 1024);
    println!("workload: {gemm}  (algorithmic reuse {:.0} ops/B)\n", gemm.algorithmic_reuse());

    // WHAT: pick a CiM primitive (Table IV).
    let prim = CimPrimitive::digital_6t();
    println!(
        "primitive: {} — {}x{} parallel CiM units, {} ns/pass, {} pJ/MAC",
        prim.name, prim.rp, prim.cp, prim.latency_ns, prim.mac_energy_pj
    );

    // WHERE: integrate it at the register file under iso-area.
    let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
    println!("system:    {} (peak {:.0} GOPS)\n", sys.label(), sys.peak_gops());

    // Map the GEMM with the paper's priority-based algorithm...
    let mapping = PriorityMapper::new(&sys).map(&gemm);
    println!("mapping:   {}\n", mapping.describe());

    // ...and evaluate it with the analytical cost model.
    let cim = CostModel::new(&sys).evaluate(&gemm, &mapping);
    let base = BaselineModel::new(&arch).evaluate(&gemm);

    println!("               {:>12} {:>12}", "CiM@RF", "Tensor-core");
    println!("TOPS/W         {:>12.3} {:>12.3}", cim.tops_per_watt, base.tops_per_watt);
    println!("GFLOPS         {:>12.0} {:>12.0}", cim.gflops, base.gflops);
    println!("utilization    {:>11.1}% {:>11.1}%", 100.0 * cim.utilization, 100.0 * base.utilization);
    println!("fJ/MAC         {:>12.0} {:>12.0}", cim.fj_per_mac(), base.fj_per_mac());
    println!(
        "\nWHEN: CiM wins energy here by {:.2}x (weight reuse in-array); the baseline \
         keeps a {:.2}x throughput edge on this shape.",
        cim.tops_per_watt / base.tops_per_watt,
        base.gflops / cim.gflops
    );

    // Roofline context (Appendix B).
    let ridge = Roofline::of(&sys, MemLevel::Dram);
    println!(
        "roofline: ridge at {:.1} ops/B -> this GEMM is {}.",
        ridge.ridge_point(),
        if ridge.memory_bound(&gemm) { "memory-bound" } else { "compute-bound" }
    );
}
