//! Scripted client for the `repro serve` daemon.
//!
//! With an address argument it talks to a running daemon:
//!
//! ```sh
//! target/release/repro serve --addr 127.0.0.1:7878 --cache=results/cache.bin &
//! cargo run --example serve_client -- 127.0.0.1:7878
//! ```
//!
//! Without one it is self-contained: it starts an in-process daemon on
//! a free port, queries it, and drains it — so the walkthrough always
//! runs. Either way it shows the full protocol round trip: `ping`, a
//! cold `eval`, the same `eval` warm (zero misses), `stats`, and the
//! raw newline-delimited JSON a non-Rust client would speak.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::Result;
use www_cim::scenario::Scenario;
use www_cim::serve::{Client, ServeOptions, Server};
use www_cim::util::json::Json;

fn main() -> Result<()> {
    // 1. Find (or start) a daemon.
    let arg_addr = std::env::args().nth(1);
    let mut local = None;
    let addr = match &arg_addr {
        Some(addr) => addr.clone(),
        None => {
            let server = Server::bind(ServeOptions {
                addr: "127.0.0.1:0".to_string(),
                workers: 2,
                queue_depth: 4,
                quiet: true,
                ..ServeOptions::default()
            })?;
            let addr = server.local_addr()?.to_string();
            println!("(no address given; started an in-process daemon on {addr})");
            local = Some(std::thread::spawn(move || server.run()));
            addr
        }
    };

    // 2. The typed client: ping, then evaluate a scenario twice.
    let mut client = Client::connect(&addr)?;
    let pong = client.ping()?;
    println!("ping -> {}", pong.encode_compact());

    let sc = Scenario::builder("serve-demo")
        .workloads("synthetic:4")
        .prims("baseline,d1")
        .levels("rf,smem-b")
        .seed(11)
        .build()?;

    let cold = client.eval(&sc)?;
    println!(
        "cold eval: {} CSV rows, stats {}",
        cold.csv.lines().count() - 1,
        cold.stats.encode_compact()
    );
    let warm = client.eval(&sc)?;
    println!(
        "warm eval: byte-identical = {}, stats {}",
        warm.csv == cold.csv,
        warm.stats.encode_compact()
    );

    let stats = client.stats()?;
    if let Some(cache) = stats.get("cache") {
        println!("daemon cache: {}", cache.encode_compact());
    }

    // 3. The same thing a non-Rust client would do: write one JSON
    //    line, read JSON lines until "done":true.
    let raw = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(raw.try_clone()?);
    (&raw).write_all(b"{\"op\":\"ping\"}\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    println!("raw protocol: {} -> {}", "{\"op\":\"ping\"}", line.trim());
    drop(reader);
    drop(raw);

    // 4. Drain the in-process daemon (leave a real one running).
    if let Some(daemon) = local {
        client.shutdown()?;
        daemon.join().expect("daemon thread")?;
        println!("in-process daemon drained cleanly");
    }

    // Sanity: warmth must never change the payload.
    assert_eq!(cold.csv, warm.csv);
    assert_eq!(warm.stats.get("misses").and_then(Json::as_u64), Some(0));
    Ok(())
}
