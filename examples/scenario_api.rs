//! The declarative scenario API end to end: build a run description
//! with the fluent builder, serialize it, load it back, and execute it
//! — the same path `repro run`, `repro sweep` and `repro orchestrate`
//! share.
//!
//! ```sh
//! cargo run --release --example scenario_api
//! ```

use www_cim::scenario::{exec, Scenario};

fn main() -> anyhow::Result<()> {
    // A scenario completely describes a run as data: the grid axes (in
    // the CLI axis syntax), the mapper, the seed, the cache policy and
    // the output sinks.
    let scenario = Scenario::builder("api-demo")
        .workloads("bert,dlrm")
        .prims("baseline,d1,a1")
        .levels("rf,smem-b")
        .sms("1,2")
        .mapper("priority")
        .seed(7)
        .shards(2) // default process count for `repro orchestrate`
        .out_dir(std::path::Path::new("results"))
        .build()?;

    // It round-trips through schema-versioned JSON — the form you can
    // check in, diff, and hand to `repro run` / `repro orchestrate`.
    let json = scenario.to_json();
    println!("--- scenario ---\n{json}");
    assert_eq!(Scenario::from_json(&json)?, scenario);

    let path = std::path::Path::new("results/api-demo.scenario.json");
    scenario.write(path)?;
    println!("wrote {} — try `repro run {}`\n", path.display(), path.display());

    // Execution lowers onto the same engine + cache machinery the CLI
    // uses: this writes results/api-demo.csv and results/api-demo.json.
    exec::execute(&scenario, None)?;
    Ok(())
}
