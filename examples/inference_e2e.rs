//! End-to-end driver: run a small real model (a miniature INT8
//! transformer encoder layer compiled from JAX+Pallas) through the full
//! three-layer stack, proving every layer composes:
//!
//!   1. load the AOT artifacts (HLO text from `make artifacts`) via the
//!      PJRT runtime — no python anywhere on this path;
//!   2. execute the composed encoder graph end-to-end and check it
//!      bit-exactly against the rust oracle;
//!   3. replay every GEMM of the layer *through its analytical
//!      mapping* tile-by-tile (the CiM dataflow the paper prices) and
//!      check bit-exactness again;
//!   4. price the same GEMMs with the analytical model on a CiM system
//!      and the baseline, reporting the paper's metrics next to the
//!      measured wall-clock of the real execution.
//!
//! ```sh
//! make artifacts && cargo run --release --example inference_e2e
//! ```

use std::time::Instant;

use anyhow::{bail, Context, Result};

use www_cim::arch::{Architecture, CimSystem, MemLevel};
use www_cim::cim::CimPrimitive;
use www_cim::cost::{BaselineModel, CostModel};
use www_cim::mapping::PriorityMapper;
use www_cim::runtime::matrix::{gemm_ref, requant, MatI8};
use www_cim::runtime::{default_artifacts_dir, Engine, TiledExecutor};
use www_cim::util::rng::Rng;
use www_cim::util::table::Table;
use www_cim::workload::Gemm;

fn main() -> Result<()> {
    let dir = default_artifacts_dir();
    let engine = Engine::load(&dir)
        .with_context(|| format!("loading artifacts from {dir:?} — run `make artifacts`"))?;
    println!(
        "PJRT platform: {} | {} artifacts loaded from {}\n",
        engine.platform(),
        engine.manifest().len(),
        dir.display()
    );

    let mut rng = Rng::from_env(0xE2E);

    // ---- 1+2: composed encoder layer, one-shot execution ----------
    let e = 64usize;
    let x = MatI8::random(16, e, &mut rng);
    let wq = MatI8::random(e, e, &mut rng);
    let wk = MatI8::random(e, e, &mut rng);
    let wv = MatI8::random(e, e, &mut rng);
    let wo = MatI8::random(e, e, &mut rng);
    let w1 = MatI8::random(e, 256, &mut rng);
    let w2 = MatI8::random(256, e, &mut rng);

    let t0 = Instant::now();
    let got = engine
        .execute_i8("encoder_16x64", &[&x, &wq, &wk, &wv, &wo, &w1, &w2])?
        .remove(0);
    let dt_pjrt = t0.elapsed();

    // Rust oracle for the same graph (mirrors python ref.py).
    let shift = 8;
    let fc = |x: &MatI8, w: &MatI8| requant(&gemm_ref(x, w), shift);
    let q = fc(&x, &wq);
    let k = fc(&x, &wk);
    let v = fc(&x, &wv);
    // attention: QK^T -> requant -> (.)V
    let kt = transpose(&k);
    let s = requant(&gemm_ref(&q, &kt), shift);
    let a = requant(&gemm_ref(&s, &v), shift);
    let o = fc(&a, &wo);
    let h = fc(&o, &w1);
    let want = gemm_ref(&h, &w2);

    let diff = got.max_abs_diff(&want);
    println!(
        "encoder_16x64 one-shot: {:?}, |diff| vs rust oracle = {diff}",
        dt_pjrt
    );
    if diff != 0 {
        bail!("composed graph diverges from the oracle");
    }

    // ---- 3: mapped (tiled) replay of each GEMM ---------------------
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let mapper = PriorityMapper::new(&sys);
    let exec = TiledExecutor::new(&engine);

    // The encoder layer's GEMM shapes (Table I) at this scale.
    let layer_gemms = [
        ("Q/K/V/O proj", Gemm::new(16, 64, 64)),
        ("logits QK^T", Gemm::new(16, 16, 64)),
        ("attn (QK^T)V", Gemm::new(16, 64, 16)),
        ("FFN expand", Gemm::new(16, 256, 64)),
        ("FFN contract", Gemm::new(16, 64, 256)),
    ];

    let mut table = Table::new(vec![
        "layer", "GEMM", "kernel calls", "|diff|", "wall µs", "model TOPS/W", "model GFLOPS",
        "baseline TOPS/W",
    ]);
    let cost = CostModel::new(&sys);
    let baseline = BaselineModel::new(&arch);
    let mut all_exact = true;
    for (name, g) in layer_gemms {
        let xg = MatI8::random(g.m as usize, g.k as usize, &mut rng);
        let wg = MatI8::random(g.k as usize, g.n as usize, &mut rng);
        let mapping = mapper.map(&g);
        let t0 = Instant::now();
        let run = exec.run(&mapping, &xg, &wg)?;
        let dt = t0.elapsed();
        all_exact &= run.diff_vs_oracle == 0;
        let m = cost.evaluate(&g, &mapping);
        let b = baseline.evaluate(&g);
        table.row(vec![
            name.to_string(),
            g.to_string(),
            run.kernel_calls.to_string(),
            run.diff_vs_oracle.to_string(),
            format!("{:.0}", dt.as_secs_f64() * 1e6),
            format!("{:.3}", m.tops_per_watt),
            format!("{:.0}", m.gflops),
            format!("{:.3}", b.tops_per_watt),
        ]);
    }
    println!("\nmapped (CiM dataflow) replay on {}:", sys.label());
    print!("{table}");
    if !all_exact {
        bail!("a mapped dataflow diverged from the oracle");
    }

    // ---- 4: throughput of the runtime itself -----------------------
    let reps = 50;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(
            engine.execute_i8("encoder_16x64", &[&x, &wq, &wk, &wv, &wo, &w1, &w2])?,
        );
    }
    let per = t0.elapsed() / reps;
    println!(
        "\nsteady-state: {per:?}/encoder layer ({:.0} layers/s) on the CPU PJRT client",
        1.0 / per.as_secs_f64()
    );
    println!("e2e OK: all layers composed, all numerics bit-exact");
    Ok(())
}

fn transpose(m: &MatI8) -> MatI8 {
    let mut t = MatI8::zeros(m.cols, m.rows);
    for r in 0..m.rows {
        for c in 0..m.cols {
            t.data[c * m.rows + r] = m.get(r, c);
        }
    }
    t
}
