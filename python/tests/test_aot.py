"""AOT pipeline tests: lowering to HLO text, manifest integrity, and the
artifact catalog's signatures."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


class TestLowering:
    def test_hlo_text_structure(self):
        spec = jax.ShapeDtypeStruct((8, 16), jnp.int8)
        wspec = jax.ShapeDtypeStruct((16, 8), jnp.int8)
        lowered = jax.jit(lambda x, w: (model.gemm(x, w),)).lower(spec, wspec)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "s32" in text  # int32 accumulator
        assert "s8" in text   # int8 operands

    def test_pallas_lowers_to_plain_hlo(self):
        # interpret=True must leave no Mosaic custom-calls behind —
        # otherwise the CPU PJRT client cannot run the artifact.
        spec = jax.ShapeDtypeStruct((64, 256), jnp.int8)
        wspec = jax.ShapeDtypeStruct((256, 16), jnp.int8)
        lowered = jax.jit(lambda x, w: (model.gemm(x, w),)).lower(spec, wspec)
        text = aot.to_hlo_text(lowered)
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()

    def test_signature_formatting(self):
        specs = [
            jax.ShapeDtypeStruct((16, 64), jnp.int8),
            jax.ShapeDtypeStruct((64, 32), jnp.int8),
        ]
        assert aot._sig(specs) == "i8:16x64,i8:64x32"


class TestCatalog:
    def test_catalog_names_unique(self):
        names = [name for name, _, _ in aot.catalog()]
        assert len(names) == len(set(names))

    def test_catalog_covers_required_entries(self):
        names = {name for name, _, _ in aot.catalog()}
        assert "gemm_128x64x512" in names  # tile workhorse
        assert "mlp_16x64x256" in names
        assert "encoder_16x64" in names
        assert any(n.startswith("gemm_1x") for n in names)  # GEMV

    def test_lower_entry_produces_signatures(self):
        name, fn, specs = next(
            e for e in aot.catalog() if e[0] == "gemm_16x64x64"
        )
        text, in_sig, out_sig = aot.lower_entry(name, fn, specs)
        assert in_sig == "i8:16x64,i8:64x64"
        assert out_sig == "i32:16x64"
        assert "ENTRY" in text


@pytest.mark.slow
class TestFullPipeline:
    def test_aot_main_writes_artifacts(self, tmp_path):
        out = tmp_path / "artifacts"
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        manifest = (out / "manifest.tsv").read_text().strip().splitlines()
        assert len(manifest) == len(aot.catalog())
        for line in manifest:
            name, fname, in_sig, out_sig = line.split("\t")
            assert (out / fname).exists()
            assert in_sig.startswith("in=")
            assert out_sig.startswith("out=")
