"""Conv-through-CiM-GEMM correctness: the im2col path against a direct
convolution oracle, exact integer comparison."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.cim_conv import conv2d, conv2d_ref, im2col

RNG = np.random.default_rng(0xC04)


def rand_i8(*shape):
    return RNG.integers(-128, 128, size=shape, dtype=np.int8)


class TestIm2col:
    def test_shapes(self):
        x = rand_i8(8, 8, 3)
        cols, (ho, wo) = im2col(x, 3, 3, stride=1, pad=1)
        assert (ho, wo) == (8, 8)
        assert cols.shape == (64, 27)

    def test_stride_two(self):
        x = rand_i8(8, 8, 2)
        cols, (ho, wo) = im2col(x, 2, 2, stride=2)
        assert (ho, wo) == (4, 4)
        assert cols.shape == (16, 8)

    def test_1x1_is_reshape(self):
        x = rand_i8(4, 4, 5)
        cols, _ = im2col(x, 1, 1)
        np.testing.assert_array_equal(np.asarray(cols), x.reshape(16, 5))


class TestConv:
    def test_identity_kernel(self):
        # 1x1 conv with identity weights passes channels through.
        x = rand_i8(6, 6, 3)
        w = np.eye(3, dtype=np.int8).reshape(1, 1, 3, 3)
        out = np.asarray(conv2d(x, w))
        np.testing.assert_array_equal(out, x.astype(np.int32))

    def test_matches_reference_3x3(self):
        x = rand_i8(10, 10, 4)
        w = rand_i8(3, 3, 4, 8)
        np.testing.assert_array_equal(
            np.asarray(conv2d(x, w, stride=1, pad=1)),
            np.asarray(conv2d_ref(x, w, stride=1, pad=1)),
        )

    def test_resnet_stem_shape(self):
        # The 7x7/2 stem of ResNet-50 at reduced resolution: the im2col
        # GEMM is (Ho*Wo, Cout, 147) like Table VI's first row.
        x = rand_i8(28, 28, 3)
        w = rand_i8(7, 7, 3, 8)
        out = np.asarray(conv2d(x, w, stride=2, pad=3))
        assert out.shape == (14, 14, 8)
        np.testing.assert_array_equal(
            out, np.asarray(conv2d_ref(x, w, stride=2, pad=3))
        )

    @settings(max_examples=15, deadline=None)
    @given(
        h=st.integers(4, 12),
        cin=st.integers(1, 6),
        cout=st.integers(1, 8),
        k=st.sampled_from([1, 2, 3]),
        stride=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_sweep(self, h, cin, cout, k, stride, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(h, h, cin), dtype=np.int8)
        w = rng.integers(-128, 128, size=(k, k, cin, cout), dtype=np.int8)
        pad = k // 2
        np.testing.assert_array_equal(
            np.asarray(conv2d(x, w, stride=stride, pad=pad)),
            np.asarray(conv2d_ref(x, w, stride=stride, pad=pad)),
        )
