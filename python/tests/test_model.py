"""Layer-2 correctness: composed model graphs against the pure-jnp
composition oracles, plus shape contracts."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(0xC1A0)


def rand_i8(*shape):
    return RNG.integers(-128, 128, size=shape, dtype=np.int8)


def assert_exact(got, want):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestLayers:
    def test_gemm_layer(self):
        x, w = rand_i8(16, 64), rand_i8(64, 32)
        assert_exact(model.gemm(x, w), ref.gemm_ref(x, w))

    def test_fc_layer_requantizes(self):
        x, w = rand_i8(16, 64), rand_i8(64, 32)
        out = np.asarray(model.fc_layer(x, w))
        assert out.dtype == np.int8
        assert_exact(out, ref.requant_ref(ref.gemm_ref(x, w)))

    def test_mlp_matches_ref(self):
        x, w1, w2 = rand_i8(16, 64), rand_i8(64, 256), rand_i8(256, 64)
        assert_exact(model.mlp(x, w1, w2), ref.mlp_ref(x, w1, w2))

    def test_attention_matches_ref(self):
        q, k, v = rand_i8(16, 64), rand_i8(16, 64), rand_i8(16, 64)
        assert_exact(model.attention(q, k, v), ref.attention_ref(q, k, v))

    def test_attention_shapes(self):
        # QK^T reduces over embed; (QK^T)V reduces over seq (Table I).
        q, k, v = rand_i8(16, 64), rand_i8(16, 64), rand_i8(16, 64)
        out = np.asarray(model.attention(q, k, v))
        assert out.shape == (16, 64)

    def test_encoder_layer_end_to_end(self):
        e = 64
        x = rand_i8(16, e)
        wq, wk, wv, wo = (rand_i8(e, e) for _ in range(4))
        w1, w2 = rand_i8(e, 256), rand_i8(256, e)
        got = np.asarray(model.encoder_layer(x, wq, wk, wv, wo, w1, w2))
        # Reference composition from the oracles only.
        q = ref.requant_ref(ref.gemm_ref(x, wq))
        kk = ref.requant_ref(ref.gemm_ref(x, wk))
        v = ref.requant_ref(ref.gemm_ref(x, wv))
        a = ref.requant_ref(ref.attention_ref(q, kk, v))
        o = ref.requant_ref(ref.gemm_ref(a, wo))
        want = ref.mlp_ref(o, w1, w2)
        assert_exact(got, want)
        assert got.shape == (16, e)
        assert got.dtype == np.int32


class TestRequantSemantics:
    def test_right_shift_is_arithmetic(self):
        acc = np.array([[-256, 256, -1, 511]], dtype=np.int32)
        out = np.asarray(ref.requant_ref(acc, 8))
        assert out.tolist() == [[-1, 1, -1, 1]]

    def test_truncating_cast_wraps(self):
        acc = np.array([[130 << 8, -130 << 8]], dtype=np.int32)
        out = np.asarray(ref.requant_ref(acc, 8))
        # two's-complement wrap (matches rust `as i8`)
        assert out.tolist() == [[-126, 126]]

    @settings(max_examples=25, deadline=None)
    @given(shift=st.integers(0, 16), seed=st.integers(0, 2**31))
    def test_model_and_ref_agree_for_any_shift(self, shift, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(8, 32), dtype=np.int8)
        w1 = rng.integers(-128, 128, size=(32, 48), dtype=np.int8)
        w2 = rng.integers(-128, 128, size=(48, 16), dtype=np.int8)
        assert_exact(
            model.mlp(x, w1, w2, shift=shift), ref.mlp_ref(x, w1, w2, shift)
        )
