"""Layer-1 correctness: the Pallas CiM-schedule kernel against the
pure-jnp oracle. Integer arithmetic — every comparison is exact.

Hypothesis sweeps shapes (including non-block-multiples, GEMV rows, and
degenerate dims) and block configurations, per the repro requirement
that the kernel be property-tested against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cim_gemm import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_N,
    blocks_for_primitive,
    cim_gemm,
)
from compile.kernels.ref import gemm_ref

RNG = np.random.default_rng(0x57575757)


def rand_i8(*shape):
    return RNG.integers(-128, 128, size=shape, dtype=np.int8)


def assert_exact(got, want):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestBasics:
    def test_small_square(self):
        x, w = rand_i8(16, 16), rand_i8(16, 16)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    def test_block_multiple_shape(self):
        x, w = rand_i8(128, 512), rand_i8(512, 32)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    def test_non_dividing_shapes_pad_correctly(self):
        # 147 = the ResNet stem's im2col K; deliberately awkward.
        x, w = rand_i8(49, 147), rand_i8(147, 33)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    def test_gemv_row(self):
        # M = 1: the CiM-hostile shape of §VI-C must still be correct.
        x, w = rand_i8(1, 256), rand_i8(256, 64)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    def test_single_output(self):
        x, w = rand_i8(1, 8), rand_i8(8, 1)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    def test_extreme_values_accumulate_in_int32(self):
        # 127*127*K and -128*127*K must not overflow int32 for our K.
        x = np.full((4, 1024), 127, dtype=np.int8)
        w = np.full((1024, 4), 127, dtype=np.int8)
        out = np.asarray(cim_gemm(x, w))
        assert out.dtype == np.int32
        assert (out == 127 * 127 * 1024).all()
        w_neg = np.full((1024, 4), -128, dtype=np.int8)
        assert (np.asarray(cim_gemm(x, w_neg)) == 127 * -128 * 1024).all()

    def test_zero_inputs(self):
        x, w = np.zeros((8, 8), np.int8), np.zeros((8, 8), np.int8)
        assert (np.asarray(cim_gemm(x, w)) == 0).all()

    def test_reduction_mismatch_rejected(self):
        with pytest.raises(AssertionError):
            cim_gemm(rand_i8(4, 8), rand_i8(9, 4))


class TestPrimitiveBlockConfigs:
    @pytest.mark.parametrize(
        "prim", ["analog-6t", "analog-8t", "digital-6t", "digital-8t"]
    )
    def test_each_table_iv_grid(self, prim):
        blocks = blocks_for_primitive(prim)
        x, w = rand_i8(32, 300), rand_i8(300, 40)
        assert_exact(cim_gemm(x, w, **blocks), gemm_ref(x, w))

    def test_unknown_primitive(self):
        with pytest.raises(KeyError):
            blocks_for_primitive("quantum-3t")

    def test_default_blocks_are_digital6t(self):
        b = blocks_for_primitive("digital-6t")
        assert b["block_k"] == DEFAULT_BLOCK_K
        assert b["block_n"] == DEFAULT_BLOCK_N


class TestHypothesisSweep:
    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 96),
        n=st.integers(1, 96),
        k=st.integers(1, 160),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_on_random_shapes(self, m, n, k, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(m, k), dtype=np.int8)
        w = rng.integers(-128, 128, size=(k, n), dtype=np.int8)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))

    @settings(max_examples=15, deadline=None)
    @given(
        bm=st.sampled_from([1, 8, 64]),
        bk=st.sampled_from([16, 64, 256]),
        bn=st.sampled_from([8, 16, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_across_block_configs(self, bm, bk, bn, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(72, 130), dtype=np.int8)
        w = rng.integers(-128, 128, size=(130, 36), dtype=np.int8)
        got = cim_gemm(x, w, block_m=bm, block_k=bk, block_n=bn)
        assert_exact(got, gemm_ref(x, w))

    @settings(max_examples=20, deadline=None)
    @given(k=st.integers(1, 2048), seed=st.integers(0, 2**31))
    def test_reduction_depth_sweep(self, k, seed):
        # The in-situ-reduction axis (K) is the paper's critical
        # dimension (Fig 10c); sweep it hard at fixed M, N.
        rng = np.random.default_rng(seed)
        x = rng.integers(-128, 128, size=(8, k), dtype=np.int8)
        w = rng.integers(-128, 128, size=(k, 8), dtype=np.int8)
        assert_exact(cim_gemm(x, w), gemm_ref(x, w))
