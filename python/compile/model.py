"""Layer-2 JAX model: the INT8 GEMM compute graphs of ML inference
(Table I), built on the Layer-1 Pallas kernel.

Everything here is build-time only: `aot.py` lowers these functions to
HLO text once; the rust runtime executes the artifacts. Nothing in this
package runs on the request path.
"""

from compile.kernels.cim_gemm import cim_gemm
from compile.kernels.ref import requant_ref


def gemm(x, w, **blocks):
    """A single GEMM layer through the CiM-schedule kernel."""
    return cim_gemm(x, w, **blocks)


def fc_layer(x, w, shift: int = 8, **blocks):
    """Fully-connected layer: GEMM + INT8 requantization (Table I row 2)."""
    return requant_ref(cim_gemm(x, w, **blocks), shift)


def mlp(x, w1, w2, shift: int = 8, **blocks):
    """Two-layer MLP (DLRM-style / transformer FFN): the K-then-N chain
    whose reduction behaviour Fig 10(c) studies."""
    h = fc_layer(x, w1, shift, **blocks)
    return cim_gemm(h, w2, **blocks)


def attention(q, k, v, shift: int = 8, **blocks):
    """Fused attention-score computation (Table I rows 4-5):
    ``QK^T`` (logit GEMM), requantize, then ``(QK^T)V`` (attention GEMM).
    """
    logits = cim_gemm(q, k.T, **blocks)
    s = requant_ref(logits, shift)
    return cim_gemm(s, v, **blocks)


def encoder_layer(x, wq, wk, wv, wo, w1, w2, shift: int = 8, **blocks):
    """A miniature transformer encoder layer (BERT-style) in pure INT8:
    Q/K/V projections, fused attention, output projection, and the
    two-GEMM FFN — every GEMM of Table I exercised in one graph."""
    q = fc_layer(x, wq, shift, **blocks)
    k = fc_layer(x, wk, shift, **blocks)
    v = fc_layer(x, wv, shift, **blocks)
    a = requant_ref(attention(q, k, v, shift, **blocks), shift)
    o = fc_layer(a, wo, shift, **blocks)
    return mlp(o, w1, w2, shift, **blocks)
