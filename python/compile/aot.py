"""AOT compilation: lower the Layer-2 graphs (with the Layer-1 Pallas
kernel inside) to HLO **text** artifacts for the rust runtime.

HLO text — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the rust `xla` crate) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]

Produces one ``<name>.hlo.txt`` per catalog entry plus ``manifest.tsv``
describing each artifact's signature:

    name \t file \t in=i8:16x64,i8:64x256 \t out=i32:16x64
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """Convert a jitted+lowered function to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.int8):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(specs):
    names = {jnp.int8.dtype: "i8", jnp.int32.dtype: "i32"}
    return ",".join(
        f"{names[s.dtype]}:{'x'.join(str(d) for d in s.shape)}" for s in specs
    )


# ---------------------------------------------------------------------------
# Artifact catalog.
#
# The plain `gemm_*` entries are the tiled-execution workhorses: the rust
# runtime replays an analytical mapping tile-by-tile by zero-padding each
# weight-residency tile up to one of these shapes (zero padding is exact
# for integer GEMM). `mlp_*` / `encoder_*` are composed Layer-2 graphs
# for the end-to-end driver.
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (64, 64, 64),       # quickstart
    (16, 64, 64),
    (64, 32, 256),      # one Digital-6T residency (2 primitives)
    (128, 32, 512),
    (128, 64, 512),     # tile workhorse: every smaller tile pads to this
    (1, 64, 256),       # GEMV row (DLRM/GPT-J decode shape family)
    (16, 256, 64),
]


def catalog():
    """(name, fn, [arg specs]) for every artifact."""
    out = []
    for m, n, k in GEMM_SHAPES:
        name = f"gemm_{m}x{n}x{k}"

        def fn(x, w):
            return (model.gemm(x, w),)

        out.append((name, fn, [_spec((m, k)), _spec((k, n))]))

    def mlp_fn(x, w1, w2):
        return (model.mlp(x, w1, w2),)

    out.append(
        (
            "mlp_16x64x256",
            mlp_fn,
            [_spec((16, 64)), _spec((64, 256)), _spec((256, 64))],
        )
    )

    def attn_fn(q, k, v):
        return (model.attention(q, k, v),)

    out.append(
        (
            "attention_16x64",
            attn_fn,
            [_spec((16, 64)), _spec((16, 64)), _spec((16, 64))],
        )
    )

    def enc_fn(x, wq, wk, wv, wo, w1, w2):
        return (model.encoder_layer(x, wq, wk, wv, wo, w1, w2),)

    e = 64
    out.append(
        (
            "encoder_16x64",
            enc_fn,
            [
                _spec((16, e)),
                _spec((e, e)),
                _spec((e, e)),
                _spec((e, e)),
                _spec((e, e)),
                _spec((e, 256)),
                _spec((256, e)),
            ],
        )
    )
    return out


def lower_entry(name, fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *specs)
    return text, _sig(specs), _sig(list(out_specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, specs in catalog():
        text, in_sig, out_sig = lower_entry(name, fn, specs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name}\t{fname}\tin={in_sig}\tout={out_sig}")
        print(f"  {name}: {len(text)} chars -> {fname}")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
