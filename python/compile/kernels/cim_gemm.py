"""Layer-1 Pallas kernel: the CiM primitive's compute schedule as a
weight-stationary tiled INT8 GEMM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CiM
primitive holds a ``(Rp·Rh) x (Cp·Ch)`` weight tile stationary in the
SRAM array while input rows stream through it. On the TPU-shaped
substrate this becomes a VMEM-resident weight block with the HBM<->VMEM
schedule expressed through ``BlockSpec``:

* ``block_k`` plays the role of the primitive's weight *rows* (the
  reduction dimension mapped to wordlines),
* ``block_n`` plays the weight *columns* (bitlines),
* the grid iterates ``(n, k, m)`` with **M innermost** — the paper's
  compute loop order ``M < K < N`` (§IV-B): the weight block's index map
  ``(k, n)`` is constant across the inner m sweep, so the block stays
  resident exactly like the stationary CiM tile, and partial sums
  accumulate across the k axis like the primitive's in-situ reduction.

The kernel is lowered with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute. Correctness
is pinned to the pure-jnp oracle in ``ref.py`` (pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default blocks mirror the Digital-6T primitive of Table IV:
# 256 weight rows (Rp) x 16 columns (Cp), with 64 input rows streamed
# per residency.
DEFAULT_BLOCK_M = 64
DEFAULT_BLOCK_K = 256
DEFAULT_BLOCK_N = 16


def _kernel(x_ref, w_ref, o_ref):
    """One grid step: multiply an (bm, bk) input slab into the resident
    (bk, bn) weight block and accumulate into the (bm, bn) output block.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # INT8 x INT8 -> INT32, exactly as the paper's 8b-8b MAC with a
    # full-precision accumulator.
    acc = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += acc


def _pad_to(a, rows, cols):
    """Zero-pad a 2-D array up to (rows, cols); zeros are exact identity
    padding for integer GEMM."""
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_k", "block_n", "interpret"),
)
def cim_gemm(
    x,
    w,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Weight-stationary INT8 GEMM: ``x (M,K) @ w (K,N) -> int32 (M,N)``.

    Shapes need not divide the block sizes — inputs are zero-padded to
    the block grid and the result sliced back, mirroring the partial
    CiM-tile utilization of the analytical model.
    """
    assert x.ndim == 2 and w.ndim == 2, "cim_gemm operates on matrices"
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"reduction mismatch: {k} vs {k2}"

    bm, bk, bn = (min(block_m, m), min(block_k, k), min(block_n, n))
    mp = pl.cdiv(m, bm) * bm
    kp = pl.cdiv(k, bk) * bk
    np_ = pl.cdiv(n, bn) * bn
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)

    grid = (np_ // bn, kp // bk, mp // bm)  # (n, k, m): M innermost

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ni, ki, mi: (mi, ki)),
            # Weight block index ignores the inner m axis: stationary.
            pl.BlockSpec((bk, bn), lambda ni, ki, mi: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda ni, ki, mi: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def blocks_for_primitive(name: str):
    """Block configuration mirroring a Table IV primitive's stationary
    grid (rows = Rp*Rh, cols = Cp*Ch)."""
    grids = {
        "analog-6t": (64, 64),
        "analog-8t": (64, 64),
        "digital-6t": (256, 16),
        "digital-8t": (10, 128),
    }
    key = name.lower().replace("_", "-")
    if key not in grids:
        raise KeyError(f"unknown primitive {name!r}; options: {sorted(grids)}")
    rows, cols = grids[key]
    return {"block_m": DEFAULT_BLOCK_M, "block_k": rows, "block_n": cols}
