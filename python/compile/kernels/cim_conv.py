"""Conv2D through the CiM GEMM kernel via im2col (paper Table I row 1,
§III-A): Conv(Ci->Co, KhxKw, stride s) on HxW becomes
GEMM(M=Ho*Wo, N=Co, K=Kh*Kw*Ci) — the transformation the ResNet-50
dataset rows were derived with.
"""

import jax.numpy as jnp

from compile.kernels.cim_gemm import cim_gemm


def im2col(x, kh: int, kw: int, stride: int = 1, pad: int = 0):
    """Unfold an (H, W, C) int8 image into the (Ho*Wo, Kh*Kw*C) patch
    matrix. Zero padding matches integer-GEMM identity semantics."""
    h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    rows = []
    for i in range(kh):
        for j in range(kw):
            patch = x[i : i + ho * stride : stride, j : j + wo * stride : stride, :]
            rows.append(patch.reshape(ho * wo, c))
    # (Ho*Wo, Kh*Kw*C), laid out kernel-position-major to match the
    # weight reshape below.
    return jnp.concatenate(rows, axis=1), (ho, wo)


def conv2d(x, w, stride: int = 1, pad: int = 0, **blocks):
    """INT8 Conv2D -> INT32, through the weight-stationary CiM kernel.

    x: (H, W, Cin) int8; w: (Kh, Kw, Cin, Cout) int8.
    Returns (Ho, Wo, Cout) int32.
    """
    kh, kw, cin, cout = w.shape
    cols, (ho, wo) = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(kh * kw * cin, cout)
    out = cim_gemm(cols, wmat, **blocks)
    return out.reshape(ho, wo, cout)


def conv2d_ref(x, w, stride: int = 1, pad: int = 0):
    """Oracle: direct convolution in int32 (no GEMM, no Pallas)."""
    kh, kw, cin, cout = w.shape
    if pad:
        x = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    h, wd, _ = x.shape
    ho = (h - kh) // stride + 1
    wo = (wd - kw) // stride + 1
    xi = x.astype(jnp.int32)
    wi = w.astype(jnp.int32)
    out = jnp.zeros((ho, wo, cout), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = xi[i : i + ho * stride : stride, j : j + wo * stride : stride, :]
            out = out + jnp.einsum("hwc,co->hwo", patch, wi[i, j])
    return out
