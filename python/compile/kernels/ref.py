"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

These are the single source of truth for numerics: the Pallas kernel
(`cim_gemm`) and every composed model graph must match them exactly
(integer arithmetic — no tolerance).
"""

import jax.numpy as jnp


def gemm_ref(x, w):
    """INT8 GEMM with INT32 accumulation: the 8b-8b MAC of the paper."""
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))


def requant_ref(acc, shift: int = 8):
    """Deterministic INT32 -> INT8 requantization: arithmetic right
    shift then two's-complement truncation. Chosen over float scaling so
    the rust runtime can cross-check results bit-exactly."""
    return jnp.right_shift(acc, shift).astype(jnp.int8)


def mlp_ref(x, w1, w2, shift: int = 8):
    """Two-layer INT8 MLP: gemm -> requant -> gemm (the DLRM/FFN shape
    of Table I)."""
    h = requant_ref(gemm_ref(x, w1), shift)
    return gemm_ref(h, w2)


def attention_scores_ref(q, k, shift: int = 8):
    """Fused attention-score path of Table I: logits = Q @ K^T followed
    by requantization (integer stand-in for softmax scaling)."""
    return requant_ref(gemm_ref(q, k.T), shift)


def attention_ref(q, k, v, shift: int = 8):
    """QK^T -> requant -> (.)V : the logit and attention GEMMs."""
    s = attention_scores_ref(q, k, shift)
    return gemm_ref(s, v)
