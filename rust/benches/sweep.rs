//! Sweep-engine benchmark: a ≥500-point design-space grid evaluated
//! (a) cold on one thread, (b) cold on the full worker pool,
//! (c) warm (fully memoized), and (d) warm from a persisted cache file
//! (load included — the `--cache` cross-process path), plus (e) the
//! mapping-aware cache's headline win: an exhaustive-mapper point (the
//! `optimality` axis every `repro experiment all` run pays for) cold vs
//! warm-from-disk, and (f) a batched grid (GPT-J decode at batch 1 and
//! 16) showing batched points memoize like any others. The acceptance
//! numbers for the DSE subsystem: parallelism and the memo cache must
//! both be measurable wins over the cold single-threaded run, and the
//! warm exhaustive point must be orders of magnitude cheaper than the
//! cold search it memoizes.

use std::sync::Arc;

use www_cim::arch::Architecture;
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::SystemSpec;
use www_cim::mapping::Objective;
use www_cim::sweep::{persist, spec, EvalCache, MapperChoice, SweepEngine, SweepJob, SweepSpec};
use www_cim::util::bench::{black_box, Bencher};
use www_cim::util::pool;
use www_cim::workload::{synthetic, Gemm};

fn grid_spec() -> SweepSpec {
    // 50 synthetic GEMMs x (1 baseline + 4 primitives x 3 integration
    // points) = 650 grid points.
    let mut systems = vec![SystemSpec::Baseline];
    for p in CimPrimitive::all() {
        systems.push(SystemSpec::CimAtRf(p.clone()));
        systems.push(SystemSpec::CimAtSmem(p.clone(), www_cim::arch::SmemConfig::ConfigA));
        systems.push(SystemSpec::CimAtSmem(p, www_cim::arch::SmemConfig::ConfigB));
    }
    SweepSpec::new("bench-grid")
        .workload("synthetic", synthetic::dataset(7, 50))
        .systems(systems)
}

fn main() {
    let arch = Architecture::default_sm();
    let spec = grid_spec();
    let jobs = spec.jobs();
    let n = jobs.len() as u64;
    let threads = pool::default_threads();
    println!(
        "sweep bench: {} grid points, pool = {} threads",
        n, threads
    );

    let mut b = Bencher::new();

    // (a) cold, single-threaded: fresh engine (and cache) per iteration.
    let cold_1 = b
        .bench_with_items(&format!("sweep/{n}pts/cold/threads=1"), n, &mut || {
            let engine = SweepEngine::new(arch.clone()).threads(1);
            black_box(engine.run(&jobs));
        })
        .mean();

    // (b) cold, parallel: fresh engine per iteration, full pool.
    let cold_n = b
        .bench_with_items(
            &format!("sweep/{n}pts/cold/threads={threads}"),
            n,
            &mut || {
                let engine = SweepEngine::new(arch.clone());
                black_box(engine.run(&jobs));
            },
        )
        .mean();

    // (c) warm: one engine primed once, every point a cache hit.
    let warm_engine = SweepEngine::new(arch.clone());
    warm_engine.run(&jobs);
    let warm = b
        .bench_with_items(&format!("sweep/{n}pts/warm/threads={threads}"), n, &mut || {
            black_box(warm_engine.run(&jobs));
        })
        .mean();

    // (d) warm from disk: persist the primed cache once, then load it
    // into a fresh engine per iteration — what a second process pays
    // with `--cache` (file parse + preload + all-hit sweep).
    let cache_file = std::env::temp_dir().join("www_cim_sweep_bench_cache.bin");
    persist::save(warm_engine.cache(), &cache_file).expect("persist bench cache");
    let disk = b
        .bench_with_items(
            &format!("sweep/{n}pts/warm-from-disk/threads={threads}"),
            n,
            &mut || {
                let cache = Arc::new(EvalCache::new());
                persist::load_into(&cache, &cache_file).expect("load bench cache");
                let engine = SweepEngine::with_cache(arch.clone(), cache);
                black_box(engine.run(&jobs));
            },
        )
        .mean();
    let _ = std::fs::remove_file(&cache_file);

    // (e) exhaustive-mapper point, cold vs warm-from-disk: the cache
    // now memoizes (mapping, metrics), so a warm `repro experiment all`
    // skips the whole exhaustive search — the single most expensive
    // evaluation any experiment performs.
    let ex_job = SweepJob {
        workload: "optimality".to_string(),
        gemm: Gemm::new(256, 512, 512),
        spec: SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
        sms: 1,
        mapper: MapperChoice::Exhaustive {
            objective: Objective::Energy,
        },
    };
    let cold_ex = b
        .bench("sweep/exhaustive-point/cold", &mut || {
            let engine = SweepEngine::new(arch.clone()).threads(1);
            black_box(engine.evaluate(&ex_job));
        })
        .mean();
    let ex_cache_file = std::env::temp_dir().join("www_cim_sweep_bench_excache.bin");
    let primed = SweepEngine::new(arch.clone()).threads(1);
    primed.evaluate(&ex_job);
    persist::save(primed.cache(), &ex_cache_file).expect("persist exhaustive cache");
    let warm_ex = b
        .bench("sweep/exhaustive-point/warm-from-disk", &mut || {
            let cache = Arc::new(EvalCache::new());
            persist::load_into(&cache, &ex_cache_file).expect("load exhaustive cache");
            let engine = SweepEngine::with_cache(arch.clone(), cache).threads(1);
            black_box(engine.evaluate(&ex_job));
        })
        .mean();
    let _ = std::fs::remove_file(&ex_cache_file);
    println!(
        "exhaustive point: cold = {:?}, warm-from-disk = {:?} ({:.0}x)",
        cold_ex,
        warm_ex,
        cold_ex.as_secs_f64() / warm_ex.as_secs_f64().max(1e-12)
    );
    if warm_ex >= cold_ex {
        println!("WARNING: warm exhaustive point was not faster than the cold search");
    }

    // (f) the batch axis: GPT-J decode at batch 1 and 16 — weight GEMMs
    // fold the batch along M, attention GEMMs replicate, and the
    // resulting points are ordinary reshaped GEMMs that memoize like
    // any others (the warm pass is all hits).
    let batched = SweepSpec::new("bench-batched")
        .workloads(spec::parse_workloads_batched("gptj", 7, &[1, 16]).expect("batched parse"))
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
        ])
        .batches(vec![1, 16]);
    let bjobs = batched.jobs();
    let bn = bjobs.len() as u64;
    let cold_b = b
        .bench_with_items(&format!("sweep/batched/{bn}pts/cold"), bn, &mut || {
            let engine = SweepEngine::new(arch.clone());
            black_box(engine.run(&bjobs));
        })
        .mean();
    let warm_b_engine = SweepEngine::new(arch.clone());
    warm_b_engine.run(&bjobs);
    let warm_b = b
        .bench_with_items(&format!("sweep/batched/{bn}pts/warm"), bn, &mut || {
            black_box(warm_b_engine.run(&bjobs));
        })
        .mean();
    println!("batched grid (gptj @ b1,b16): cold = {cold_b:?}, warm = {warm_b:?}");
    if warm_b >= cold_b {
        println!("WARNING: warm batched run was not faster than the cold batched run");
    }

    println!(
        "\nspeedup vs cold single-thread: cold x{} = {:.2}x, warm = {:.2}x, \
         warm-from-disk = {:.2}x",
        threads,
        cold_1.as_secs_f64() / cold_n.as_secs_f64().max(1e-12),
        cold_1.as_secs_f64() / warm.as_secs_f64().max(1e-12),
        cold_1.as_secs_f64() / disk.as_secs_f64().max(1e-12),
    );
    if warm >= cold_1 {
        println!("WARNING: warm memoized run was not faster than the cold single-threaded run");
    }
    if disk >= cold_1 {
        println!("WARNING: warm-from-disk run was not faster than the cold single-threaded run");
    }
    b.finish("sweep");
}
