//! Mapper micro-benchmarks: the priority mapper (the paper's runtime
//! claim in Table II is that it is cheap) and the heuristic-search
//! comparator across representative GEMM shapes.

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::mapping::{HeuristicMapper, PriorityMapper};
use www_cim::util::bench::{black_box, Bencher};
use www_cim::util::rng::Rng;
use www_cim::workload::Gemm;

fn main() {
    let arch = Architecture::default_sm();
    let rf = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let smem = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);

    let shapes = [
        ("bert", Gemm::new(512, 1024, 1024)),
        ("resnet-stem", Gemm::new(12544, 64, 147)),
        ("gemv", Gemm::new(1, 4096, 4096)),
        ("huge", Gemm::new(8192, 8192, 8192)),
    ];

    let mut b = Bencher::new();
    for (name, g) in &shapes {
        b.bench_with_items(&format!("priority_map/rf/{name}"), 1000, &mut || {
            let mapper = PriorityMapper::new(&rf);
            for _ in 0..1000 {
                black_box(mapper.map(g));
            }
        });
    }
    for (name, g) in &shapes {
        b.bench_with_items(&format!("priority_map/smem_b/{name}"), 1000, &mut || {
            let mapper = PriorityMapper::new(&smem);
            for _ in 0..1000 {
                black_box(mapper.map(g));
            }
        });
    }

    // Heuristic search with the paper's stopping rule, small budget.
    let mut h = HeuristicMapper::new(&rf);
    h.valid_budget = 100;
    for (name, g) in &shapes[..2] {
        b.bench(&format!("heuristic_map/rf/{name}/100-valid"), || {
            let mut rng = Rng::new(7);
            black_box(h.map(g, &mut rng));
        });
    }
    b.finish("mapper");
}
