//! Ablation benches: quantify the design choices DESIGN.md calls out —
//! the balance threshold, the exact-local loop-order optimization, and
//! the quality/speed trade of the heuristic comparator's budget.

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::cost::CostModel;
use www_cim::mapping::{HeuristicMapper, PriorityMapper};
use www_cim::util::bench::{black_box, Bencher};
use www_cim::util::rng::Rng;
use www_cim::util::stats::geomean;
use www_cim::workload::synthetic;

fn main() {
    let arch = Architecture::default_sm();
    let dataset = synthetic::dataset(7, 64);
    let mut b = Bencher::new();

    // Threshold ablation: quality (geomean TOPS/W) printed alongside
    // the mapping-time measurement.
    let smem = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
    for threshold in [1u64, 4, 64] {
        let cost = CostModel::new(&smem);
        let tops: Vec<f64> = dataset
            .iter()
            .map(|g| {
                let m = PriorityMapper::with_threshold(&smem, threshold).map(g);
                cost.evaluate(g, &m).tops_per_watt
            })
            .collect();
        println!(
            "quality: threshold={threshold:<3} geomean TOPS/W = {:.4}",
            geomean(&tops)
        );
        b.bench_with_items(&format!("map/threshold={threshold}"), dataset.len() as u64, &mut || {
            for g in &dataset {
                black_box(PriorityMapper::with_threshold(&smem, threshold).map(g));
            }
        });
    }

    // Heuristic budget sweep: search cost vs achieved quality.
    let rf = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    for budget in [20u64, 100, 500] {
        let cost = CostModel::new(&rf);
        let mut h = HeuristicMapper::new(&rf);
        h.valid_budget = budget;
        let tops: Vec<f64> = dataset
            .iter()
            .map(|g| {
                let (m, _) = h.map(g, &mut Rng::new(11));
                cost.evaluate(g, &m).tops_per_watt
            })
            .collect();
        println!(
            "quality: heuristic budget={budget:<4} geomean TOPS/W = {:.4}",
            geomean(&tops)
        );
        b.bench(&format!("heuristic/budget={budget}/64-gemms"), || {
            let mut rng = Rng::new(11);
            for g in &dataset {
                black_box(h.map(g, &mut rng));
            }
        });
    }
    b.finish("ablations");
}
