//! Cost-engine micro-benchmarks: the access-counting + energy/latency
//! evaluation that sits inside every grid cell of every experiment.
//! This is the L3 hot path (each fig9 run is ~4000 evaluations).

use www_cim::arch::{Architecture, CimSystem, MemLevel};
use www_cim::cim::CimPrimitive;
use www_cim::cost::{BaselineModel, CostModel};
use www_cim::coordinator::jobs::{Grid, SystemSpec};
use www_cim::mapping::PriorityMapper;
use www_cim::util::bench::{black_box, Bencher};
use www_cim::workload::{synthetic, Gemm};

fn main() {
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let g = Gemm::new(512, 1024, 1024);
    let mapping = PriorityMapper::new(&sys).map(&g);

    let mut b = Bencher::new();
    b.bench_with_items("cost/evaluate_mapping", 10_000, &mut || {
        let cost = CostModel::new(&sys);
        for _ in 0..10_000 {
            black_box(cost.evaluate(&g, &mapping));
        }
    });

    b.bench_with_items("cost/baseline_evaluate", 10_000, &mut || {
        let bm = BaselineModel::new(&arch);
        for _ in 0..10_000 {
            black_box(bm.evaluate(&g));
        }
    });

    b.bench_with_items("cost/map+evaluate", 10_000, &mut || {
        let cost = CostModel::new(&sys);
        let mapper = PriorityMapper::new(&sys);
        for _ in 0..10_000 {
            let m = mapper.map(&g);
            black_box(cost.evaluate(&g, &m));
        }
    });

    // Whole-grid throughput: the coordinator fan-out over a synthetic
    // slice, serial vs parallel (the §Perf scaling number). A fresh
    // memo cache per iteration keeps this a cold-evaluation measurement.
    let dataset = synthetic::dataset(7, 256);
    let workloads = vec![("synthetic".to_string(), dataset)];
    let specs = vec![SystemSpec::CimAtRf(CimPrimitive::digital_6t())];
    for threads in [1usize, 4, www_cim::util::pool::default_threads()] {
        let jobs = Grid::new(arch.clone()).cross(&workloads, &specs);
        let n = jobs.len() as u64;
        b.bench_with_items(&format!("grid/256-gemms/threads={threads}"), n, &mut || {
            let mut grid = Grid::new(arch.clone());
            grid.threads = threads;
            black_box(grid.run(&jobs));
        });
    }
    // Warm (memoized) replay of the same grid.
    let grid = Grid::new(arch.clone());
    let jobs = grid.cross(&workloads, &specs);
    grid.run(&jobs); // prime the cache
    b.bench_with_items("grid/256-gemms/warm-cache", jobs.len() as u64, &mut || {
        black_box(grid.run(&jobs));
    });
    b.finish("cost_engine");
}
