//! End-to-end benchmark: wall-time of regenerating every paper
//! table/figure (quick configuration). One measurement per experiment
//! id — the "does the harness run fast enough to iterate" metric, and
//! the per-figure timing reported in EXPERIMENTS.md §Perf.
//!
//! Custom harness (criterion is unavailable offline): see
//! `www_cim::util::bench`.

use www_cim::experiments::{self, Ctx};
use www_cim::util::bench::Bencher;

fn main() {
    let mut ctx = Ctx::quick();
    ctx.out_dir = std::env::temp_dir().join("www_cim_bench_results");
    ctx.threads = 1; // deterministic single-thread timing

    // Regeneration output would swamp the report; mute stdout noise by
    // spot-checking once first.
    let mut b = Bencher::new();
    for id in experiments::ids() {
        b.bench(&format!("experiment/{id}"), || {
            experiments::run(id, &ctx).expect("experiment runs");
        });
    }
    b.finish("experiments");
}
