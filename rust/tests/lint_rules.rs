//! Integration tests for `repro lint`: per-rule fixtures (firing,
//! clean, allowlisted), the allow-marker hygiene diagnostics, the R3
//! version-guard lifecycle over a temp tree, and the self-test that
//! the repo's own sources come out clean.

use std::fs;
use std::path::{Path, PathBuf};

use www_cim::lint::{self, check_source, guards, LintOptions, RULE_IDS};

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

fn rule_ids(diags: &[lint::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule).collect()
}

/// Fresh temp tree rooted at a unique dir; caller writes files under it.
fn temp_root(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("www_cim_lint_it_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(&root).expect("create temp root");
    root
}

fn write(root: &Path, rel: &str, content: &str) {
    let path = root.join(rel);
    fs::create_dir_all(path.parent().expect("rel has a parent")).expect("mkdir");
    fs::write(path, content).expect("write fixture");
}

fn no_guards() -> LintOptions {
    LintOptions { fix_guards: false, check_guards: false }
}

// ---------------------------------------------------------------------------
// R1 — no direct cost-model construction in experiments/
// ---------------------------------------------------------------------------

const R1_FIRING: &str = "pub fn run() -> f64 {\n    let m = CostModel::new(&sys());\n    m.evaluate()\n}\n";

#[test]
fn r1_fires_on_cost_model_in_experiments() {
    let diags = check_source("rust/src/experiments/fig_x.rs", R1_FIRING);
    assert_eq!(rule_ids(&diags), ["R1"], "{diags:?}");
    assert_eq!(diags[0].line, 2);
}

#[test]
fn r1_fires_on_baseline_model_too() {
    let src = "pub fn run() { let _b = BaselineModel::new(); }\n";
    let diags = check_source("rust/src/experiments/fig_y.rs", src);
    assert_eq!(rule_ids(&diags), ["R1"]);
}

#[test]
fn r1_ignores_same_code_outside_experiments() {
    assert!(check_source("rust/src/sweep/engine.rs", R1_FIRING).is_empty());
}

#[test]
fn r1_applies_inside_test_code_as_well() {
    // Experiments must route through the engine even in their tests —
    // R1 sets skip_tests = false.
    let src = "#[test]\nfn t() {\n    let _m = CostModel::new(&sys());\n}\n";
    let diags = check_source("rust/src/experiments/fig_z.rs", src);
    assert_eq!(rule_ids(&diags), ["R1"]);
}

#[test]
fn r1_allow_marker_suppresses_with_reason() {
    let src = "pub fn run() -> f64 {\n    // lint: allow(R1): fixture exercises the raw model\n    let m = CostModel::new(&sys());\n    m.evaluate()\n}\n";
    assert!(check_source("rust/src/experiments/fig_x.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R2 — no lossy float formatting in fingerprint/persist code
// ---------------------------------------------------------------------------

#[test]
fn r2_fires_on_precision_format_in_persist() {
    let src = "pub fn enc(x: f64) -> String {\n    format!(\"{:.6}\", x)\n}\n";
    let diags = check_source("rust/src/sweep/persist.rs", src);
    assert_eq!(rule_ids(&diags), ["R2"]);
    assert_eq!(diags[0].line, 2);
}

#[test]
fn r2_fires_on_scientific_notation() {
    let src = "pub fn enc(x: f64) -> String {\n    format!(\"{:e}\", x)\n}\n";
    assert_eq!(rule_ids(&check_source("rust/src/util/hash.rs", src)), ["R2"]);
}

#[test]
fn r2_allows_exact_formatting_and_out_of_scope_files() {
    let exact = "pub fn enc(bits: u64) -> String {\n    format!(\"{bits:016x}\")\n}\n";
    assert!(check_source("rust/src/sweep/persist.rs", exact).is_empty());
    // Report tables may round for display.
    let lossy = "pub fn cell(x: f64) -> String {\n    format!(\"{x:.2}\")\n}\n";
    assert!(check_source("rust/src/util/table.rs", lossy).is_empty());
}

// ---------------------------------------------------------------------------
// R4 — no unwrap()/expect()/panic! on the library path
// ---------------------------------------------------------------------------

#[test]
fn r4_fires_on_unwrap_expect_and_panic() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n    let a = v.first().unwrap();\n    let b: u32 = \"7\".parse().expect(\"digit\");\n    if *a == b { panic!(\"collision\") }\n    *a + b\n}\n";
    let diags = check_source("rust/src/cost/mod.rs", src);
    assert_eq!(rule_ids(&diags), ["R4", "R4", "R4"], "{diags:?}");
    assert_eq!(diags.iter().map(|d| d.line).collect::<Vec<_>>(), [2, 3, 4]);
}

#[test]
fn r4_skips_tests_benches_and_main() {
    let in_test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        assert_eq!(\"7\".parse::<u32>().unwrap(), 7);\n    }\n}\n";
    assert!(check_source("rust/src/cost/mod.rs", in_test).is_empty());
    let in_main = "fn main() {\n    run().unwrap();\n}\n";
    assert!(check_source("rust/src/main.rs", in_main).is_empty());
}

#[test]
fn r4_allow_marker_covers_marker_line_and_next_code_line() {
    let own_line = "pub fn f(v: &[u32]) -> u32 {\n    // lint: allow(R4): fixture-provable non-empty\n    *v.first().unwrap()\n}\n";
    assert!(check_source("rust/src/cost/mod.rs", own_line).is_empty());
    let trailing = "pub fn f(v: &[u32]) -> u32 {\n    *v.first().unwrap() // lint: allow(R4): fixture-provable non-empty\n}\n";
    assert!(check_source("rust/src/cost/mod.rs", trailing).is_empty());
}

#[test]
fn r4_method_named_like_expect_does_not_fire_at_declaration() {
    // Only call sites shaped like `.expect(` are flagged; declaring an
    // inherent method named `expect` is not itself a violation (its
    // call sites would be — json.rs renamed to expect_char for that).
    let src = "impl P {\n    fn expect(&mut self, c: char) -> bool {\n        self.peek() == Some(c)\n    }\n}\n";
    assert!(check_source("rust/src/util/json.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R5 — no wildcard `_ =>` arms in decode code
// ---------------------------------------------------------------------------

const R5_FIRING: &str = "pub fn dec(t: u8) -> u8 {\n    match t {\n        1 => 10,\n        _ => 0,\n    }\n}\n";

#[test]
fn r5_fires_on_wildcard_arm_in_decode_code() {
    let diags = check_source("rust/src/sweep/persist.rs", R5_FIRING);
    assert_eq!(rule_ids(&diags), ["R5"]);
    assert_eq!(diags[0].line, 4);
}

#[test]
fn r5_ignores_wildcards_outside_decode_scope_and_bound_patterns() {
    assert!(check_source("rust/src/cost/mod.rs", R5_FIRING).is_empty());
    // `Some(_) | None` spells the cases out — no bare `_ =>`.
    let explicit = "pub fn dec(t: Option<u8>) -> u8 {\n    match t {\n        Some(v) => v,\n        None => 0,\n    }\n}\n";
    assert!(check_source("rust/src/sweep/persist.rs", explicit).is_empty());
}

// ---------------------------------------------------------------------------
// R6 — no HashMap/HashSet in deterministic-output code
// ---------------------------------------------------------------------------

#[test]
fn r6_fires_on_hashmap_in_output_sink() {
    let src = "use std::collections::HashMap;\n\npub fn rows() -> HashMap<String, u64> {\n    HashMap::new()\n}\n";
    let diags = check_source("rust/src/sweep/output.rs", src);
    assert_eq!(rule_ids(&diags), ["R6", "R6", "R6"]);
}

#[test]
fn r6_allows_btreemap_and_out_of_scope_hashmaps() {
    let btree = "use std::collections::BTreeMap;\n\npub fn rows() -> BTreeMap<String, u64> {\n    BTreeMap::new()\n}\n";
    assert!(check_source("rust/src/sweep/output.rs", btree).is_empty());
    let hash = "use std::collections::HashMap;\npub type Memo = HashMap<String, u64>;\n";
    assert!(check_source("rust/src/mapping/priority.rs", hash).is_empty());
}

// ---------------------------------------------------------------------------
// R7 — no un-sorted read_dir walks in deterministic-output code
// ---------------------------------------------------------------------------

#[test]
fn r7_fires_on_read_dir_in_output_sink() {
    let src = "pub fn shard_paths(dir: &std::path::Path) -> std::io::Result<Vec<std::path::PathBuf>> {\n    let mut out = Vec::new();\n    for entry in std::fs::read_dir(dir)? {\n        out.push(entry?.path());\n    }\n    Ok(out)\n}\n";
    let diags = check_source("rust/src/sweep/output.rs", src);
    assert_eq!(rule_ids(&diags), ["R7"], "{diags:?}");
    assert_eq!(diags[0].line, 3);
}

#[test]
fn r7_ignores_read_dir_outside_sink_scope() {
    let src = "pub fn count(dir: &std::path::Path) -> usize {\n    std::fs::read_dir(dir).map(|it| it.count()).unwrap_or(0)\n}\n";
    assert!(check_source("rust/src/mapping/priority.rs", src).is_empty());
}

#[test]
fn r7_allow_marker_suppresses_with_reason() {
    let src = "pub fn sorted_paths(dir: &std::path::Path) -> std::io::Result<Vec<std::path::PathBuf>> {\n    let mut out = Vec::new();\n    // lint: allow(R7): entries are collected and sorted before use\n    for entry in std::fs::read_dir(dir)? {\n        out.push(entry?.path());\n    }\n    out.sort();\n    Ok(out)\n}\n";
    assert!(check_source("rust/src/sweep/output.rs", src).is_empty());
}

// ---------------------------------------------------------------------------
// R8 — persistent-artifact writes go through util::fsx::write_atomic
// ---------------------------------------------------------------------------

const R8_FIRING: &str = "pub fn save(path: &std::path::Path, text: &str) -> std::io::Result<()> {\n    std::fs::write(path, text)\n}\n";

#[test]
fn r8_fires_on_bare_fs_write_in_persist_and_serve() {
    let diags = check_source("rust/src/sweep/persist.rs", R8_FIRING);
    assert_eq!(rule_ids(&diags), ["R8"], "{diags:?}");
    assert_eq!(diags[0].line, 2);
    let diags = check_source("rust/src/scenario/orchestrate.rs", R8_FIRING);
    assert_eq!(rule_ids(&diags), ["R8"]);
    // The whole serve tree is in scope by prefix.
    let diags = check_source("rust/src/serve/listener.rs", R8_FIRING);
    assert_eq!(rule_ids(&diags), ["R8"]);
}

#[test]
fn r8_allows_write_atomic_and_out_of_scope_writes() {
    let clean = "pub fn save(path: &std::path::Path, text: &str) -> anyhow::Result<()> {\n    crate::util::fsx::write_atomic(path, text)\n}\n";
    assert!(check_source("rust/src/sweep/persist.rs", clean).is_empty());
    // fsx.rs itself hosts the one sanctioned fs::write; cost/ never
    // persists artifacts — both out of scope.
    assert!(check_source("rust/src/util/fsx.rs", R8_FIRING).is_empty());
    assert!(check_source("rust/src/cost/mod.rs", R8_FIRING).is_empty());
    // io::Write method calls are not `fs::write` paths.
    let method = "pub fn put(w: &mut dyn std::io::Write, b: &[u8]) -> std::io::Result<usize> {\n    w.write(b)\n}\n";
    assert!(check_source("rust/src/sweep/persist.rs", method).is_empty());
}

#[test]
fn r8_skips_tests_and_honors_allow_markers() {
    let in_test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        std::fs::write(std::path::Path::new(\"/tmp/x\"), \"fixture\").unwrap();\n    }\n}\n";
    assert!(check_source("rust/src/sweep/persist.rs", in_test).is_empty());
    let allowed = "pub fn scratch(path: &std::path::Path) -> std::io::Result<()> {\n    // lint: allow(R8): probe file is unlinked before anyone can read it\n    std::fs::write(path, \"probe\")\n}\n";
    assert!(check_source("rust/src/serve/listener.rs", allowed).is_empty());
}

// ---------------------------------------------------------------------------
// Allow-marker hygiene — bad markers are themselves diagnostics
// ---------------------------------------------------------------------------

#[test]
fn allow_marker_without_reason_is_rejected() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n    // lint: allow(R4):\n    *v.first().unwrap()\n}\n";
    let diags = check_source("rust/src/cost/mod.rs", src);
    // The malformed marker reports, and without a valid marker the
    // unwrap underneath still fires.
    assert_eq!(rule_ids(&diags), ["lint", "R4"], "{diags:?}");
}

#[test]
fn allow_marker_with_unknown_rule_is_rejected() {
    let src = "// lint: allow(R9): no such rule\npub fn f() {}\n";
    let diags = check_source("rust/src/cost/mod.rs", src);
    assert_eq!(rule_ids(&diags), ["lint"]);
}

#[test]
fn unused_allow_marker_is_reported() {
    let src = "pub fn f(v: &[u32]) -> u32 {\n    // lint: allow(R4): nothing here needs it\n    v.len() as u32\n}\n";
    let diags = check_source("rust/src/cost/mod.rs", src);
    assert_eq!(rule_ids(&diags), ["lint"]);
    assert!(diags[0].message.contains("never matched"), "{:?}", diags[0].message);
}

// ---------------------------------------------------------------------------
// R3 — version-guard lifecycle over a temp tree
// ---------------------------------------------------------------------------

const GUARDED_V1: &str = "pub const MAPPER_VERSION: u32 = 1;\n\npub fn map(x: u64) -> u64 {\n    x * 7\n}\n";
const GUARDED_V1_EDITED: &str = "pub const MAPPER_VERSION: u32 = 1;\n\npub fn map(x: u64) -> u64 {\n    x * 8\n}\n";
const GUARDED_V2_EDITED: &str = "pub const MAPPER_VERSION: u32 = 2;\n\npub fn map(x: u64) -> u64 {\n    x * 8\n}\n";

const BOOTSTRAP_MANIFEST: &str = "[[guard]]\nname = \"mapper\"\nversion_const = \"MAPPER_VERSION\"\nversion_file = \"rust/src/mapping/mod.rs\"\npaths = [\"rust/src/mapping\"]\nversion = 1\nhash = \"\"\n";

fn run_guarded(root: &Path, fix: bool) -> lint::LintReport {
    lint::run(root, &LintOptions { fix_guards: fix, check_guards: true })
        .expect("lint runs on temp tree")
}

#[test]
fn guard_lifecycle_bootstrap_drift_bump_fix() {
    let root = temp_root("guard_lifecycle");
    write(&root, "rust/src/mapping/mod.rs", GUARDED_V1);
    write(&root, lint::GUARDS_MANIFEST, BOOTSTRAP_MANIFEST);

    // 1. Bootstrap: empty hash reports until --fix-guards records it.
    let report = run_guarded(&root, false);
    assert_eq!(rule_ids(&report.diagnostics), ["R3"], "{report:?}");
    assert!(report.diagnostics[0].message.contains("no recorded content hash"));
    let report = run_guarded(&root, true);
    assert!(report.clean(), "{}", report.render());
    assert!(report.guards_rewritten);
    let report = run_guarded(&root, false);
    assert!(report.clean(), "recorded manifest must be stable: {}", report.render());

    // 2. Drift: content changes, constant does not → fails, and
    //    --fix-guards refuses to launder it.
    write(&root, "rust/src/mapping/mod.rs", GUARDED_V1_EDITED);
    let report = run_guarded(&root, false);
    assert_eq!(rule_ids(&report.diagnostics), ["R3"]);
    assert!(report.diagnostics[0].message.contains("MAPPER_VERSION is still 1"), "{}", report.render());
    let report = run_guarded(&root, true);
    assert_eq!(rule_ids(&report.diagnostics), ["R3"], "--fix-guards must not adopt drift");
    assert!(!report.guards_rewritten);

    // 3. Bump the constant: now the fix records the new (version, hash).
    write(&root, "rust/src/mapping/mod.rs", GUARDED_V2_EDITED);
    let report = run_guarded(&root, false);
    assert_eq!(rule_ids(&report.diagnostics), ["R3"], "bump still needs recording");
    let report = run_guarded(&root, true);
    assert!(report.clean(), "{}", report.render());
    assert!(report.guards_rewritten);

    // 4. Steady state again.
    let report = run_guarded(&root, false);
    assert!(report.clean(), "{}", report.render());
    let manifest = fs::read_to_string(root.join(lint::GUARDS_MANIFEST)).expect("manifest");
    assert!(manifest.contains("version = 2"), "{manifest}");

    let _ = fs::remove_dir_all(&root);
}

#[test]
fn missing_manifest_is_an_r3_diagnostic() {
    let root = temp_root("guard_missing_manifest");
    write(&root, "rust/src/cost/mod.rs", "pub fn f() {}\n");
    let report = lint::run(&root, &LintOptions::default()).expect("lint runs");
    assert_eq!(rule_ids(&report.diagnostics), ["R3"]);
    assert!(report.diagnostics[0].message.contains("missing"));
    let _ = fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// The repo itself
// ---------------------------------------------------------------------------

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn repo_tree_is_lint_clean() {
    let report = lint::run(repo_root(), &LintOptions::default()).expect("lint runs on the repo");
    assert!(report.clean(), "repo must be lint-clean:\n{}", report.render());
    assert!(!report.guards_rewritten);
}

#[test]
fn repo_manifest_guards_the_six_versioned_modules() {
    let text = fs::read_to_string(repo_root().join(lint::GUARDS_MANIFEST)).expect("manifest");
    let parsed = guards::parse(&text).expect("manifest parses");
    let names: Vec<&str> = parsed.iter().map(|g| g.name.as_str()).collect();
    assert_eq!(
        names,
        ["mapper", "cost-model", "cache-format", "scenario-format", "workload", "serve-protocol"]
    );
    for g in &parsed {
        assert!(!g.hash.is_empty(), "guard {:?} left at bootstrap sentinel", g.name);
        assert_eq!(g.hash.len(), 16, "guard {:?} hash is not fnv1a-64 hex", g.name);
    }
}

#[test]
fn rule_ids_cover_r1_through_r8() {
    assert_eq!(RULE_IDS, ["R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"]);
}
