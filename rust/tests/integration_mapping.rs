//! Integration: mapping algorithms against full systems and workloads.

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::mapping::loopnest::Dim;
use www_cim::mapping::{HeuristicMapper, PriorityMapper};
use www_cim::util::rng::Rng;
use www_cim::workload::{models, synthetic, Gemm};

fn all_systems() -> Vec<CimSystem> {
    let arch = Architecture::default_sm();
    let mut out = Vec::new();
    for p in CimPrimitive::all() {
        out.push(CimSystem::at_level(&arch, p.clone(), MemLevel::RegisterFile));
        out.push(CimSystem::at_smem(&arch, p.clone(), SmemConfig::ConfigA));
        out.push(CimSystem::at_smem(&arch, p, SmemConfig::ConfigB));
    }
    out
}

#[test]
fn priority_mapper_valid_on_every_real_layer_and_system() {
    for sys in all_systems() {
        let mapper = PriorityMapper::new(&sys);
        for wl in models::real_dataset() {
            for g in wl.gemms() {
                let m = mapper.map(g);
                assert!(
                    m.nest.validate().is_ok(),
                    "{} on {}: {:?}",
                    g,
                    sys.label(),
                    m.nest.validate()
                );
                assert!(m.spatial.validate(&sys).is_ok(), "{} on {}", g, sys.label());
            }
        }
    }
}

#[test]
fn priority_mapper_valid_on_synthetic_sweep() {
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let mapper = PriorityMapper::new(&sys);
    for g in synthetic::dataset(123, 400) {
        let m = mapper.map(&g);
        assert!(m.nest.validate().is_ok(), "{g}");
    }
}

#[test]
fn weight_capacity_never_exceeded() {
    // The stationary weight tile must fit the integrated arrays.
    for sys in all_systems() {
        let mapper = PriorityMapper::new(&sys);
        for g in synthetic::dataset(9, 100) {
            let m = mapper.map(&g);
            let tile = m.k0() * m.n0();
            assert!(
                tile <= sys.weight_capacity_elems(),
                "{} on {}: tile {} > capacity {}",
                g,
                sys.label(),
                tile,
                sys.weight_capacity_elems()
            );
        }
    }
}

#[test]
fn staging_capacity_respected_at_rf() {
    let arch = Architecture::default_sm();
    let smem = arch.capacity(MemLevel::Smem);
    for p in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, p, MemLevel::RegisterFile);
        let mapper = PriorityMapper::new(&sys);
        for g in synthetic::dataset(11, 100) {
            let m = mapper.map(&g);
            let m1 = m.nest.blocks[2].dim_factor(Dim::M);
            let k_staged: u64 = m.nest.blocks[1].dim_factor(Dim::K) * m.k0();
            let n_staged: u64 = m.nest.blocks[1].dim_factor(Dim::N) * m.n0();
            assert!(
                m1 * (k_staged + n_staged) <= smem,
                "{} on {}: staged {} bytes > SMEM",
                g,
                sys.label(),
                m1 * (k_staged + n_staged)
            );
        }
    }
}

#[test]
fn heuristic_search_stops_and_reports_stats() {
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let mut h = HeuristicMapper::new(&sys);
    h.valid_budget = 50;
    let (m, stats) = h.map(&Gemm::new(512, 512, 512), &mut Rng::new(3));
    assert!(m.nest.validate().is_ok());
    assert_eq!(stats.valid, 50);
    assert_eq!(stats.sampled, stats.valid + stats.invalid);
}

#[test]
fn gemv_mappings_use_single_input_row() {
    for sys in all_systems() {
        let m = PriorityMapper::new(&sys).map(&Gemm::new(1, 4096, 4096));
        assert_eq!(m.nest.total_factor(Dim::M), 1, "{}", sys.label());
    }
}

#[test]
fn bigger_pool_never_maps_fewer_primitives() {
    // SMEM configB (16x pool) should engage at least as many primitives
    // as configA for large GEMMs.
    let arch = Architecture::default_sm();
    let g = Gemm::new(2048, 4096, 4096);
    for p in CimPrimitive::all() {
        let a = CimSystem::at_smem(&arch, p.clone(), SmemConfig::ConfigA);
        let b = CimSystem::at_smem(&arch, p, SmemConfig::ConfigB);
        let ma = PriorityMapper::new(&a).map(&g);
        let mb = PriorityMapper::new(&b).map(&g);
        assert!(mb.spatial.prims_used() >= ma.spatial.prims_used());
    }
}

// ---------------------------------------------------------------------
// Canonical serialization (ISSUE 3): randomized round-trip and
// fingerprint-perturbation properties.
// ---------------------------------------------------------------------

/// Random mappings (priority and heuristic, every system) survive
/// serialize → parse → re-serialize bit-exactly — the property the
/// mapping-aware persisted cache rests on.
#[test]
fn prop_canonical_round_trip_bit_exact() {
    use www_cim::mapping::Mapping;
    use www_cim::util::check::{check, Config};

    let systems = all_systems();
    check(Config::default().cases(64), "canonical round trip", |rng| {
        let dim = |rng: &mut Rng| -> u64 {
            match rng.gen_range(0, 3) {
                0 => 1 << rng.gen_range(0, 13),
                1 => rng.gen_range(1, 4097),
                _ => rng.gen_range(1, 64),
            }
        };
        let g = Gemm::new(dim(rng), dim(rng), dim(rng));
        let sys = &systems[rng.index(systems.len())];
        let m = if rng.gen_range(0, 2) == 0 {
            PriorityMapper::new(sys).map(&g)
        } else {
            let mut h = HeuristicMapper::new(sys);
            h.valid_budget = 20;
            h.map(&g, &mut Rng::new(rng.gen_range(0, 1 << 30))).0
        };
        let text = m.canonical();
        let back = Mapping::from_canonical(&text)
            .map_err(|e| format!("{g} on {}: {e:#}", sys.label()))?;
        if back != m {
            return Err(format!("{g} on {}: round trip changed the mapping", sys.label()));
        }
        if back.canonical() != text {
            return Err(format!("{g} on {}: re-serialization drifted", sys.label()));
        }
        if back.occupancy.to_bits() != m.occupancy.to_bits() {
            return Err(format!("{g}: occupancy not bit-exact"));
        }
        Ok(())
    });
}

/// Perturbing any loop-nest, spatial, GEMM or occupancy field of a
/// randomized mapping changes its fingerprint.
#[test]
fn prop_fingerprint_tracks_perturbations() {
    use www_cim::mapping::loopnest::Loop;
    use www_cim::util::check::{check, Config};

    let systems = all_systems();
    check(Config::default().cases(64), "fingerprint perturbation", |rng| {
        let g = Gemm::new(
            rng.gen_range(2, 4097),
            rng.gen_range(2, 4097),
            rng.gen_range(2, 4097),
        );
        let sys = &systems[rng.index(systems.len())];
        let m = PriorityMapper::new(sys).map(&g);
        let base = m.fingerprint();
        if base != m.fingerprint() {
            return Err("fingerprint is not deterministic".to_string());
        }

        let mut p = m.clone();
        match rng.gen_range(0, 5) {
            0 => p.gemm = Gemm::new(p.gemm.m + 1, p.gemm.n, p.gemm.k),
            1 => p.spatial.ku += 1,
            2 => p.occupancy = f64::from_bits(p.occupancy.to_bits() + 1),
            3 => {
                // Perturb a loop factor somewhere in the nest (append a
                // loop if the chosen block is empty).
                let b = rng.index(p.nest.blocks.len());
                let block = &mut p.nest.blocks[b];
                if block.loops.is_empty() {
                    block.loops.push(Loop::new(Dim::K, 2));
                } else {
                    let l = rng.index(block.loops.len());
                    block.loops[l].factor += 1;
                }
            }
            _ => {
                // Change a block's memory level.
                let b = rng.index(p.nest.blocks.len());
                let block = &mut p.nest.blocks[b];
                block.mem = if block.mem == MemLevel::PeBuffer {
                    MemLevel::Dram
                } else {
                    MemLevel::PeBuffer
                };
            }
        }
        if p.fingerprint() == base {
            return Err(format!("{g}: perturbation left the fingerprint unchanged"));
        }
        Ok(())
    });
}
