//! Integration: mapping algorithms against full systems and workloads.

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::mapping::loopnest::Dim;
use www_cim::mapping::{HeuristicMapper, PriorityMapper};
use www_cim::util::rng::Rng;
use www_cim::workload::{models, synthetic, Gemm};

fn all_systems() -> Vec<CimSystem> {
    let arch = Architecture::default_sm();
    let mut out = Vec::new();
    for p in CimPrimitive::all() {
        out.push(CimSystem::at_level(&arch, p.clone(), MemLevel::RegisterFile));
        out.push(CimSystem::at_smem(&arch, p.clone(), SmemConfig::ConfigA));
        out.push(CimSystem::at_smem(&arch, p, SmemConfig::ConfigB));
    }
    out
}

#[test]
fn priority_mapper_valid_on_every_real_layer_and_system() {
    for sys in all_systems() {
        let mapper = PriorityMapper::new(&sys);
        for wl in models::real_dataset() {
            for g in wl.gemms() {
                let m = mapper.map(g);
                assert!(
                    m.nest.validate().is_ok(),
                    "{} on {}: {:?}",
                    g,
                    sys.label(),
                    m.nest.validate()
                );
                assert!(m.spatial.validate(&sys).is_ok(), "{} on {}", g, sys.label());
            }
        }
    }
}

#[test]
fn priority_mapper_valid_on_synthetic_sweep() {
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let mapper = PriorityMapper::new(&sys);
    for g in synthetic::dataset(123, 400) {
        let m = mapper.map(&g);
        assert!(m.nest.validate().is_ok(), "{g}");
    }
}

#[test]
fn weight_capacity_never_exceeded() {
    // The stationary weight tile must fit the integrated arrays.
    for sys in all_systems() {
        let mapper = PriorityMapper::new(&sys);
        for g in synthetic::dataset(9, 100) {
            let m = mapper.map(&g);
            let tile = m.k0() * m.n0();
            assert!(
                tile <= sys.weight_capacity_elems(),
                "{} on {}: tile {} > capacity {}",
                g,
                sys.label(),
                tile,
                sys.weight_capacity_elems()
            );
        }
    }
}

#[test]
fn staging_capacity_respected_at_rf() {
    let arch = Architecture::default_sm();
    let smem = arch.capacity(MemLevel::Smem);
    for p in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, p, MemLevel::RegisterFile);
        let mapper = PriorityMapper::new(&sys);
        for g in synthetic::dataset(11, 100) {
            let m = mapper.map(&g);
            let m1 = m.nest.blocks[2].dim_factor(Dim::M);
            let k_staged: u64 = m.nest.blocks[1].dim_factor(Dim::K) * m.k0();
            let n_staged: u64 = m.nest.blocks[1].dim_factor(Dim::N) * m.n0();
            assert!(
                m1 * (k_staged + n_staged) <= smem,
                "{} on {}: staged {} bytes > SMEM",
                g,
                sys.label(),
                m1 * (k_staged + n_staged)
            );
        }
    }
}

#[test]
fn heuristic_search_stops_and_reports_stats() {
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let mut h = HeuristicMapper::new(&sys);
    h.valid_budget = 50;
    let (m, stats) = h.map(&Gemm::new(512, 512, 512), &mut Rng::new(3));
    assert!(m.nest.validate().is_ok());
    assert_eq!(stats.valid, 50);
    assert_eq!(stats.sampled, stats.valid + stats.invalid);
}

#[test]
fn gemv_mappings_use_single_input_row() {
    for sys in all_systems() {
        let m = PriorityMapper::new(&sys).map(&Gemm::new(1, 4096, 4096));
        assert_eq!(m.nest.total_factor(Dim::M), 1, "{}", sys.label());
    }
}

#[test]
fn bigger_pool_never_maps_fewer_primitives() {
    // SMEM configB (16x pool) should engage at least as many primitives
    // as configA for large GEMMs.
    let arch = Architecture::default_sm();
    let g = Gemm::new(2048, 4096, 4096);
    for p in CimPrimitive::all() {
        let a = CimSystem::at_smem(&arch, p.clone(), SmemConfig::ConfigA);
        let b = CimSystem::at_smem(&arch, p, SmemConfig::ConfigB);
        let ma = PriorityMapper::new(&a).map(&g);
        let mb = PriorityMapper::new(&b).map(&g);
        assert!(mb.spatial.prims_used() >= ma.spatial.prims_used());
    }
}
