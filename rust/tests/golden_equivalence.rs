//! Golden equivalence harness for the engine-routing refactor (ISSUE 3).
//!
//! `fig7`, `table2`, `optimality`, `ablation-duplication`,
//! `ablation-interconnect` (and the two mapper ablations) used to
//! hand-roll serial direct evaluation; they now evaluate through the
//! shared `SweepEngine`. The refactor's contract is **byte-identical
//! CSV output**, and this suite proves it: each test regenerates the
//! pre-refactor CSV with a *reference implementation* — the literal
//! direct-evaluation code the experiment used before the refactor,
//! preserved verbatim below — and asserts the engine-routed experiment
//! emits exactly those bytes.
//!
//! The goldens are captured as code rather than committed CSV files on
//! purpose: several columns are `{:.4}`-formatted results of `ln`/`exp`
//! (geomeans), so a committed file would pin one platform's libm and
//! flake on another, while the in-process reference pins precisely the
//! property the refactor must preserve — same inputs, same bytes — on
//! every platform the tests run on.
//!
//! `table2` reports wall-clock seconds, which no harness can make
//! byte-stable; for it the structural columns (header + the runs axis)
//! are pinned instead.

use www_cim::arch::{CimSystem, Interconnect, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::cost::CostModel;
use www_cim::experiments::{self, Ctx};
use www_cim::mapping::loopnest::Dim;
use www_cim::mapping::{ExhaustiveMapper, HeuristicMapper, Objective, PriorityMapper};
use www_cim::util::csv::{self, Csv};
use www_cim::util::rng::Rng;
use www_cim::util::stats::geomean;
use www_cim::workload::{models, synthetic, Gemm};

fn quick_ctx(tag: &str) -> Ctx {
    let mut ctx = Ctx::quick();
    ctx.out_dir = std::env::temp_dir().join(format!("www_cim_golden_eq_{tag}"));
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
    ctx
}

/// Run one experiment id and return the CSV mirror's bytes.
fn run_and_read(ctx: &Ctx, id: &str) -> String {
    experiments::run(id, ctx).unwrap_or_else(|e| panic!("{id} failed: {e:#}"));
    let path = ctx.out_dir.join(format!("{id}.csv"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{id}: missing csv mirror: {e}"))
}

/// The pre-refactor fig7 evaluation suite (quick mode), verbatim.
fn fig7_suite(ctx: &Ctx) -> Vec<(String, Vec<Gemm>)> {
    assert!(ctx.quick, "goldens are captured in quick mode");
    let mut out: Vec<(String, Vec<Gemm>)> = models::real_dataset()
        .into_iter()
        .map(|w| {
            let gemms = w.unique_with_counts().into_iter().map(|(g, _)| g).collect();
            (w.name, gemms)
        })
        .collect();
    out.push(("Synthetic".to_string(), synthetic::dataset(ctx.seed, 12)));
    out
}

#[test]
fn fig7_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("fig7");
    let got = run_and_read(&ctx, "fig7");

    // Pre-refactor reference: per GEMM, priority vs seeded heuristic
    // search, both scored with the direct cost model.
    let sys = CimSystem::at_level(&ctx.arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let cost = CostModel::new(&sys);
    let mut want = Csv::new(vec![
        "workload", "m", "n", "k", "d_topsw", "d_gflops", "d_util",
    ]);
    for (name, gemms) in fig7_suite(&ctx) {
        for g in &gemms {
            let ours = cost.evaluate(g, &PriorityMapper::new(&sys).map(g));
            let mut h = HeuristicMapper::new(&sys);
            h.valid_budget = ctx.heuristic_budget();
            let (hm, _) = h.map(g, &mut Rng::new(ctx.seed ^ g.m ^ g.n ^ g.k));
            let base = cost.evaluate(g, &hm);
            want.row(vec![
                name.clone(),
                g.m.to_string(),
                g.n.to_string(),
                g.k.to_string(),
                format!("{:.4}", ours.tops_per_watt / base.tops_per_watt),
                format!("{:.4}", ours.gflops / base.gflops),
                format!("{:.4}", ours.utilization / base.utilization.max(1e-12)),
            ])
            .unwrap();
        }
    }
    assert_eq!(got, want.encode(), "fig7.csv drifted from the direct evaluation");
}

#[test]
fn table2_engine_axis_keeps_the_golden_structure() {
    // Timings cannot be byte-stable; pin the schema and the runs axis.
    let ctx = quick_ctx("table2");
    let got = run_and_read(&ctx, "table2");
    let rows = csv::parse(&got);
    assert_eq!(rows[0], vec!["runs", "ours_s", "heuristic_s"]);
    let runs: Vec<&str> = rows[1..].iter().map(|r| r[0].as_str()).collect();
    assert_eq!(runs, vec!["2", "5"], "quick-mode runs axis drifted");
    for r in &rows[1..] {
        for cell in &r[1..] {
            let secs: f64 = cell.parse().unwrap_or_else(|e| {
                panic!("table2 timing {cell:?} is not a number: {e}")
            });
            assert!(secs >= 0.0);
        }
    }
}

#[test]
fn optimality_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("optimality");
    let got = run_and_read(&ctx, "optimality");

    // Pre-refactor reference: exhaustive optimum vs priority, direct.
    let sys = CimSystem::at_level(&ctx.arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let cost = CostModel::new(&sys);
    let shapes = [Gemm::new(64, 128, 256), Gemm::new(256, 512, 512)];
    let mut want = Csv::new(vec![
        "m", "n", "k", "candidates", "opt_pj", "ours_pj", "gap", "opt_cycles", "ours_cycles",
    ]);
    for g in &shapes {
        let exact = ExhaustiveMapper::new(&sys, Objective::Energy).map(g);
        let ours = cost.evaluate(g, &PriorityMapper::new(&sys).map(g));
        let gap = ours.energy_pj / exact.metrics.energy_pj;
        want.row(vec![
            g.m.to_string(),
            g.n.to_string(),
            g.k.to_string(),
            exact.candidates.to_string(),
            format!("{:.1}", exact.metrics.energy_pj),
            format!("{:.1}", ours.energy_pj),
            format!("{gap:.4}"),
            exact.metrics.total_cycles.to_string(),
            ours.total_cycles.to_string(),
        ])
        .unwrap();
    }
    assert_eq!(
        got,
        want.encode(),
        "optimality.csv drifted from the direct evaluation"
    );
}

#[test]
fn duplication_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("dup");
    let got = run_and_read(&ctx, "ablation-duplication");

    let sys = CimSystem::at_smem(&ctx.arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
    let cost = CostModel::new(&sys);
    let shapes = [
        Gemm::new(8192, 16, 256),
        Gemm::new(4096, 32, 256),
        Gemm::new(12544, 64, 147),
        Gemm::new(2048, 64, 512),
        Gemm::new(512, 1024, 1024),
    ];
    let mut want = Csv::new(vec![
        "m", "n", "k", "dup", "gflops_off", "gflops_on", "topsw_off", "topsw_on",
    ]);
    for g in shapes {
        let off = cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g));
        let dup_mapping = PriorityMapper::new(&sys).with_weight_duplication().map(&g);
        let on = cost.evaluate(&g, &dup_mapping);
        want.row(vec![
            g.m.to_string(),
            g.n.to_string(),
            g.k.to_string(),
            dup_mapping.spatial.m_prims.to_string(),
            format!("{:.1}", off.gflops),
            format!("{:.1}", on.gflops),
            format!("{:.4}", off.tops_per_watt),
            format!("{:.4}", on.tops_per_watt),
        ])
        .unwrap();
    }
    assert_eq!(
        got,
        want.encode(),
        "ablation-duplication.csv drifted from the direct evaluation"
    );
}

#[test]
fn interconnect_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("noc");
    let got = run_and_read(&ctx, "ablation-interconnect");

    let dataset = synthetic::dataset(ctx.seed, ctx.synthetic_size().min(200));
    let mut want = Csv::new(vec![
        "system", "hop_pj", "topsw_base", "topsw_noc", "overhead_pct",
    ]);
    for (label, sys) in [
        (
            "D-1 @ RF",
            CimSystem::at_level(&ctx.arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile),
        ),
        (
            "D-1 @ SMEM/B",
            CimSystem::at_smem(&ctx.arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB),
        ),
    ] {
        for hop in [0.03, 0.06, 0.12] {
            let noc = Interconnect { hop_pj: hop };
            let rows: Vec<(f64, f64)> = dataset
                .iter()
                .map(|g| {
                    let m = PriorityMapper::new(&sys).map(g);
                    let base = CostModel::new(&sys).evaluate(g, &m);
                    let with = base.energy_pj + noc.energy_pj(&m);
                    (base.ops as f64 / base.energy_pj, base.ops as f64 / with)
                })
                .collect();
            let base: Vec<f64> = rows.iter().map(|r| r.0).collect();
            let with: Vec<f64> = rows.iter().map(|r| r.1).collect();
            let (gb, gw) = (geomean(&base), geomean(&with));
            want.row(vec![
                label.to_string(),
                format!("{hop}"),
                format!("{gb:.4}"),
                format!("{gw:.4}"),
                format!("{:.2}", 100.0 * (gb / gw - 1.0)),
            ])
            .unwrap();
        }
    }
    assert_eq!(
        got,
        want.encode(),
        "ablation-interconnect.csv drifted from the direct evaluation"
    );
}

#[test]
fn threshold_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("threshold");
    let got = run_and_read(&ctx, "ablation-threshold");

    let dataset = synthetic::dataset(ctx.seed, ctx.synthetic_size().min(300));
    let sys = CimSystem::at_smem(&ctx.arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
    let mut want = Csv::new(vec!["threshold", "geo_topsw", "geo_gflops", "mean_util"]);
    for threshold in [1u64, 2, 4, 8, 16, 64] {
        let rows: Vec<_> = dataset
            .iter()
            .map(|g| {
                let mapper = PriorityMapper::with_threshold(&sys, threshold);
                CostModel::new(&sys).evaluate(g, &mapper.map(g))
            })
            .collect();
        let t: Vec<f64> = rows.iter().map(|m| m.tops_per_watt).collect();
        let f: Vec<f64> = rows.iter().map(|m| m.gflops).collect();
        let u = rows.iter().map(|m| m.utilization).sum::<f64>() / rows.len() as f64;
        want.row(vec![
            threshold.to_string(),
            format!("{:.4}", geomean(&t)),
            format!("{:.2}", geomean(&f)),
            format!("{:.4}", u),
        ])
        .unwrap();
    }
    assert_eq!(
        got,
        want.encode(),
        "ablation-threshold.csv drifted from the direct evaluation"
    );
}

#[test]
fn order_engine_routed_csv_is_byte_identical() {
    let ctx = quick_ctx("order");
    let got = run_and_read(&ctx, "ablation-order");

    let dataset = synthetic::dataset(ctx.seed, ctx.synthetic_size().min(300));
    let sys = CimSystem::at_level(&ctx.arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let variants: [(&str, Option<[Dim; 3]>); 4] = [
        ("greedy (ours)", None),
        ("fixed M,K,N", Some([Dim::M, Dim::K, Dim::N])),
        ("fixed N,K,M", Some([Dim::N, Dim::K, Dim::M])),
        ("fixed K,N,M", Some([Dim::K, Dim::N, Dim::M])),
    ];
    let mut want = Csv::new(vec!["order", "geo_topsw", "geo_gflops"]);
    for (name, order) in variants {
        let rows: Vec<_> = dataset
            .iter()
            .map(|g| {
                let base = PriorityMapper::new(&sys).map(g);
                let mapping = match order {
                    None => base,
                    Some(o) => base.with_dram_order(o),
                };
                CostModel::new(&sys).evaluate(g, &mapping)
            })
            .collect();
        let t: Vec<f64> = rows.iter().map(|m| m.tops_per_watt).collect();
        let f: Vec<f64> = rows.iter().map(|m| m.gflops).collect();
        want.row(vec![
            name.to_string(),
            format!("{:.4}", geomean(&t)),
            format!("{:.2}", geomean(&f)),
        ])
        .unwrap();
    }
    assert_eq!(
        got,
        want.encode(),
        "ablation-order.csv drifted from the direct evaluation"
    );
}

/// ISSUE 4 acceptance: every experiment id executes via the scenario
/// path (`repro run <name>`) with a CSV byte-identical to the classic
/// `repro experiment <name>` path. Both lower to the same registry run
/// function — this pins the *lowering* (quick mode, seed, out-dir and
/// cache wiring) so `repro run` can never silently drift from
/// `repro experiment`.
///
/// `table2` reports wall-clock seconds, which no harness can make
/// byte-stable; for it the header and the runs axis are compared
/// instead of raw bytes.
#[test]
fn every_experiment_id_via_scenario_run_is_byte_identical() {
    use www_cim::scenario::{self, exec, ScenarioKind};

    for id in experiments::ids() {
        // Classic path: the experiment registry over a plain quick Ctx.
        let direct_ctx = quick_ctx(&format!("cls_{id}"));
        let direct = run_and_read(&direct_ctx, id);

        // Scenario path: the built-in scenario for the id, switched to
        // quick mode, writing into its own directory.
        let mut sc = scenario::builtin(id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        match &mut sc.kind {
            ScenarioKind::Experiment { quick, .. } => *quick = true,
            other => panic!("{id}: built-in must be an experiment scenario, got {other:?}"),
        }
        sc.output.dir = std::env::temp_dir().join(format!("www_cim_golden_eq_run_{id}"));
        let _ = std::fs::remove_dir_all(&sc.output.dir);
        exec::execute(&sc, None).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        let via_run = std::fs::read_to_string(sc.output.dir.join(format!("{id}.csv")))
            .unwrap_or_else(|e| panic!("{id}: scenario run left no csv mirror: {e}"));

        if id == "table2" {
            let a = csv::parse(&direct);
            let b = csv::parse(&via_run);
            assert_eq!(a[0], b[0], "table2: header drifted");
            assert_eq!(a.len(), b.len(), "table2: row count drifted");
            let runs = |rows: &[Vec<String>]| -> Vec<String> {
                rows[1..].iter().map(|r| r[0].clone()).collect()
            };
            assert_eq!(runs(&a), runs(&b), "table2: runs axis drifted");
        } else {
            assert_eq!(
                via_run, direct,
                "{id}: `repro run {id}` CSV drifted from `repro experiment {id}`"
            );
        }
        let _ = std::fs::remove_dir_all(&sc.output.dir);
        let _ = std::fs::remove_dir_all(&direct_ctx.out_dir);
    }
}

/// The batch axis's no-op guarantee: `--batch 1` produces the same
/// workload list — names, shapes, order — and therefore the same sweep
/// fingerprint as a parse that never heard of batching. Existing cache
/// files and shard summaries stay valid.
#[test]
fn batch_one_is_a_strict_fingerprint_no_op() {
    use www_cim::arch::Architecture;
    use www_cim::sweep::{shard, spec, SweepSpec};

    let seed = synthetic::DEFAULT_SEED;
    let plain = spec::parse_workloads("all", seed).unwrap();
    let batched = spec::parse_workloads_batched("all", seed, &[1]).unwrap();
    assert_eq!(plain, batched, "batch=1 must not perturb the parsed workloads");

    let arch = Architecture::default_sm();
    let systems = spec::parse_systems("baseline,d1", "rf,smem-b").unwrap();
    let before = SweepSpec::new("golden")
        .workloads(plain)
        .systems(systems.clone());
    let after = SweepSpec::new("golden")
        .workloads(batched)
        .systems(systems)
        .batches(vec![1]);
    assert_eq!(
        shard::sweep_fingerprint(&arch, &before),
        shard::sweep_fingerprint(&arch, &after),
        "batch=1 must leave the sweep fingerprint untouched"
    );
}

/// And the inverse property: any batch above 1 reshapes the grid (new
/// `@b<n>` workload names, folded M dimensions), so its fingerprint —
/// and with it every cache/shard compatibility check — must diverge
/// from the batch-1 sweep's.
#[test]
fn batched_fingerprints_differ_from_batch_one() {
    use www_cim::arch::Architecture;
    use www_cim::sweep::{shard, spec, SweepSpec};

    let seed = synthetic::DEFAULT_SEED;
    let arch = Architecture::default_sm();
    let systems = spec::parse_systems("baseline,d1", "rf").unwrap();
    let fp_at = |batches: &[u64]| {
        let s = SweepSpec::new("golden")
            .workloads(spec::parse_workloads_batched("gptj,bert", seed, batches).unwrap())
            .systems(systems.clone())
            .batches(batches.to_vec());
        shard::sweep_fingerprint(&arch, &s)
    };
    let one = fp_at(&[1]);
    for b in [2u64, 4, 16, 64] {
        assert_ne!(one, fp_at(&[b]), "batch={b} must change the fingerprint");
        assert_ne!(one, fp_at(&[1, b]), "batch axis [1,{b}] must change the fingerprint");
    }
}
