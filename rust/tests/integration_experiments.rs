//! Integration: every experiment regenerator runs end-to-end in quick
//! mode and produces its CSV mirror.

use www_cim::experiments::{self, Ctx};

fn quick_ctx(tag: &str) -> Ctx {
    let mut ctx = Ctx::quick();
    ctx.out_dir = std::env::temp_dir().join(format!("www_cim_test_results_{tag}"));
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
    ctx
}

#[test]
fn every_experiment_runs_quick() {
    let ctx = quick_ctx("all");
    for id in experiments::ids() {
        experiments::run(id, &ctx).unwrap_or_else(|e| panic!("{id} failed: {e:#}"));
    }
}

#[test]
fn csv_outputs_created_with_content() {
    let ctx = quick_ctx("csv");
    for id in ["fig2", "fig9", "fig12", "table6", "roofline"] {
        experiments::run(id, &ctx).unwrap();
        let path = ctx.out_dir.join(format!("{id}.csv"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{id}: missing csv: {e}"));
        assert!(text.lines().count() > 2, "{id}: csv nearly empty");
    }
}

#[test]
fn fig9_csv_covers_all_primitives() {
    let ctx = quick_ctx("fig9");
    experiments::run("fig9", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("fig9.csv")).unwrap();
    for prim in ["Analog-6T", "Analog-8T", "Digital-6T", "Digital-8T"] {
        assert!(text.contains(prim), "fig9.csv missing {prim}");
    }
}

#[test]
fn fig12_reports_cim_energy_win_for_bert() {
    let ctx = quick_ctx("fig12");
    experiments::run("fig12", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("fig12.csv")).unwrap();
    let bert_rf: Vec<&str> = text
        .lines()
        .filter(|l| l.starts_with("a:RF,BERT-Large"))
        .collect();
    assert_eq!(bert_rf.len(), 1);
    let mean: f64 = bert_rf[0].split(',').nth(2).unwrap().parse().unwrap();
    assert!(mean > 1.5, "BERT RF TOPS/W change {mean} should be >1.5x");
}

#[test]
fn table6_lists_all_real_layers() {
    let ctx = quick_ctx("table6");
    experiments::run("table6", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("table6.csv")).unwrap();
    // 5 BERT + 5 GPT-J + 53 ResNet + 2 DLRM + header
    assert_eq!(text.lines().count(), 1 + 5 + 5 + 53 + 2);
}

#[test]
fn unknown_experiment_rejected() {
    let ctx = quick_ctx("unknown");
    assert!(experiments::run("fig99", &ctx).is_err());
}
