//! Integration tests for the persistence + sharding layer (ISSUE 2 +
//! ISSUE 3): persisted-cache round trips (warm-from-disk runs
//! bit-identical to cold ones, zero misses, zero mapper invocations now
//! that mappings persist too), cost-model/mapper/format-version
//! invalidation at the engine level, and shard + merge reproducing the
//! unsharded sweep byte-for-byte.

use std::path::PathBuf;
use std::sync::Arc;

use www_cim::arch::{Architecture, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::SystemSpec;
use www_cim::cost::COST_MODEL_VERSION;
use www_cim::sweep::{
    output, persist, shard, sweep_fingerprint, CacheLoad, EvalCache, SweepEngine, SweepSpec,
};
use www_cim::util::check::{check, Config};
use www_cim::util::rng::Rng;
use www_cim::workload::{synthetic, Gemm};

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("www_cim_persist_it_{tag}"))
}

fn random_gemm(rng: &mut Rng) -> Gemm {
    let dim = |rng: &mut Rng| -> u64 {
        match rng.gen_range(0, 3) {
            0 => 1 << rng.gen_range(0, 12),
            1 => rng.gen_range(1, 4097),
            _ => rng.gen_range(1, 64),
        }
    };
    Gemm::new(dim(rng), dim(rng), dim(rng))
}

fn random_spec(rng: &mut Rng) -> SystemSpec {
    let prim = CimPrimitive::all()[rng.index(4)].clone();
    match rng.gen_range(0, 4) {
        0 => SystemSpec::Baseline,
        1 => SystemSpec::CimAtRf(prim),
        2 => SystemSpec::CimAtSmem(prim, SmemConfig::ConfigA),
        _ => SystemSpec::CimAtSmem(prim, SmemConfig::ConfigB),
    }
}

/// ISSUE property: save → load → warm run is bit-identical to the cold
/// run that wrote the cache, with zero warm misses — for random grids.
#[test]
fn prop_persisted_cache_round_trip() {
    let arch = Architecture::default_sm();
    let dir = tmp_dir("prop");
    let _ = std::fs::remove_dir_all(&dir);
    let mut case = 0u32;
    check(
        Config::default().cases(12),
        "save -> load -> warm == cold",
        |rng| {
            case += 1;
            let path = dir.join(format!("cache-{case}.bin"));
            let gemms: Vec<Gemm> = (0..(1 + rng.index(5))).map(|_| random_gemm(rng)).collect();
            let spec = SweepSpec::new("prop")
                .workload("w", gemms)
                .systems(vec![random_spec(rng), random_spec(rng)]);

            let cold_engine = SweepEngine::new(arch.clone()).threads(1);
            let cold = cold_engine.run_spec(&spec);
            persist::save(cold_engine.cache(), &path).map_err(|e| format!("save: {e:#}"))?;

            let warm_cache = Arc::new(EvalCache::new());
            match persist::load_into(&warm_cache, &path).map_err(|e| format!("load: {e:#}"))? {
                CacheLoad::Loaded { entries } => {
                    if entries as u64 != cold.cache_misses {
                        return Err(format!(
                            "persisted {entries} entries, cold run computed {}",
                            cold.cache_misses
                        ));
                    }
                }
                other => return Err(format!("expected Loaded, got {other:?}")),
            }
            let warm_engine = SweepEngine::with_cache(arch.clone(), warm_cache).threads(1);
            let warm = warm_engine.run_spec(&spec);
            if warm.cache_misses != 0 {
                return Err(format!(
                    "warm-from-disk run recomputed {} points",
                    warm.cache_misses
                ));
            }
            for (a, b) in cold.results.iter().zip(&warm.results) {
                if a.metrics != b.metrics || a.system != b.system {
                    return Err(format!("{} on {}: warm != cold", a.gemm, a.system));
                }
                // serialize → persist → load → re-serialize must be
                // bit-exact, canonical mapping form included.
                if a.mapping != b.mapping {
                    return Err(format!("{} on {}: mapping round trip drifted", a.gemm, a.system));
                }
            }
            if warm_engine.cache().mapper_calls() != 0 {
                return Err("warm-from-disk run re-invoked the mapper".to_string());
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cross-process warm-start contract on a realistic grid: every
/// point of a second process's identical sweep is served from the
/// persisted file, and re-saving yields a byte-identical cache file.
#[test]
fn warm_start_across_processes_zero_misses() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("sweep")
        .workload("synthetic", synthetic::dataset(7, 30))
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_8t(), SmemConfig::ConfigB),
        ]);
    let dir = tmp_dir("warm");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    // "Process 1": cold sweep, persist.
    let p1 = SweepEngine::new(arch.clone());
    let cold = p1.run_spec(&spec);
    assert!(cold.cache_misses > 0);
    persist::save(p1.cache(), &path).unwrap();
    let file1 = std::fs::read_to_string(&path).unwrap();

    // "Process 2": fresh engine, warm cache from disk.
    let cache = Arc::new(EvalCache::new());
    persist::load_into(&cache, &path).unwrap();
    let p2 = SweepEngine::with_cache(arch, cache);
    let warm = p2.run_spec(&spec);
    assert_eq!(warm.cache_misses, 0, "cross-process rerun must be all hits");
    assert_eq!(warm.cache_hits as usize, spec.n_points());
    for (a, b) in cold.results.iter().zip(&warm.results) {
        assert_eq!(a.metrics, b.metrics);
    }

    // Determinism: persisting the warmed cache reproduces the file.
    persist::save(p2.cache(), &path).unwrap();
    let file2 = std::fs::read_to_string(&path).unwrap();
    assert_eq!(file1, file2, "cache file must be stable across save cycles");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 3 warm-start contract: a persisted mapping-aware cache
/// fully warms a second process — zero misses *and zero mapper
/// invocations* (the cached mappings make re-mapping unnecessary), with
/// every CiM result carrying its mapping bit-for-bit.
#[test]
fn warm_start_with_mappings_never_reinvokes_the_mapper() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("warm-mappings")
        .workload("synthetic", synthetic::dataset(5, 20))
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_8t(), SmemConfig::ConfigB),
        ]);
    let dir = tmp_dir("warm_mappings");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    // "Process 1": cold sweep — one mapper call per distinct CiM miss
    // (the random dataset may repeat a shape; repeats are hits).
    let distinct: std::collections::HashSet<Gemm> =
        spec.workloads[0].1.iter().copied().collect();
    // Single-threaded so a repeated random shape cannot race two
    // concurrent misses (which would double-count the mapper call).
    let p1 = SweepEngine::new(arch.clone()).threads(1);
    let cold = p1.run_spec(&spec);
    assert_eq!(
        p1.cache().mapper_calls(),
        2 * distinct.len() as u64,
        "one mapper call per (CiM system, distinct GEMM) miss"
    );
    persist::save(p1.cache(), &path).unwrap();

    // "Process 2": warm from disk — no misses, no mapper calls at all.
    let cache = Arc::new(EvalCache::new());
    persist::load_into(&cache, &path).unwrap();
    let p2 = SweepEngine::with_cache(arch, cache);
    let warm = p2.run_spec(&spec);
    assert_eq!(warm.cache_misses, 0, "warm run must be all hits");
    assert_eq!(
        p2.cache().mapper_calls(),
        0,
        "cached mappings must make re-mapping unnecessary"
    );
    for (a, b) in cold.results.iter().zip(&warm.results) {
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.mapping, b.mapping, "{} on {}", a.gemm, a.system);
    }
    // Baseline rows have no mapping; every CiM row has one.
    for r in &warm.results {
        assert_eq!(
            r.mapping.is_some(),
            r.system != "Tensor-core",
            "{} on {}",
            r.gemm,
            r.system
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A PR 2-format (format-version 1, mapping-less) cache file must be
/// discarded wholesale at the engine level: the next run recomputes
/// every point rather than trusting mapper-less entries.
#[test]
fn pr2_format_cache_forces_recomputation() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("pr2")
        .workload("w", vec![Gemm::new(64, 64, 64), Gemm::new(256, 256, 256)])
        .systems(vec![SystemSpec::CimAtRf(CimPrimitive::digital_6t())]);
    let dir = tmp_dir("pr2_format");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    // Write a current cache, then rewrite it into the PR 2 shape:
    // format=1 header without the mapper token, entries without the
    // mapping column.
    let p1 = SweepEngine::new(arch.clone());
    p1.run_spec(&spec);
    persist::save(p1.cache(), &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let v1: String = text
        .lines()
        .enumerate()
        .map(|(i, line)| {
            let fields: Vec<&str> = line.split('\t').collect();
            if i == 0 {
                // magic + format=1 + cost-model=…, no mapper token.
                format!("{}\tformat=1\t{}", fields[0], fields[2])
            } else {
                // drop the last-used and mapping columns (fields 4-5).
                let mut f = fields.clone();
                f.remove(4);
                f.remove(4);
                f.join("\t")
            }
        })
        .collect::<Vec<String>>()
        .join("\n")
        + "\n";
    assert_ne!(text, v1);
    std::fs::write(&path, v1).unwrap();

    let cache = Arc::new(EvalCache::new());
    match persist::load_into(&cache, &path).unwrap() {
        CacheLoad::Discarded { reason } => {
            assert!(reason.contains("incompatible header"), "{reason}")
        }
        other => panic!("PR 2-format cache must be discarded, got {other:?}"),
    }
    assert!(cache.is_empty(), "zero v1 entries may survive");
    let p2 = SweepEngine::with_cache(arch, cache);
    let rerun = p2.run_spec(&spec);
    assert_eq!(rerun.cache_misses as usize, spec.n_points());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A stale mapper version in the header (an algorithm change without a
/// cache-format change) likewise discards the file with zero survivors.
#[test]
fn stale_mapper_version_forces_recomputation() {
    use www_cim::mapping::MAPPER_VERSION;
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("stale-mapper")
        .workload("w", vec![Gemm::new(128, 128, 128)])
        .systems(vec![SystemSpec::CimAtRf(CimPrimitive::digital_8t())]);
    let dir = tmp_dir("stale_mapper");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    let p1 = SweepEngine::new(arch.clone());
    p1.run_spec(&spec);
    persist::save(p1.cache(), &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let stale = text.replacen(
        &format!("mapper={MAPPER_VERSION}"),
        &format!("mapper={}", MAPPER_VERSION + 1),
        1,
    );
    assert_ne!(text, stale);
    std::fs::write(&path, stale).unwrap();

    let cache = Arc::new(EvalCache::new());
    match persist::load_into(&cache, &path).unwrap() {
        CacheLoad::Discarded { .. } => {}
        other => panic!("stale-mapper cache must be discarded, got {other:?}"),
    }
    assert!(cache.is_empty(), "zero stale entries may survive");
    let p2 = SweepEngine::with_cache(arch, cache);
    let rerun = p2.run_spec(&spec);
    assert_eq!(rerun.cache_misses as usize, spec.n_points());
    assert_eq!(p2.cache().mapper_calls(), 1, "the point must be re-mapped");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A bumped cost-model version must invalidate the persisted cache at
/// the engine level: the next run recomputes everything instead of
/// serving stale metrics.
#[test]
fn stale_cost_model_forces_recomputation() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("stale")
        .workload("w", vec![Gemm::new(64, 64, 64), Gemm::new(256, 256, 256)])
        .systems(vec![SystemSpec::CimAtRf(CimPrimitive::digital_6t())]);
    let dir = tmp_dir("stale");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    let p1 = SweepEngine::new(arch.clone());
    p1.run_spec(&spec);
    persist::save(p1.cache(), &path).unwrap();

    // Pretend the file came from a binary with a newer cost model.
    let text = std::fs::read_to_string(&path).unwrap();
    let stale = text.replacen(
        &format!("cost-model={COST_MODEL_VERSION}"),
        &format!("cost-model={}", COST_MODEL_VERSION + 1),
        1,
    );
    assert_ne!(text, stale);
    std::fs::write(&path, stale).unwrap();

    let cache = Arc::new(EvalCache::new());
    match persist::load_into(&cache, &path).unwrap() {
        CacheLoad::Discarded { .. } => {}
        other => panic!("version-bumped cache must be discarded, got {other:?}"),
    }
    let p2 = SweepEngine::with_cache(arch, cache);
    let rerun = p2.run_spec(&spec);
    assert_eq!(
        rerun.cache_misses as usize,
        spec.n_points(),
        "a discarded cache must recompute every point"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `n` shards, run by `n` independent engines, merged via the shard
/// summaries == the unsharded sweep — byte-identical CSV included.
#[test]
fn shard_merge_reproduces_unsharded_sweep() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("sweep")
        .workload("synthetic", synthetic::dataset(11, 13))
        .workload("fixed", vec![Gemm::new(512, 1024, 1024), Gemm::new(1, 256, 512)])
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_8t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_6t(), SmemConfig::ConfigA),
        ])
        .sm_counts(vec![1, 4]);
    let fp = sweep_fingerprint(&arch, &spec);
    let jobs = spec.jobs();
    let full = SweepEngine::new(arch.clone()).run_spec(&spec);
    let full_csv = output::results_csv(&full.results).unwrap().encode();

    let dir = tmp_dir("merge");
    let _ = std::fs::remove_dir_all(&dir);
    for count in [2usize, 3] {
        let mut paths = Vec::new();
        for index in 0..count {
            let id = shard::ShardId { index, count };
            // Each shard runs in its own engine, as separate processes
            // (or hosts) would.
            let engine = SweepEngine::new(arch.clone());
            let run = engine.run_jobs_named(&spec.name, &id.slice(&jobs));
            let path = dir.join(format!("{count}way-{}.json", id.file_tag()));
            shard::write_shard_json(&run, id, &fp, jobs.len(), &path).unwrap();
            paths.push(path);
        }
        let merged = shard::merge_files(&paths).unwrap();
        assert_eq!(merged.shard_count, count);
        assert_eq!(merged.results.len(), full.results.len());
        let merged_csv = output::results_csv(&merged.results).unwrap().encode();
        assert_eq!(
            merged_csv, full_csv,
            "{count}-way merge must be byte-identical to the unsharded CSV"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two *processes* saving into one `--cache` path concurrently must
/// union their entries, not last-writer-win: the saves serialize on
/// the sidecar lock and each re-reads the file before writing, so a
/// warm rerun of EITHER sweep is served fully from the shared cache.
#[test]
fn racing_processes_union_the_shared_cache() {
    use std::process::{Command, Stdio};

    let exe = env!("CARGO_BIN_EXE_repro");
    let dir = tmp_dir("race");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cache = dir.join("cache.bin");

    let sweep = |seed: u32, tag: &str| -> Command {
        let mut cmd = Command::new(exe);
        cmd.arg("sweep")
            .arg("--workloads")
            .arg("synthetic:3")
            .arg("--prims")
            .arg("d1")
            .arg("--levels")
            .arg("rf")
            .arg("--seed")
            .arg(seed.to_string())
            .arg("--tag")
            .arg(tag)
            .arg("--out")
            .arg(&dir)
            .arg(format!("--cache={}", cache.display()));
        cmd
    };

    // The race: two different sweeps (different seeds -> disjoint
    // synthetic workloads) run and save concurrently.
    let mut a = sweep(1, "race-a").stdout(Stdio::null()).spawn().unwrap();
    let mut b = sweep(2, "race-b").stdout(Stdio::null()).spawn().unwrap();
    assert!(a.wait().unwrap().success(), "seed 1 sweep failed");
    assert!(b.wait().unwrap().success(), "seed 2 sweep failed");
    assert!(cache.exists(), "shared cache file must exist after both saves");

    // Warm reruns: a lost save would force recomputation of that
    // sweep's points ("N unique" with N > 0).
    for (seed, tag) in [(1, "race-a"), (2, "race-b")] {
        let out = sweep(seed, tag).output().unwrap();
        assert!(out.status.success(), "seed {seed} warm rerun failed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(
            stdout.contains("cache: 0 unique"),
            "seed {seed} warm rerun must be all hits:\n{stdout}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Sharding composes with the persistent cache: shards sharing one
/// cache file leave a cache that fully warms the unsharded sweep.
#[test]
fn shards_prime_the_persistent_cache_for_full_runs() {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("compose")
        .workload("synthetic", synthetic::dataset(3, 10))
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
        ]);
    let jobs = spec.jobs();
    let dir = tmp_dir("compose");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("cache.bin");

    for index in 0..2usize {
        let id = shard::ShardId { index, count: 2 };
        let cache = Arc::new(EvalCache::new());
        persist::load_into(&cache, &path).unwrap();
        let engine = SweepEngine::with_cache(arch.clone(), cache);
        engine.run_jobs_named(&spec.name, &id.slice(&jobs));
        persist::save(engine.cache(), &path).unwrap();
    }

    let cache = Arc::new(EvalCache::new());
    match persist::load_into(&cache, &path).unwrap() {
        CacheLoad::Loaded { entries } => assert!(entries > 0),
        other => panic!("expected Loaded, got {other:?}"),
    }
    let engine = SweepEngine::with_cache(arch, cache);
    let run = engine.run_spec(&spec);
    assert_eq!(
        run.cache_misses, 0,
        "two half-sweeps must fully warm the whole grid"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Save a small mixed (baseline + CiM) grid and return the cache-file
/// text plus the clean-load entry set the salvage tests check against.
fn saved_cache_text(dir: &std::path::Path) -> (String, Vec<(String, Gemm)>) {
    let arch = Architecture::default_sm();
    let spec = SweepSpec::new("salvage")
        .workload("w", vec![
            Gemm::new(8, 8, 8),
            Gemm::new(64, 32, 16),
            Gemm::new(256, 64, 128),
        ])
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
        ]);
    let engine = SweepEngine::new(arch).threads(1);
    engine.run_spec(&spec);
    let path = dir.join("clean.bin");
    persist::save(engine.cache(), &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let keys: Vec<(String, Gemm)> = engine
        .cache()
        .snapshot()
        .into_iter()
        .map(|(p, g, _)| (p, g))
        .collect();
    (text, keys)
}

/// Every surviving entry must be one the undamaged file held — a
/// salvaging load may lose a line, never invent or mutate one.
fn assert_no_invented_entries(cache: &EvalCache, original: &[(String, Gemm)]) {
    for (point, gemm, _) in cache.snapshot() {
        assert!(
            original.contains(&(point.clone(), gemm)),
            "salvage invented entry {point:?} {gemm}"
        );
    }
}

/// ISSUE 10 property: flipping any single non-newline byte after the
/// header of a saved v4 cache file salvages all but at most one entry
/// — and never invents one. (Header damage is out of scope by design:
/// an unrecognizable header discards the file wholesale.)
#[test]
fn prop_single_byte_flip_salvages_all_but_one_entry() {
    let dir = tmp_dir("flip");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (text, original) = saved_cache_text(&dir);
    let total = original.len();
    assert!(total >= 3, "grid must persist several entries");
    let body_start = text.find('\n').unwrap() + 1;
    let mut case = 0u32;
    check(
        Config::default().cases(40),
        "single byte flip salvages all but one entry",
        |rng| {
            case += 1;
            let mut bytes = text.clone().into_bytes();
            // Flip a body byte that is not a line separator: merging
            // two lines (or splitting one — both halves then fail the
            // checksum) is a different, multi-line corruption.
            let mut pos = body_start + rng.index(bytes.len() - body_start);
            while bytes[pos] == b'\n' {
                pos = body_start + rng.index(bytes.len() - body_start);
            }
            let xor = 1 + rng.index(255) as u8;
            bytes[pos] ^= xor;
            let path = dir.join(format!("flip-{case}.bin"));
            std::fs::write(&path, &bytes).map_err(|e| format!("write: {e}"))?;

            let cache = EvalCache::new();
            let load = persist::load_into(&cache, &path)
                .map_err(|e| format!("load: {e:#}"))?;
            let kept = match load {
                CacheLoad::Salvaged { kept, dropped, quarantined } => {
                    // One flipped byte damages one line — except a
                    // flip *to* the newline value, which splits a line
                    // into two corrupt halves (dropped == 2).
                    if dropped == 0 || dropped > 2 {
                        return Err(format!(
                            "one flipped byte, {dropped} dropped lines (pos {pos})"
                        ));
                    }
                    if !quarantined {
                        return Err("damaged file must be quarantined".to_string());
                    }
                    kept
                }
                other => return Err(format!("expected Salvaged, got {other:?}")),
            };
            if kept + 1 < total {
                return Err(format!("kept {kept} of {total} (pos {pos})"));
            }
            assert_no_invented_entries(&cache, &original);
            // The quarantined original still exists for post-mortem.
            let _ = std::fs::remove_file(dir.join(format!(
                "flip-{case}.bin.quarantine.{}",
                std::process::id()
            )));
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fixture: a file truncated mid-line (the classic torn tail from a
/// crashed writer) loses only its final entry.
#[test]
fn truncated_mid_line_fixture_salvages_the_rest() {
    let dir = tmp_dir("torn_tail");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (text, original) = saved_cache_text(&dir);
    let total = original.len();
    // Cut inside the last entry line, well before its checksum.
    let last_line_start = text.trim_end().rfind('\n').unwrap() + 1;
    let torn = &text[..last_line_start + 10];
    let path = dir.join("torn.bin");
    std::fs::write(&path, torn).unwrap();

    let cache = EvalCache::new();
    let load = persist::load_into(&cache, &path).unwrap();
    assert_eq!(
        load,
        CacheLoad::Salvaged { kept: total - 1, dropped: 1, quarantined: true }
    );
    assert_no_invented_entries(&cache, &original);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fixture: a file truncated inside the final checksum column — the
/// short checksum field condemns that line only.
#[test]
fn truncated_mid_checksum_fixture_salvages_the_rest() {
    let dir = tmp_dir("torn_sum");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (text, original) = saved_cache_text(&dir);
    let total = original.len();
    // The file ends "...\t<16 hex digits>\n"; keep 7 checksum digits.
    let torn = &text[..text.len() - 10];
    assert!(!torn.ends_with('\n'), "cut must land inside the checksum");
    let path = dir.join("torn-sum.bin");
    std::fs::write(&path, torn).unwrap();

    let cache = EvalCache::new();
    let load = persist::load_into(&cache, &path).unwrap();
    assert_eq!(
        load,
        CacheLoad::Salvaged { kept: total - 1, dropped: 1, quarantined: true }
    );
    assert_no_invented_entries(&cache, &original);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fixture: a duplicated entry line (e.g. a partially-flushed append
/// replayed). Every checksum verifies, so the load is clean — and the
/// duplicate deduplicates instead of inventing an entry.
#[test]
fn duplicated_line_fixture_loads_clean_without_inventing_entries() {
    let dir = tmp_dir("dup_line");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (text, original) = saved_cache_text(&dir);
    let total = original.len();
    let first_entry_line = text.lines().nth(1).unwrap().to_string();
    let dup = format!("{}{first_entry_line}\n", text);
    let path = dir.join("dup.bin");
    std::fs::write(&path, dup).unwrap();

    let cache = EvalCache::new();
    let load = persist::load_into(&cache, &path).unwrap();
    // All lines verify; the duplicated key collapses in the cache map.
    assert_eq!(load, CacheLoad::Loaded { entries: total + 1 });
    assert_eq!(cache.len(), total, "duplicate must deduplicate");
    assert_no_invented_entries(&cache, &original);
    assert!(path.exists(), "a clean load must not quarantine");
    let _ = std::fs::remove_dir_all(&dir);
}
