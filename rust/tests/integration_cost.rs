//! Integration: cost model trends that the paper's evaluation depends
//! on (the When/Where answers), exercised across systems and workloads.

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::cost::{BaselineModel, CostModel, Metrics};
use www_cim::mapping::PriorityMapper;
use www_cim::workload::{models, Gemm};

fn eval(sys: &CimSystem, g: Gemm) -> Metrics {
    CostModel::new(sys).evaluate(&g, &PriorityMapper::new(sys).map(&g))
}

fn rf(p: CimPrimitive) -> CimSystem {
    CimSystem::at_level(&Architecture::default_sm(), p, MemLevel::RegisterFile)
}

#[test]
fn bert_layers_high_efficiency_at_rf() {
    // §VI-C: BERT-Large layers achieve > 1.67 TOPS/W at RF (D-1).
    let sys = rf(CimPrimitive::digital_6t());
    for g in models::bert_large().gemms() {
        let m = eval(&sys, *g);
        assert!(m.tops_per_watt > 1.0, "{g}: {}", m.tops_per_watt);
    }
}

#[test]
fn gemv_layers_match_paper_pathology() {
    // §VI-C: M=1 layers fall to ~0.03 TOPS/W with low throughput.
    let sys = rf(CimPrimitive::digital_6t());
    for g in [Gemm::new(1, 4096, 4096), Gemm::new(1, 16384, 4096)] {
        let m = eval(&sys, g);
        assert!(m.tops_per_watt < 0.05, "{g}: {}", m.tops_per_watt);
        assert!(m.memory_bound(), "{g} must be DRAM-throttled");
    }
}

#[test]
fn cim_beats_baseline_on_energy_for_regular_shapes() {
    // Table V "When": consistent TOPS/W advantage on regular GEMMs.
    let arch = Architecture::default_sm();
    let sys = rf(CimPrimitive::digital_6t());
    let base = BaselineModel::new(&arch);
    for g in models::bert_large().gemms() {
        let c = eval(&sys, *g);
        let b = base.evaluate(g);
        assert!(
            c.tops_per_watt > b.tops_per_watt,
            "{g}: cim {} vs base {}",
            c.tops_per_watt,
            b.tops_per_watt
        );
    }
}

#[test]
fn baseline_beats_cim_rf_on_gemv_throughput() {
    // Table V "Where": at RF, CiM underperforms the baseline for pure
    // matrix-vector workloads (DLRM/GPT-J decode).
    let arch = Architecture::default_sm();
    let sys = rf(CimPrimitive::digital_6t());
    let base = BaselineModel::new(&arch);
    let g = Gemm::new(1, 256, 512);
    assert!(base.evaluate(&g).gflops >= eval(&sys, g).gflops);
}

#[test]
fn smem_configb_highest_throughput_across_primitives() {
    // Table V "Where": the biggest memory level gives the biggest
    // parallelism; configB beats RF throughput for every primitive on
    // large shapes.
    let arch = Architecture::default_sm();
    let g = Gemm::new(2048, 4096, 4096);
    for p in CimPrimitive::all() {
        let rf_m = eval(&rf(p.clone()), g);
        let smem = CimSystem::at_smem(&arch, p.clone(), SmemConfig::ConfigB);
        let sm_m = eval(&smem, g);
        assert!(
            sm_m.gflops > rf_m.gflops,
            "{}: smem {} vs rf {}",
            p.name,
            sm_m.gflops,
            rf_m.gflops
        );
    }
}

#[test]
fn energy_efficiency_saturates_with_weight_size() {
    // Fig 10(a): TOPS/W stabilizes once K exceeds on-chip capacity.
    let sys = rf(CimPrimitive::digital_6t());
    let t1 = eval(&sys, Gemm::new(512, 2048, 2048)).tops_per_watt;
    let t2 = eval(&sys, Gemm::new(512, 4096, 4096)).tops_per_watt;
    let rel = (t1 - t2).abs() / t1;
    assert!(rel < 0.35, "plateau violated: {t1} vs {t2}");
}

#[test]
fn n_growth_helps_energy() {
    // Fig 10(b): increasing N monotonically (weakly) improves TOPS/W.
    let sys = rf(CimPrimitive::digital_6t());
    let t16 = eval(&sys, Gemm::new(512, 16, 512)).tops_per_watt;
    let t512 = eval(&sys, Gemm::new(512, 512, 512)).tops_per_watt;
    let t4096 = eval(&sys, Gemm::new(512, 4096, 512)).tops_per_watt;
    assert!(t512 > t16);
    assert!(t4096 >= t512 * 0.9);
}

#[test]
fn throughput_grows_with_n_until_primitives_exhaust() {
    // Fig 10(b): N engages more primitives in parallel.
    let sys = rf(CimPrimitive::digital_6t());
    let f16 = eval(&sys, Gemm::new(512, 16, 512)).gflops;
    let f48 = eval(&sys, Gemm::new(512, 48, 512)).gflops;
    assert!(f48 > 1.5 * f16, "{f48} vs {f16}");
}

#[test]
fn fig13_energy_plateaus_for_large_squares() {
    let sys = rf(CimPrimitive::digital_6t());
    let e2k = eval(&sys, Gemm::new(2048, 2048, 2048)).fj_per_mac();
    let e8k = eval(&sys, Gemm::new(8192, 8192, 8192)).fj_per_mac();
    assert!(
        (e2k - e8k).abs() / e2k < 0.5,
        "fJ/MAC should plateau: {e2k} vs {e8k}"
    );
}

#[test]
fn tcore_pays_more_than_cim_for_large_squares() {
    // Fig 13: the baseline's RF/PE-buffer traffic keeps it above the
    // CiM configurations once DRAM amortizes.
    let arch = Architecture::default_sm();
    let g = Gemm::new(4096, 4096, 4096);
    let tc = BaselineModel::new(&arch).evaluate(&g).fj_per_mac();
    let d1 = eval(&rf(CimPrimitive::digital_6t()), g).fj_per_mac();
    assert!(tc > d1, "tcore {tc} vs d1 {d1}");
}

#[test]
fn dram_bytes_lower_bounded_by_matrix_sizes() {
    // Conservation: at least one pass of every matrix must cross DRAM.
    let sys = rf(CimPrimitive::digital_6t());
    for g in models::bert_large().gemms() {
        let m = eval(&sys, *g);
        assert!(m.dram_bytes >= g.total_bytes(), "{g}");
    }
}

#[test]
fn memory_bound_iff_bandwidth_cycles_dominate() {
    let sys = rf(CimPrimitive::digital_6t());
    for g in models::real_dataset().iter().flat_map(|w| w.gemms().to_vec()) {
        let m = eval(&sys, g);
        assert_eq!(
            m.memory_bound(),
            m.total_cycles > m.compute_cycles,
            "{g}"
        );
        assert_eq!(
            m.total_cycles,
            m.compute_cycles.max(m.dram_cycles).max(m.smem_cycles).max(1)
        );
    }
}
