//! Fault-injection tests for the shard orchestrator: a SIGKILLed shard
//! is retried and the merged CSV stays byte-identical to a
//! single-process run; a hung shard is killed by the wall-clock
//! timeout; `--resume` re-runs only the missing shards; and a spawn
//! failure reaps every already-running child. All drive
//! `orchestrate_with` against test [`Spawner`]s wrapping the real
//! binary (`CARGO_BIN_EXE_repro` — inside an integration test,
//! `current_exe` would be the *test* binary).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use www_cim::arch::Architecture;
use www_cim::scenario::orchestrate::{
    orchestrate_with, LocalSpawner, OrchestrateOptions, Spawner,
};
use www_cim::scenario::Scenario;
use www_cim::sweep::shard::ShardId;
use www_cim::sweep::{output, SweepEngine};
use www_cim::util::json::Json;

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

fn repro_exe() -> &'static str {
    env!("CARGO_BIN_EXE_repro")
}

/// Fresh output dir per test (orchestrations share nothing).
fn temp_out(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("www_cim_orch_it_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).expect("create temp out dir");
    dir
}

/// A small, fast sweep scenario writing into `out`.
fn scenario(name: &str, out: &Path) -> Scenario {
    Scenario::builder(name)
        .workloads("synthetic:2")
        .prims("d1")
        .levels("rf")
        .out_dir(out)
        .build()
        .expect("scenario builds")
}

fn opts(procs: usize) -> OrchestrateOptions {
    OrchestrateOptions { procs, timeout: None, retries: 0, resume: false }
}

/// The unsharded ground truth: the same scenario evaluated in-process.
fn reference_csv(sc: &Scenario) -> String {
    let spec = sc.sweep_spec().expect("scenario lowers");
    let run = SweepEngine::new(Architecture::default_sm()).run_spec(&spec);
    output::results_csv(&run.results).expect("csv encodes").encode()
}

fn read_manifest(out: &Path, base: &str) -> Json {
    let text = fs::read_to_string(out.join(format!("{base}.orchestrate.json")))
        .expect("run manifest exists");
    Json::parse(&text).expect("run manifest parses")
}

fn manifest_shard(manifest: &Json, index: usize) -> Json {
    let shards = manifest.get("shards").and_then(Json::as_array).expect("shards array");
    shards
        .iter()
        .find(|s| s.get("index").and_then(Json::as_u64) == Some(index as u64))
        .unwrap_or_else(|| panic!("manifest has no shard {index}"))
        .clone()
}

fn shard_status(manifest: &Json, index: usize) -> String {
    manifest_shard(manifest, index)
        .get("status")
        .and_then(Json::as_str)
        .expect("shard status")
        .to_string()
}

fn shard_attempts(manifest: &Json, index: usize) -> usize {
    manifest_shard(manifest, index)
        .get("attempts")
        .and_then(Json::as_array)
        .expect("shard attempts")
        .len()
}

// ---------------------------------------------------------------------------
// Test spawners
// ---------------------------------------------------------------------------

/// Delegates to [`LocalSpawner`], except the first spawn of shard
/// `victim` becomes a child that SIGKILLs itself before writing any
/// summary — a stand-in for an OOM kill mid-shard.
struct KillOnce {
    inner: LocalSpawner,
    victim: usize,
    kills: AtomicUsize,
}

impl Spawner for KillOnce {
    fn spawn_shard(&self, shard: ShardId, scenario: &Path) -> Result<Child> {
        if shard.index == self.victim && self.kills.fetch_add(1, Ordering::SeqCst) == 0 {
            return Command::new("sh")
                .arg("-c")
                .arg("kill -KILL $$")
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .context("spawning the self-killing child");
        }
        self.inner.spawn_shard(shard, scenario)
    }

    fn locus(&self, shard: ShardId) -> String {
        self.inner.locus(shard)
    }
}

/// Every shard hangs forever (well, 1000 s).
struct Hang;

impl Spawner for Hang {
    fn spawn_shard(&self, _shard: ShardId, _scenario: &Path) -> Result<Child> {
        Command::new("sleep")
            .arg("1000")
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .context("spawning the hung child")
    }

    fn locus(&self, _shard: ShardId) -> String {
        "hang".to_string()
    }
}

/// Delegates to [`LocalSpawner`] and counts spawns (resume must re-run
/// only the missing shards).
struct Counting {
    inner: LocalSpawner,
    spawns: AtomicUsize,
}

impl Counting {
    fn new() -> Counting {
        Counting { inner: LocalSpawner::new(repro_exe()), spawns: AtomicUsize::new(0) }
    }
}

impl Spawner for Counting {
    fn spawn_shard(&self, shard: ShardId, scenario: &Path) -> Result<Child> {
        self.spawns.fetch_add(1, Ordering::SeqCst);
        self.inner.spawn_shard(shard, scenario)
    }

    fn locus(&self, shard: ShardId) -> String {
        self.inner.locus(shard)
    }
}

/// Shard 0 becomes a long sleeper (its pid recorded); shard 1 fails to
/// spawn at all. The orchestrator must kill and reap the sleeper on its
/// way out.
struct FailSecond {
    sleeper_pid: AtomicUsize,
}

impl Spawner for FailSecond {
    fn spawn_shard(&self, shard: ShardId, _scenario: &Path) -> Result<Child> {
        if shard.index == 0 {
            let child = Command::new("sleep")
                .arg("1000")
                .stdout(Stdio::piped())
                .stderr(Stdio::piped())
                .spawn()
                .context("spawning the sleeper")?;
            self.sleeper_pid.store(child.id() as usize, Ordering::SeqCst);
            Ok(child)
        } else {
            bail!("injected spawn failure")
        }
    }

    fn locus(&self, _shard: ShardId) -> String {
        "test".to_string()
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

#[test]
fn killed_shard_is_retried_and_merge_matches_single_process() {
    let out = temp_out("killonce");
    let sc = scenario("orch-killonce", &out);
    let spawner =
        KillOnce { inner: LocalSpawner::new(repro_exe()), victim: 1, kills: AtomicUsize::new(0) };
    let opts = OrchestrateOptions { retries: 1, ..opts(2) };
    orchestrate_with(&sc, &opts, &spawner).expect("one SIGKILL must not abort the run");
    assert!(spawner.kills.load(Ordering::SeqCst) >= 1, "the victim shard never spawned");

    // The retried shard is deterministic, so the merged CSV is
    // byte-identical to an unsharded in-process evaluation.
    let merged = fs::read_to_string(out.join("orch-killonce.csv")).expect("merged csv");
    assert_eq!(merged, reference_csv(&sc), "merged CSV must be byte-identical");

    // The manifest records both attempts of the killed shard.
    let manifest = read_manifest(&out, "orch-killonce");
    assert_eq!(manifest.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(shard_status(&manifest, 1), "ok");
    assert_eq!(shard_attempts(&manifest, 1), 2, "SIGKILLed attempt + successful retry");
    assert_eq!(shard_attempts(&manifest, 0), 1);

    let _ = fs::remove_dir_all(&out);
}

#[test]
fn timeout_kills_hung_shards() {
    let out = temp_out("timeout");
    let sc = scenario("orch-timeout", &out);
    let opts = OrchestrateOptions { timeout: Some(Duration::from_millis(300)), ..opts(1) };
    let started = Instant::now();
    let err = orchestrate_with(&sc, &opts, &Hang).expect_err("a hung shard must fail the run");
    assert!(
        started.elapsed() < Duration::from_secs(60),
        "the 1000 s sleeper must have been killed by the 300 ms timeout"
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("timeout"), "{msg}");
    assert!(msg.contains("--resume"), "failure must point at resume: {msg}");

    // Failures still write the manifest — that is what makes them
    // diagnosable and resumable.
    let manifest = read_manifest(&out, "orch-timeout");
    assert_eq!(manifest.get("status").and_then(Json::as_str), Some("failed"));
    assert_eq!(shard_status(&manifest, 0), "timeout");

    let _ = fs::remove_dir_all(&out);
}

#[test]
fn resume_reruns_only_the_missing_shard() {
    let out = temp_out("resume");
    let sc = scenario("orch-resume", &out);

    let first = Counting::new();
    orchestrate_with(&sc, &opts(2), &first).expect("first run");
    assert_eq!(first.spawns.load(Ordering::SeqCst), 2);
    let csv_path = out.join("orch-resume.csv");
    let first_csv = fs::read_to_string(&csv_path).expect("first merged csv");

    // Lose shard 0's summary; resume must re-run exactly that shard and
    // adopt the surviving one.
    fs::remove_file(out.join("orch-resume-shard0of2.json")).expect("remove shard 0 summary");
    let second = Counting::new();
    let opts = OrchestrateOptions { resume: true, ..opts(2) };
    orchestrate_with(&sc, &opts, &second).expect("resumed run");
    assert_eq!(second.spawns.load(Ordering::SeqCst), 1, "resume must spawn only shard 0");
    assert_eq!(
        fs::read_to_string(&csv_path).expect("resumed merged csv"),
        first_csv,
        "resumed merge must be byte-identical"
    );

    let manifest = read_manifest(&out, "orch-resume");
    assert_eq!(shard_status(&manifest, 0), "ok");
    assert_eq!(shard_status(&manifest, 1), "skipped");
    assert_eq!(shard_attempts(&manifest, 1), 0, "an adopted shard never spawned");

    let _ = fs::remove_dir_all(&out);
}

#[test]
fn spawn_failure_reaps_already_spawned_children() {
    let out = temp_out("spawnfail");
    let sc = scenario("orch-spawnfail", &out);
    let spawner = FailSecond { sleeper_pid: AtomicUsize::new(0) };
    let err = orchestrate_with(&sc, &opts(2), &spawner)
        .expect_err("a spawn failure must abort the run");
    assert!(format!("{err:#}").contains("injected spawn failure"), "{err:#}");

    let pid = spawner.sleeper_pid.load(Ordering::SeqCst);
    assert!(pid != 0, "the sleeper was spawned before the failure");
    if cfg!(target_os = "linux") {
        assert!(
            !Path::new(&format!("/proc/{pid}")).exists(),
            "the sleeper (pid {pid}) must be killed and reaped, not leaked"
        );
    }

    // Even the aborted run documents itself.
    let manifest = read_manifest(&out, "orch-spawnfail");
    assert_eq!(manifest.get("status").and_then(Json::as_str), Some("failed"));

    let _ = fs::remove_dir_all(&out);
}
