//! Property-based tests over the mapping + cost invariants, using the
//! in-tree harness (`util::check`, the proptest substitute).

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::cost::{BaselineModel, CostModel};
use www_cim::mapping::loopnest::{distinct_tiles, refetches, Dim, Loop, Tensor};
use www_cim::mapping::PriorityMapper;
use www_cim::util::check::{check, Config};
use www_cim::util::rng::Rng;
use www_cim::workload::Gemm;

fn random_gemm(rng: &mut Rng) -> Gemm {
    // Mix of power-of-two and awkward shapes, spanning GEMV to huge.
    let dim = |rng: &mut Rng| -> u64 {
        match rng.gen_range(0, 3) {
            0 => 1 << rng.gen_range(0, 14),
            1 => rng.gen_range(1, 8193),
            _ => rng.gen_range(1, 64),
        }
    };
    Gemm::new(dim(rng), dim(rng), dim(rng))
}

fn random_system(rng: &mut Rng) -> CimSystem {
    let arch = Architecture::default_sm();
    let prim = CimPrimitive::all()[rng.index(4)].clone();
    match rng.gen_range(0, 3) {
        0 => CimSystem::at_level(&arch, prim, MemLevel::RegisterFile),
        1 => CimSystem::at_smem(&arch, prim, SmemConfig::ConfigA),
        _ => CimSystem::at_smem(&arch, prim, SmemConfig::ConfigB),
    }
}

#[test]
fn prop_mapping_always_valid() {
    check(Config::default().cases(300), "mapping valid", |rng| {
        let gemm = random_gemm(rng);
        let sys = random_system(rng);
        let m = PriorityMapper::new(&sys).map(&gemm);
        m.nest
            .validate()
            .map_err(|e| format!("{gemm} on {}: {e}", sys.label()))?;
        m.spatial
            .validate(&sys)
            .map_err(|e| format!("{gemm} on {}: {e}", sys.label()))
    });
}

#[test]
fn prop_metrics_well_formed() {
    check(Config::default().cases(200), "metrics well-formed", |rng| {
        let gemm = random_gemm(rng);
        let sys = random_system(rng);
        let m = CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm));
        if !(m.energy_pj.is_finite() && m.energy_pj > 0.0) {
            return Err(format!("{gemm}: energy {}", m.energy_pj));
        }
        if !(0.0..=1.0 + 1e-9).contains(&m.utilization) {
            return Err(format!("{gemm}: util {}", m.utilization));
        }
        if m.gflops > sys.peak_gops() * 1.001 {
            return Err(format!("{gemm}: {} > peak {}", m.gflops, sys.peak_gops()));
        }
        if m.total_cycles < m.compute_cycles {
            return Err(format!("{gemm}: total < compute cycles"));
        }
        Ok(())
    });
}

#[test]
fn prop_dram_traffic_conservation() {
    // Every byte of all three matrices must cross DRAM at least once;
    // refetches only add.
    check(Config::default().cases(200), "dram conservation", |rng| {
        let gemm = random_gemm(rng);
        let sys = random_system(rng);
        let m = CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm));
        if m.dram_bytes < gemm.total_bytes() {
            return Err(format!(
                "{gemm}: dram {} < matrices {}",
                m.dram_bytes,
                gemm.total_bytes()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_work() {
    // Growing any single dimension never reduces total energy.
    check(Config::default().cases(120), "energy monotone", |rng| {
        let g = random_gemm(rng);
        let sys = random_system(rng);
        let cost = CostModel::new(&sys);
        let e = |g: Gemm| cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g)).energy_pj;
        let base = e(g);
        let grown = [
            Gemm::new(g.m * 2, g.n, g.k),
            Gemm::new(g.m, g.n * 2, g.k),
            Gemm::new(g.m, g.n, g.k * 2),
        ];
        for gg in grown {
            if e(gg) < base * 0.999 {
                return Err(format!("{g} -> {gg} reduced energy"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_refetches_bounds() {
    // distinct <= refetches <= product of all factors, for any prefix.
    check(Config::default().cases(400), "refetch bounds", |rng| {
        let n = rng.index(6);
        let dims = [Dim::M, Dim::N, Dim::K];
        let prefix: Vec<Loop> = (0..n)
            .map(|_| Loop::new(dims[rng.index(3)], rng.gen_range(1, 64)))
            .collect();
        let product: u64 = prefix.iter().map(|l| l.factor).product();
        for t in Tensor::all() {
            let r = refetches(&prefix, t);
            let d = distinct_tiles(&prefix, t);
            if !(d <= r && r <= product) {
                return Err(format!("{prefix:?} {t:?}: d={d} r={r} p={product}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_refetches_order_invariant_lower_bound() {
    // Reordering loops never drops refetches below the distinct count,
    // and the relevant-dim product is order-invariant.
    check(Config::default().cases(200), "order invariance", |rng| {
        let dims = [Dim::M, Dim::N, Dim::K];
        let mut prefix: Vec<Loop> = (0..4)
            .map(|_| Loop::new(dims[rng.index(3)], rng.gen_range(1, 16)))
            .collect();
        let d0: Vec<u64> = Tensor::all()
            .iter()
            .map(|t| distinct_tiles(&prefix, *t))
            .collect();
        rng.shuffle(&mut prefix);
        for (i, t) in Tensor::all().iter().enumerate() {
            if distinct_tiles(&prefix, *t) != d0[i] {
                return Err("distinct count changed with order".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_baseline_metrics_well_formed() {
    check(Config::default().cases(150), "baseline well-formed", |rng| {
        let gemm = random_gemm(rng);
        let arch = Architecture::default_sm();
        let m = BaselineModel::new(&arch).evaluate(&gemm);
        if !(m.energy_pj.is_finite() && m.energy_pj > 0.0) {
            return Err(format!("{gemm}: energy {}", m.energy_pj));
        }
        if m.gflops > arch.tensor_core.peak_gops() * 1.001 {
            return Err(format!("{gemm}: above peak"));
        }
        if !(0.0..=1.0 + 1e-9).contains(&m.utilization) {
            return Err(format!("{gemm}: util {}", m.utilization));
        }
        Ok(())
    });
}

#[test]
fn prop_tiled_cpu_replay_matches_oracle() {
    // Pure-rust property over the tiling identity the runtime relies
    // on: mapping-shaped tiling + accumulation reproduces the GEMM for
    // arbitrary shapes (no PJRT needed here; integration_runtime.rs
    // covers the PJRT path).
    use www_cim::runtime::matrix::{gemm_ref, MatI32, MatI8};
    check(Config::default().cases(60), "tiled replay", |rng| {
        let m = rng.gen_range(1, 65) as usize;
        let n = rng.gen_range(1, 65) as usize;
        let k = rng.gen_range(1, 129) as usize;
        let x = MatI8::random(m, k, rng);
        let w = MatI8::random(k, n, rng);
        let want = gemm_ref(&x, &w);
        let (tm, tn, tk) = (
            rng.gen_range(1, 65) as usize,
            rng.gen_range(1, 65) as usize,
            rng.gen_range(1, 129) as usize,
        );
        let mut got = MatI32::zeros(m, n);
        for k0 in (0..k).step_by(tk) {
            for n0 in (0..n).step_by(tn) {
                for m0 in (0..m).step_by(tm) {
                    let xt = x.tile_padded(m0, k0, tm, tk);
                    let wt = w.tile_padded(k0, n0, tk, tn);
                    got.accumulate(m0, n0, &gemm_ref(&xt, &wt));
                }
            }
        }
        if got.max_abs_diff(&want) != 0 {
            return Err(format!("{m}x{n}x{k} tiles {tm}/{tn}/{tk} diverged"));
        }
        Ok(())
    });
}
