//! Integration: the coordinator's grid scheduler and reports over the
//! full real dataset and the full system matrix.

use www_cim::arch::{Architecture, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::{Grid, SystemSpec};
use www_cim::coordinator::report::WorkloadReport;
use www_cim::workload::{models, Gemm};

fn full_matrix() -> Vec<SystemSpec> {
    let mut specs = vec![SystemSpec::Baseline];
    for p in CimPrimitive::all() {
        specs.push(SystemSpec::CimAtRf(p.clone()));
        specs.push(SystemSpec::CimAtSmem(p.clone(), SmemConfig::ConfigA));
        specs.push(SystemSpec::CimAtSmem(p, SmemConfig::ConfigB));
    }
    specs
}

#[test]
fn full_grid_over_real_dataset() {
    let grid = Grid::default();
    let workloads: Vec<(String, Vec<Gemm>)> = models::real_dataset()
        .into_iter()
        .map(|w| {
            let g = w.unique_with_counts().into_iter().map(|(g, _)| g).collect();
            (w.name, g)
        })
        .collect();
    let specs = full_matrix();
    let jobs = grid.cross(&workloads, &specs);
    let n_gemms: usize = workloads.iter().map(|(_, g)| g.len()).sum();
    assert_eq!(jobs.len(), n_gemms * specs.len());

    let results = grid.run(&jobs);
    assert_eq!(results.len(), jobs.len());
    for r in &results {
        assert!(r.metrics.energy_pj > 0.0, "{} on {}", r.gemm, r.system);
        assert!(r.metrics.gflops > 0.0);
        assert!((0.0..=1.0001).contains(&r.metrics.utilization));
    }
}

#[test]
fn reports_for_every_workload_and_system() {
    let grid = Grid::default();
    let arch = Architecture::default_sm();
    let workloads: Vec<(String, Vec<Gemm>)> = models::real_dataset()
        .into_iter()
        .map(|w| {
            let g = w.unique_with_counts().into_iter().map(|(g, _)| g).collect();
            (w.name, g)
        })
        .collect();
    let specs = vec![
        SystemSpec::Baseline,
        SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
    ];
    let results = grid.run(&grid.cross(&workloads, &specs));
    let cim_label = specs[1].label(&arch);
    for (name, gemms) in &workloads {
        let rep = WorkloadReport::compare(name, &results, &cim_label, "Tensor-core");
        assert_eq!(rep.n_gemms, gemms.len());
        assert!(rep.tops_per_watt_change.mean > 0.0);
    }
}

#[test]
fn determinism_across_thread_counts() {
    let workloads = vec![(
        "synthetic".to_string(),
        www_cim::workload::synthetic::dataset(5, 60),
    )];
    let specs = vec![SystemSpec::CimAtRf(CimPrimitive::analog_6t())];
    let mut grid = Grid::default();
    let jobs = grid.cross(&workloads, &specs);
    grid.threads = 1;
    let a = grid.run(&jobs);
    grid.threads = 8;
    let b = grid.run(&jobs);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.metrics, y.metrics, "{}", x.gemm);
    }
}
