//! Golden tests: every id in `experiments::REGISTRY` runs in quick mode,
//! mirrors a CSV with the expected header and a non-zero row count, and
//! key cross-row invariants hold (e.g. multi-SM GFLOPS never regress as
//! SMs grow, and double while compute-bound).

use www_cim::experiments::{self, Ctx};
use www_cim::util::csv;

fn quick_ctx(tag: &str) -> Ctx {
    let mut ctx = Ctx::quick();
    ctx.out_dir = std::env::temp_dir().join(format!("www_cim_golden_{tag}"));
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
    ctx
}

/// Expected CSV header per experiment id (the mirror's schema is part
/// of the artifact contract — plot scripts depend on it).
const GOLDEN_HEADERS: &[(&str, &str)] = &[
    ("fig2", "workload,m,n,k,ops,algorithmic_reuse,count"),
    ("fig7", "workload,m,n,k,d_topsw,d_gflops,d_util"),
    ("table2", "runs,ours_s,heuristic_s"),
    ("fig9", "primitive,m,n,k,tops_w,gflops,utilization"),
    ("fig10", "panel,x,varied,m,n,k,tops_w,gflops,utilization"),
    ("fig11", "workload,m,n,k,system,tops_w,gflops,utilization"),
    (
        "fig12",
        "panel,workload,d_topsw_mean,d_topsw_std,d_gflops_mean,d_gflops_std,d_util_mean,\
         d_util_std,d_topsw_max,d_gflops_max",
    ),
    (
        "fig13",
        "level,x,system,dram_fj,smem_fj,rf_pebuf_fj,mac_fj,total_fj_per_mac,gops",
    ),
    ("table6", "workload,m,n,k,macs,algorithmic_reuse"),
    ("roofline", "primitive,level,peak_gops,ridge_smem,ridge_dram"),
    ("ablation-threshold", "threshold,geo_topsw,geo_gflops,mean_util"),
    ("ablation-order", "order,geo_topsw,geo_gflops"),
    (
        "ablation-duplication",
        "m,n,k,dup,gflops_off,gflops_on,topsw_off,topsw_on",
    ),
    (
        "ablation-interconnect",
        "system,hop_pj,topsw_base,topsw_noc,overhead_pct",
    ),
    ("scaling", "sms,cim_gflops,cim_bound,tc_gflops,tc_bound"),
    (
        "hybrid",
        "workload,policy,cim_layers,total_layers,hybrid_topsw,cim_topsw,tc_topsw,hybrid_gflops",
    ),
    (
        "optimality",
        "m,n,k,candidates,opt_pj,ours_pj,gap,opt_cycles,ours_cycles",
    ),
    ("zoo", "workload,layers,best_system,topsw,vs_tcore"),
    (
        "serving",
        "pool,p50_cycles,p99_cycles,req_per_s,cim_util,tc_util,energy_mj",
    ),
];

#[test]
fn golden_headers_cover_every_experiment_id() {
    let golden: Vec<&str> = GOLDEN_HEADERS.iter().map(|(id, _)| *id).collect();
    let ids = experiments::ids();
    for id in &ids {
        assert!(golden.contains(id), "no golden header for {id}");
    }
    assert_eq!(golden.len(), ids.len(), "stale golden entry");
}

#[test]
fn every_experiment_mirrors_its_golden_csv() {
    let ctx = quick_ctx("all");
    for (id, header) in GOLDEN_HEADERS {
        experiments::run(id, &ctx).unwrap_or_else(|e| panic!("{id} failed: {e:#}"));
        let path = ctx.out_dir.join(format!("{id}.csv"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{id}: missing csv mirror: {e}"));
        let mut lines = text.lines();
        assert_eq!(
            lines.next().unwrap_or(""),
            header.replace(char::is_whitespace, ""),
            "{id}: csv header drifted"
        );
        let rows = lines.filter(|l| !l.trim().is_empty()).count();
        assert!(rows > 0, "{id}: csv has no data rows");
    }
}

#[test]
fn scaling_gflops_monotone_until_memory_bound() {
    let ctx = quick_ctx("scaling");
    experiments::run("scaling", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("scaling.csv")).unwrap();
    let rows = csv::parse(&text);
    assert_eq!(rows[0], vec!["sms", "cim_gflops", "cim_bound", "tc_gflops", "tc_bound"]);
    let series: Vec<(u64, f64, String)> = rows[1..]
        .iter()
        .map(|r| {
            (
                r[0].parse().unwrap(),
                r[1].parse().unwrap(),
                r[2].clone(),
            )
        })
        .collect();
    assert!(series.len() >= 5, "scaling sweep too short");
    for pair in series.windows(2) {
        let (sms_a, gf_a, _) = &pair[0];
        let (sms_b, gf_b, bound_b) = &pair[1];
        assert_eq!(sms_b / sms_a, 2, "SM axis doubles");
        // GFLOPS never regress as SMs grow...
        assert!(
            gf_b >= gf_a,
            "CiM GFLOPS regressed: {gf_a} @ {sms_a} SMs -> {gf_b} @ {sms_b} SMs"
        );
        // ...and while still compute-bound, doubling SMs ~doubles them.
        if bound_b == "compute" {
            assert!(
                *gf_b >= 1.8 * *gf_a,
                "compute-bound step must ~double: {gf_a} -> {gf_b}"
            );
        }
    }
    // The sweep must show saturation setting in: either the memory wall
    // is reached outright, or the last doubling is clearly sublinear.
    let hit_wall = series.iter().any(|(_, _, b)| b == "memory");
    let last_ratio = series[series.len() - 1].1 / series[series.len() - 2].1;
    assert!(
        hit_wall || last_ratio < 1.8,
        "no saturation within the swept SM range (last ratio {last_ratio})"
    );
}

#[test]
fn fig9_csv_covers_all_primitives_with_synthetic_rows() {
    let ctx = quick_ctx("fig9");
    experiments::run("fig9", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("fig9.csv")).unwrap();
    let rows = csv::parse(&text);
    // 4 primitives x quick synthetic dataset size.
    assert_eq!(rows.len() - 1, 4 * ctx.synthetic_size());
    for prim in ["Analog-6T", "Analog-8T", "Digital-6T", "Digital-8T"] {
        assert!(
            rows[1..].iter().any(|r| r[0] == prim),
            "fig9.csv missing {prim}"
        );
    }
}

#[test]
fn fig13_baseline_rows_identical_across_levels() {
    // The tensor-core column is level-independent; the memoized engine
    // must reproduce identical baseline rows under RF and SMEM.
    let ctx = quick_ctx("fig13");
    experiments::run("fig13", &ctx).unwrap();
    let text = std::fs::read_to_string(ctx.out_dir.join("fig13.csv")).unwrap();
    let rows = csv::parse(&text);
    let tcore = |level: &str| -> Vec<Vec<String>> {
        rows[1..]
            .iter()
            .filter(|r| r[0] == level && r[2] == "Tcore")
            .map(|r| r[1..].to_vec())
            .collect()
    };
    let rf = tcore("RF");
    let smem = tcore("SMEM");
    assert!(!rf.is_empty());
    assert_eq!(rf, smem, "baseline rows must match bit-for-bit across levels");
}

#[test]
fn experiment_all_shares_one_cache() {
    // Running several grid experiments under one Ctx accumulates cache
    // hits across experiments (fig11 and fig12 share two systems).
    let ctx = quick_ctx("shared_cache");
    experiments::run("fig11", &ctx).unwrap();
    let hits_after_fig11 = ctx.cache.hits();
    experiments::run("fig12", &ctx).unwrap();
    assert!(
        ctx.cache.hits() > hits_after_fig11,
        "fig12 must reuse fig11's design points"
    );
}
