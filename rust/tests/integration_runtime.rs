//! Integration: the PJRT runtime against the built artifacts.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a loud message) when the artifacts directory is absent so
//! `cargo test` stays runnable on a fresh checkout.

use www_cim::arch::{Architecture, CimSystem, MemLevel};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::validate::validate_mappings;
use www_cim::mapping::PriorityMapper;
use www_cim::runtime::matrix::{gemm_ref, MatI8};
use www_cim::runtime::{default_artifacts_dir, Engine, TiledExecutor};
use www_cim::util::rng::Rng;
use www_cim::workload::Gemm;

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(Engine::load(&dir).expect("engine loads"))
}

#[test]
fn artifact_gemm_matches_rust_oracle() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(1);
    for (name, (m, n, k)) in engine.manifest().gemm_kernels() {
        let x = MatI8::random(m, k, &mut rng);
        let w = MatI8::random(k, n, &mut rng);
        let got = engine.execute_i8(name, &[&x, &w]).unwrap().remove(0);
        assert_eq!(got.max_abs_diff(&gemm_ref(&x, &w)), 0, "{name}");
    }
}

#[test]
fn padded_execution_exact_for_any_subtile() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(2);
    for (m, n, k) in [(1usize, 1usize, 1usize), (17, 5, 33), (128, 64, 512), (100, 48, 300)] {
        let x = MatI8::random(m, k, &mut rng);
        let w = MatI8::random(k, n, &mut rng);
        let got = engine.gemm_padded("gemm_128x64x512", &x, &w).unwrap();
        assert_eq!(got.max_abs_diff(&gemm_ref(&x, &w)), 0, "{m}x{n}x{k}");
    }
}

#[test]
fn tiled_replay_exact_for_every_primitive() {
    let Some(engine) = engine() else { return };
    let arch = Architecture::default_sm();
    let mut rng = Rng::new(3);
    let g = Gemm::new(96, 48, 320);
    let x = MatI8::random(96, 320, &mut rng);
    let w = MatI8::random(320, 48, &mut rng);
    for p in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, p.clone(), MemLevel::RegisterFile);
        let mapping = PriorityMapper::new(&sys).map(&g);
        let run = TiledExecutor::new(&engine).run(&mapping, &x, &w).unwrap();
        assert_eq!(run.diff_vs_oracle, 0, "{}", p.name);
        assert!(run.kernel_calls >= 1);
    }
}

#[test]
fn validation_pipeline_reports_exact() {
    let Some(engine) = engine() else { return };
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let gemms = [Gemm::new(64, 32, 256), Gemm::new(16, 64, 64), Gemm::new(1, 64, 256)];
    let report = validate_mappings(&engine, &sys, &gemms, 99).unwrap();
    assert_eq!(report.cases.len(), 3);
    assert!(report.all_exact());
}

#[test]
fn composed_graphs_match_oracles() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(4);
    // mlp_16x64x256: gemm -> requant(>>8) -> gemm
    let x = MatI8::random(16, 64, &mut rng);
    let w1 = MatI8::random(64, 256, &mut rng);
    let w2 = MatI8::random(256, 64, &mut rng);
    let got = engine.execute_i8("mlp_16x64x256", &[&x, &w1, &w2]).unwrap().remove(0);
    let h = www_cim::runtime::matrix::requant(&gemm_ref(&x, &w1), 8);
    let want = gemm_ref(&h, &w2);
    assert_eq!(got.max_abs_diff(&want), 0, "mlp graph");
}

#[test]
fn engine_caches_compiled_executables() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(5);
    let x = MatI8::random(16, 64, &mut rng);
    let w = MatI8::random(64, 64, &mut rng);
    assert_eq!(engine.cached(), 0);
    engine.execute_i8("gemm_16x64x64", &[&x, &w]).unwrap();
    assert_eq!(engine.cached(), 1);
    engine.execute_i8("gemm_16x64x64", &[&x, &w]).unwrap();
    assert_eq!(engine.cached(), 1, "recompilation would be a perf bug");
}

#[test]
fn shape_mismatch_rejected() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(6);
    let x = MatI8::random(8, 8, &mut rng);
    let w = MatI8::random(8, 8, &mut rng);
    assert!(engine.execute_i8("gemm_16x64x64", &[&x, &w]).is_err());
    assert!(engine.execute_i8("nonexistent", &[&x, &w]).is_err());
}
