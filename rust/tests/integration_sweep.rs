//! Integration + property tests for the design-space sweep engine:
//! memoization soundness, thread-count independence, grid expansion,
//! cache accounting, and the CSV/JSON sinks.

use std::sync::Arc;

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::SystemSpec;
use www_cim::cost::{BaselineModel, CostModel};
use www_cim::mapping::PriorityMapper;
use www_cim::sweep::{
    output, spec, EvalCache, MapperChoice, SweepEngine, SweepJob, SweepSpec,
};
use www_cim::util::check::{check, Config};
use www_cim::util::pool;
use www_cim::util::rng::Rng;
use www_cim::workload::{synthetic, Gemm};

fn random_gemm(rng: &mut Rng) -> Gemm {
    let dim = |rng: &mut Rng| -> u64 {
        match rng.gen_range(0, 3) {
            0 => 1 << rng.gen_range(0, 14),
            1 => rng.gen_range(1, 8193),
            _ => rng.gen_range(1, 64),
        }
    };
    Gemm::new(dim(rng), dim(rng), dim(rng))
}

fn random_spec(rng: &mut Rng) -> SystemSpec {
    let prim = CimPrimitive::all()[rng.index(4)].clone();
    match rng.gen_range(0, 4) {
        0 => SystemSpec::Baseline,
        1 => SystemSpec::CimAtRf(prim),
        2 => SystemSpec::CimAtSmem(prim, SmemConfig::ConfigA),
        _ => SystemSpec::CimAtSmem(prim, SmemConfig::ConfigB),
    }
}

fn job(gemm: Gemm, spec: SystemSpec) -> SweepJob {
    SweepJob {
        workload: "prop".to_string(),
        gemm,
        spec,
        sms: 1,
        mapper: MapperChoice::Priority,
    }
}

/// ISSUE property 1: a memoized re-evaluation is bit-identical to a
/// fresh evaluation — for random (gemm, system) points, the cached
/// result equals both a cold engine's result and the direct
/// mapper+cost-model computation.
#[test]
fn prop_memoized_reeval_bit_identical() {
    let arch = Architecture::default_sm();
    let shared = SweepEngine::new(arch.clone());
    check(Config::default().cases(60), "memoized == fresh", |rng| {
        let gemm = random_gemm(rng);
        let spec = random_spec(rng);
        let j = job(gemm, spec.clone());
        let first = shared.evaluate(&j).metrics; // may be a miss
        let cached = shared.evaluate(&j).metrics; // always a hit
        let cold = SweepEngine::new(arch.clone()).evaluate(&j).metrics;
        if first != cached {
            return Err(format!("{gemm}: cached result diverged from first evaluation"));
        }
        if first != cold {
            return Err(format!("{gemm}: cached result diverged from a cold engine"));
        }
        let direct = match spec.system(&arch) {
            None => BaselineModel::new(&arch).evaluate(&gemm),
            Some(sys) => {
                CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm))
            }
        };
        if first != direct {
            return Err(format!("{gemm}: engine result diverged from direct evaluation"));
        }
        Ok(())
    });
}

/// ISSUE property 2: sweep results are independent of the worker-thread
/// count (the `WWW_THREADS=1` vs N contract, pinned via the explicit
/// thread-count setter that `WWW_THREADS` feeds).
#[test]
fn prop_results_independent_of_thread_count() {
    let arch = Architecture::default_sm();
    check(Config::default().cases(8), "thread independence", |rng| {
        let n = 10 + rng.index(20);
        let gemms: Vec<Gemm> = (0..n).map(|_| random_gemm(rng)).collect();
        let sweep = SweepSpec::new("prop")
            .workload("w", gemms)
            .systems(vec![random_spec(rng), random_spec(rng), random_spec(rng)]);
        let threads_n = 2 + rng.index(7);
        let serial = SweepEngine::new(arch.clone()).threads(1).run_spec(&sweep);
        let parallel = SweepEngine::new(arch.clone())
            .threads(threads_n)
            .run_spec(&sweep);
        if serial.n_points() != parallel.n_points() {
            return Err("point counts differ".to_string());
        }
        for (a, b) in serial.results.iter().zip(&parallel.results) {
            if a.metrics != b.metrics || a.system != b.system || a.gemm != b.gemm {
                return Err(format!(
                    "{} on {}: threads=1 vs threads={threads_n} diverged",
                    a.gemm, a.system
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn duplicate_points_scored_once() {
    let engine = SweepEngine::new(Architecture::default_sm()).threads(1);
    let base = vec![
        job(Gemm::new(64, 64, 64), SystemSpec::CimAtRf(CimPrimitive::digital_6t())),
        job(Gemm::new(128, 128, 128), SystemSpec::Baseline),
    ];
    // Each unique point repeated 3x within one job list.
    let mut jobs = Vec::new();
    for _ in 0..3 {
        jobs.extend(base.clone());
    }
    let results = engine.run(&jobs);
    assert_eq!(results.len(), 6);
    assert_eq!(engine.cache().misses(), 2, "unique points evaluated once");
    assert_eq!(engine.cache().hits(), 4, "duplicates served from the cache");
    for chunk in results.chunks(2).skip(1) {
        assert_eq!(chunk[0].metrics, results[0].metrics);
        assert_eq!(chunk[1].metrics, results[1].metrics);
    }
}

#[test]
fn shared_cache_dedups_across_engines() {
    let cache = Arc::new(EvalCache::new());
    let arch = Architecture::default_sm();
    let j = job(
        Gemm::new(256, 256, 256),
        SystemSpec::CimAtSmem(CimPrimitive::digital_6t(), SmemConfig::ConfigB),
    );
    let a = SweepEngine::with_cache(arch.clone(), Arc::clone(&cache)).evaluate(&j);
    let b = SweepEngine::with_cache(arch, Arc::clone(&cache)).evaluate(&j);
    assert_eq!(a.metrics, b.metrics);
    assert_eq!(cache.misses(), 1);
    assert_eq!(cache.hits(), 1);
}

#[test]
fn hybrid_router_shares_engine_cache_keys() {
    use www_cim::coordinator::hybrid::{HybridRouter, RoutePolicy};
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let cache = Arc::new(EvalCache::new());
    let g = Gemm::new(512, 1024, 1024);

    // Engine scores the point first...
    let engine = SweepEngine::with_cache(arch.clone(), Arc::clone(&cache));
    engine.evaluate(&job(g, SystemSpec::CimAtRf(CimPrimitive::digital_6t())));
    engine.evaluate(&job(g, SystemSpec::Baseline));
    let misses_before = cache.misses();

    // ...and the router's placement replays it from the cache.
    let router = HybridRouter::with_cache(&sys, &arch, RoutePolicy::MinEnergy, Arc::clone(&cache));
    let placement = router.place(&g);
    assert_eq!(cache.misses(), misses_before, "router must not re-evaluate");
    assert!(cache.hits() >= 2);
    assert!(placement.metrics.energy_pj > 0.0);
}

#[test]
fn five_hundred_point_default_grid_runs() {
    let sweep = spec::default_grid(7).expect("default grid builds");
    assert!(sweep.n_points() >= 500, "{} points", sweep.n_points());
    let engine = SweepEngine::new(Architecture::default_sm());
    let run = engine.run_spec(&sweep);
    assert_eq!(run.n_points(), sweep.n_points());
    for r in &run.results {
        assert!(r.metrics.energy_pj > 0.0, "{} on {}", r.gemm, r.system);
        assert!(r.metrics.gflops > 0.0);
        assert!(r.metrics.tops_per_watt.is_finite());
    }
    // The default grid's baseline column duplicates GEMMs shared across
    // workloads, so some hits are expected even on a cold cache.
    assert_eq!(run.cache_hits + run.cache_misses, run.n_points() as u64);
}

#[test]
fn warm_rerun_of_a_big_grid_is_all_hits() {
    let sweep = SweepSpec::new("warm")
        .workload("synthetic", synthetic::dataset(11, 40))
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_6t(), SmemConfig::ConfigB),
        ]);
    let engine = SweepEngine::new(Architecture::default_sm());
    let cold = engine.run_spec(&sweep);
    let warm = engine.run_spec(&sweep);
    assert_eq!(warm.cache_misses, 0, "warm run must be fully memoized");
    assert_eq!(warm.cache_hits as usize, sweep.n_points());
    for (a, b) in cold.results.iter().zip(&warm.results) {
        assert_eq!(a.metrics, b.metrics);
    }
}

#[test]
fn sweep_csv_and_json_sinks() {
    let sweep = SweepSpec::new("sinks")
        .workload("w", vec![Gemm::new(64, 64, 64), Gemm::new(1, 256, 512)])
        .systems(vec![
            SystemSpec::Baseline,
            SystemSpec::CimAtRf(CimPrimitive::digital_8t()),
        ]);
    let run = SweepEngine::new(Architecture::default_sm()).run_spec(&sweep);

    let csv = output::results_csv(&run.results).unwrap();
    assert_eq!(csv.n_rows(), run.n_points());
    let text = csv.encode();
    assert_eq!(
        text.lines().next().unwrap(),
        "workload,m,n,k,system,sms,tops_w,gflops,utilization,energy_pj,total_cycles,bound"
    );

    let dir = std::env::temp_dir().join("www_cim_sweep_sink_test");
    let _ = std::fs::remove_dir_all(&dir);
    let json_path = dir.join("nested/sweep.json");
    output::write_json_summary(&run, &json_path).unwrap();
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"sweep\": \"sinks\""));
    assert!(json.contains("\"points\": 4"));
    assert!(json.contains("Tensor-core"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn grid_axis_parsers_power_the_cli() {
    // The flag combinations `repro sweep` documents.
    let workloads = spec::parse_workloads("bert,synthetic:10", 7).unwrap();
    assert_eq!(workloads.len(), 2);
    assert_eq!(workloads[1].1.len(), 10);
    let systems = spec::parse_systems("baseline,d1", "rf,smem-b").unwrap();
    assert_eq!(systems.len(), 3);
    let sms = spec::parse_sm_counts("1,8,64").unwrap();
    assert_eq!(sms, vec![1, 8, 64]);
    let sweep = SweepSpec::new("cli")
        .workloads(workloads)
        .systems(systems)
        .sm_counts(sms);
    assert_eq!(sweep.n_points(), 15 * 3 * 3);
}

#[test]
fn mapper_axis_changes_results_but_stays_deterministic() {
    let arch = Architecture::default_sm();
    let engine = SweepEngine::new(arch);
    let g = Gemm::new(8192, 16, 256); // duplication-friendly shape
    let spec = SystemSpec::CimAtSmem(CimPrimitive::digital_6t(), SmemConfig::ConfigB);
    let mk = |mapper| SweepJob {
        workload: "w".to_string(),
        gemm: g,
        spec: spec.clone(),
        sms: 1,
        mapper,
    };
    let plain = engine.evaluate(&mk(MapperChoice::Priority)).metrics;
    let dup = engine.evaluate(&mk(MapperChoice::duplication())).metrics;
    // Distinct mapper choices are distinct cache points (no false hits).
    assert_eq!(engine.cache().misses(), 2);
    assert!(plain.energy_pj > 0.0 && dup.energy_pj > 0.0);
    let h = MapperChoice::Heuristic { budget: 40, seed: 3 };
    let h1 = engine.evaluate(&mk(h)).metrics;
    let h2 = SweepEngine::new(Architecture::default_sm())
        .evaluate(&mk(h))
        .metrics;
    assert_eq!(h1, h2, "seeded heuristic sweeps are deterministic");
}

#[test]
fn default_threads_env_contract() {
    // WWW_THREADS drives pool::default_threads(), which both the CLI
    // and Ctx feed into the engine; the value must be >= 1.
    assert!(pool::default_threads() >= 1);
}
