//! Integration tests for the `repro serve` daemon: concurrent
//! determinism (the tentpole invariant — an `eval` response is
//! byte-identical to the CSV `repro run` writes for the same
//! scenario), single-flight coalescing accounting, warm-cache
//! zero-miss passes, explicit busy responses under overload, and
//! drain/flush semantics. Everything runs in-process against the
//! library API on `127.0.0.1:0`; the CI e2e step covers the real
//! binary + real SIGTERM.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use www_cim::scenario::{exec, Scenario};
use www_cim::serve::handler::ServerState;
use www_cim::serve::{Client, ServeOptions, Server};
use www_cim::sweep::{persist, EvalCache};
use www_cim::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("www_cim_serve_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small sweep scenario; `extra` GEMMs vary the grid so tests can
/// use distinct point sets.
fn scenario(name: &str, synthetic: usize) -> Scenario {
    Scenario::builder(name)
        .workloads(&format!("synthetic:{synthetic}"))
        .prims("baseline,d1")
        .levels("rf")
        .seed(7)
        .threads(2)
        .build()
        .expect("valid scenario")
}

/// Bind on a free port and run the daemon on a background thread.
fn start(opts: ServeOptions) -> (String, Arc<ServerState>, JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind(ServeOptions { addr: "127.0.0.1:0".to_string(), quiet: true, ..opts })
        .expect("bind on a free port");
    let addr = server.local_addr().expect("bound address").to_string();
    let state = server.state();
    let handle = std::thread::spawn(move || server.run());
    (addr, state, handle)
}

/// The CSV `repro run` produces for `sc`, via the same library entry
/// the daemon uses *plus* the file-writing `execute` path, asserted
/// identical to each other first.
fn reference_csv(sc: &Scenario, tag: &str) -> String {
    let dir = tmp_dir(tag);
    let mut on_disk = sc.clone();
    on_disk.output.dir = dir.clone();
    exec::execute(&on_disk, None).expect("repro run path");
    let path = dir.join(format!("{}.csv", sc.base_name()));
    let written = std::fs::read_to_string(&path).expect("run CSV written");
    let evaled = exec::eval_sweep(sc, Arc::new(EvalCache::new())).expect("eval_sweep").csv;
    assert_eq!(written, evaled, "eval_sweep must mirror execute()'s CSV");
    let _ = std::fs::remove_dir_all(&dir);
    written
}

#[test]
fn concurrent_clients_all_get_byte_identical_responses() {
    let sc_a = scenario("conc-a", 3); // 6 points
    let sc_b = scenario("conc-b", 4); // 8 points
    let expect_a = reference_csv(&sc_a, "conc_a");
    let expect_b = reference_csv(&sc_b, "conc_b");

    let (addr, _state, handle) = start(ServeOptions {
        workers: 4,
        queue_depth: 16,
        ..ServeOptions::default()
    });

    // 6 client threads, half per scenario, two evals each, all racing
    // on a cold cache.
    let threads: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            let (sc, expect) = if i % 2 == 0 {
                (sc_a.clone(), expect_a.clone())
            } else {
                (sc_b.clone(), expect_b.clone())
            };
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                for _ in 0..2 {
                    let r = client.eval(&sc).expect("eval");
                    assert_eq!(r.csv, expect, "response must be byte-identical");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread");
    }

    // Single-flight proof: 6 threads x 2 evals raced, yet every unique
    // point was computed exactly once — global misses equal the unique
    // point count and the daemon's stats op exposes the coalesced
    // counter that accounts for the duplicate in-flight probes.
    let mut client = Client::connect(&addr).expect("connect");
    let stats = client.stats().expect("stats");
    let cache = stats.get("cache").expect("cache stats");
    let n = |k: &str| cache.get(k).and_then(Json::as_u64).expect(k);
    assert_eq!(n("misses"), 6 + 8, "every unique point computed exactly once");
    assert_eq!(n("entries"), 6 + 8);
    // 3 threads x 2 evals x points per scenario served in total.
    assert_eq!(n("hits") + n("misses"), 6 * (6 + 8));
    assert!(n("coalesced") <= n("hits"), "coalesced probes are a subset of hits");
    assert_eq!(n("mapper_calls"), 3 + 4, "one mapper call per unique d1 point");

    client.shutdown().expect("shutdown");
    handle.join().expect("daemon thread").expect("clean drain");
}

#[test]
fn warm_second_pass_reports_zero_misses_and_zero_mapper_calls() {
    let sc = scenario("warm", 3);
    let (addr, _state, handle) = start(ServeOptions {
        workers: 2,
        queue_depth: 4,
        ..ServeOptions::default()
    });
    let mut client = Client::connect(&addr).expect("connect");

    let cold = client.eval(&sc).expect("cold eval");
    let warm = client.eval(&sc).expect("warm eval");
    assert_eq!(cold.csv, warm.csv, "cache warmth must be payload-invisible");

    let stat = |r: &www_cim::serve::EvalResponse, k: &str| {
        r.stats.get(k).and_then(Json::as_u64).expect("stat")
    };
    assert_eq!(stat(&cold, "misses"), 6);
    assert_eq!(stat(&warm, "misses"), 0, "warm pass misses");
    assert_eq!(stat(&warm, "mapper_calls"), 0, "warm pass mapper calls");
    assert_eq!(stat(&warm, "hits"), 6);

    client.shutdown().expect("shutdown");
    handle.join().expect("daemon thread").expect("clean drain");
}

#[test]
fn overload_gets_an_explicit_busy_response() {
    // One worker, queue depth one: the worker is pinned to the first
    // keep-alive connection, the second parks in the queue, so the
    // third must be rejected with the busy line.
    let (addr, state, handle) = start(ServeOptions {
        workers: 1,
        queue_depth: 1,
        ..ServeOptions::default()
    });

    let mut held = Client::connect(&addr).expect("c1");
    held.ping().expect("c1 round-trip pins the only worker");

    let _queued = TcpStream::connect(&addr).expect("c2");
    let deadline = Instant::now() + Duration::from_secs(5);
    while state.metrics.snapshot().get("connections").and_then(Json::as_u64) != Some(2) {
        assert!(Instant::now() < deadline, "c2 never reached the queue");
        std::thread::sleep(Duration::from_millis(10));
    }

    let c3 = TcpStream::connect(&addr).expect("c3");
    let mut line = String::new();
    BufReader::new(c3).read_line(&mut line).expect("busy line");
    let v = Json::parse(line.trim()).expect("busy response is JSON");
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(v.get("busy").and_then(Json::as_bool), Some(true));
    assert_eq!(state.metrics.busy_count(), 1);

    held.shutdown().expect("drain");
    handle.join().expect("daemon thread").expect("clean drain");
}

#[test]
fn shutdown_drains_and_flushes_the_cache_under_the_lock() {
    let dir = tmp_dir("flush");
    let cache_path = dir.join("serve-cache.bin");
    let sc = scenario("drain", 3);
    let (addr, _state, handle) = start(ServeOptions {
        workers: 2,
        queue_depth: 4,
        cache_path: Some(cache_path.clone()),
        ..ServeOptions::default()
    });
    let mut client = Client::connect(&addr).expect("connect");
    client.eval(&sc).expect("eval");

    // Explicit flush persists mid-life...
    let flushed = client.flush().expect("flush");
    assert_eq!(flushed.get("persisted").and_then(Json::as_bool), Some(true));
    assert_eq!(flushed.get("entries").and_then(Json::as_u64), Some(6));

    // ...and the drain flushes again on the way out.
    client.shutdown().expect("shutdown");
    handle.join().expect("daemon thread").expect("clean drain");
    let reloaded = EvalCache::new();
    persist::load_into(&reloaded, &cache_path).expect("flushed file loads");
    assert_eq!(reloaded.len(), 6, "drained daemon persisted its entries");
    assert!(!cache_path.with_extension("bin.lock").exists(), "save lock released");

    // A daemon started on the flushed file is warm from request one.
    let (addr2, _state2, handle2) = start(ServeOptions {
        workers: 2,
        queue_depth: 4,
        cache_path: Some(cache_path),
        ..ServeOptions::default()
    });
    let mut client2 = Client::connect(&addr2).expect("connect");
    let warm = client2.eval(&sc).expect("warm eval");
    assert_eq!(
        warm.stats.get("misses").and_then(Json::as_u64),
        Some(0),
        "preloaded cache serves with zero misses"
    );
    client2.shutdown().expect("shutdown");
    handle2.join().expect("daemon thread").expect("clean drain");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_lines_error_without_poisoning_the_connection() {
    let (addr, _state, handle) = start(ServeOptions {
        workers: 1,
        queue_depth: 2,
        ..ServeOptions::default()
    });
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut write = |s: &str| {
        let mut w = &stream;
        w.write_all(s.as_bytes()).expect("write");
        w.write_all(b"\n").expect("write");
    };
    let mut read = || {
        let mut line = String::new();
        reader.read_line(&mut line).expect("read");
        Json::parse(line.trim()).expect("response parses")
    };

    write("this is not json");
    let v = read();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert!(v.get("error").is_some());

    write("{\"op\":\"frobnicate\"}");
    let v = read();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

    // The same connection still serves real requests afterwards.
    write("{\"op\":\"ping\"}");
    let v = read();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("done").and_then(Json::as_bool), Some(true));

    write("{\"op\":\"shutdown\"}");
    let _ = read();
    handle.join().expect("daemon thread").expect("clean drain");
}

#[test]
fn signal_watching_server_drains_on_the_termination_flag() {
    // The global flag is sticky, so exactly one in-process test may
    // exercise the signal path; real SIGTERM delivery to the binary is
    // covered by the CI e2e step.
    let (addr, state, handle) = start(ServeOptions {
        workers: 1,
        queue_depth: 2,
        watch_signals: true,
        ..ServeOptions::default()
    });
    let mut client = Client::connect(&addr).expect("connect");
    client.eval(&scenario("sig", 2)).expect("in-flight work");
    www_cim::serve::drain::request_termination();
    handle.join().expect("daemon thread").expect("clean drain after signal");
    assert!(state.draining.load(Ordering::Relaxed), "drain flag latched");
}
