//! End-to-end tests of the scenario surface (ISSUE 4) over the real
//! `repro` binary: the flag-emitted scenario reproduces `repro sweep`
//! byte-for-byte, `repro orchestrate --procs 2` matches a
//! single-process `repro run` of the same scenario, and
//! `repro run <id>` matches `repro experiment <id>`.

use std::path::{Path, PathBuf};
use std::process::Command;

use www_cim::scenario::Scenario;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("www_cim_scenario_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run `repro` with `args`, failing the test (with full output) on a
/// non-zero exit. Returns stdout.
fn run_ok(args: &[&str]) -> String {
    let out = repro()
        .args(args)
        .output()
        .unwrap_or_else(|e| panic!("spawning repro {args:?}: {e}"));
    assert!(
        out.status.success(),
        "repro {args:?} failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

const GRID: &[&str] = &[
    "--workloads",
    "synthetic:8",
    "--prims",
    "baseline,d1",
    "--levels",
    "rf,smem-b",
    "--seed",
    "7",
];

#[test]
fn flag_emitted_scenario_reproduces_repro_sweep_byte_for_byte() {
    let dir_flags = tmp_dir("emit_flags");
    let dir_sc = tmp_dir("emit_sc");
    let sc_file = tmp_dir("emit_file").join("sweep.scenario.json");

    // Classic flag-driven sweep.
    let mut args: Vec<&str> = vec!["sweep"];
    args.extend(GRID);
    let dir_flags_s = dir_flags.to_str().unwrap();
    args.extend(["--out", dir_flags_s]);
    run_ok(&args);

    // The same flags, but emitting the scenario instead of running...
    let mut args: Vec<&str> = vec!["sweep"];
    args.extend(GRID);
    let dir_sc_s = dir_sc.to_str().unwrap();
    let sc_file_s = sc_file.to_str().unwrap();
    // --emit-scenario is an optional-value flag: the path must ride in
    // the `=` form (a bare flag would print to stdout instead).
    let emit = format!("--emit-scenario={sc_file_s}");
    args.extend(["--out", dir_sc_s, &emit]);
    run_ok(&args);
    assert!(
        !dir_sc.join("sweep.csv").exists(),
        "--emit-scenario must not run the sweep"
    );

    // ...then executing the emitted file.
    let sc = Scenario::from_json_file(&sc_file).expect("emitted scenario loads");
    assert_eq!(sc.seed, 7);
    run_ok(&["run", sc_file_s]);

    let a = read(&dir_flags.join("sweep.csv"));
    let b = read(&dir_sc.join("sweep.csv"));
    assert_eq!(a, b, "flag-emitted scenario must reproduce sweep.csv byte-for-byte");
    for d in [dir_flags, dir_sc, sc_file.parent().unwrap().to_path_buf()] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

#[test]
fn orchestrate_two_procs_matches_single_process_run_byte_for_byte() {
    let dir_single = tmp_dir("orch_single");
    let dir_multi = tmp_dir("orch_multi");
    let sc_dir = tmp_dir("orch_file");
    let sc_file = sc_dir.join("quick.scenario.json");

    Scenario::builder("quick")
        .workloads("synthetic:9")
        .prims("baseline,d1")
        .levels("rf,smem-b")
        .sms("1,2")
        .seed(7)
        .shards(2)
        .build()
        .expect("scenario builds")
        .write(&sc_file)
        .expect("scenario writes");
    let sc_file_s = sc_file.to_str().unwrap();

    run_ok(&["run", sc_file_s, "--out", dir_single.to_str().unwrap()]);
    let stdout = run_ok(&[
        "orchestrate",
        sc_file_s,
        "--procs",
        "2",
        "--out",
        dir_multi.to_str().unwrap(),
    ]);
    assert!(
        stdout.contains("[shard 0/2]") && stdout.contains("[shard 1/2]"),
        "orchestrate must run 2 shard subprocesses:\n{stdout}"
    );

    let single = read(&dir_single.join("quick.csv"));
    let multi = read(&dir_multi.join("quick.csv"));
    assert_eq!(
        single, multi,
        "orchestrated merge must be byte-identical to the single-process run"
    );
    // The orchestrator leaves the per-shard summaries and the canonical
    // scenario file behind for inspection.
    assert!(dir_multi.join("quick-shard0of2.json").exists());
    assert!(dir_multi.join("quick-shard1of2.json").exists());
    assert!(dir_multi.join("quick.scenario.json").exists());
    for d in [dir_single, dir_multi, sc_dir] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

#[test]
fn orchestrate_with_more_procs_than_grid_points_still_merges() {
    // 2 grid points under 5 procs: shards 2..4 run zero jobs. Their
    // summaries must still be written, validated and merged, and the
    // merged CSV must match the single-process run byte-for-byte.
    let dir_single = tmp_dir("empty_single");
    let dir_multi = tmp_dir("empty_multi");
    let sc_dir = tmp_dir("empty_file");
    let sc_file = sc_dir.join("tiny.scenario.json");

    Scenario::builder("tiny")
        .workloads("synthetic:2")
        .prims("d1")
        .levels("rf")
        .seed(7)
        .build()
        .expect("scenario builds")
        .write(&sc_file)
        .expect("scenario writes");
    let sc_file_s = sc_file.to_str().unwrap();

    run_ok(&["run", sc_file_s, "--out", dir_single.to_str().unwrap()]);
    run_ok(&[
        "orchestrate",
        sc_file_s,
        "--procs",
        "5",
        "--out",
        dir_multi.to_str().unwrap(),
    ]);
    assert_eq!(
        read(&dir_single.join("tiny.csv")),
        read(&dir_multi.join("tiny.csv")),
        "empty shards must not perturb the merged CSV"
    );
    for i in 0..5 {
        assert!(
            dir_multi.join(format!("tiny-shard{i}of5.json")).exists(),
            "shard {i}/5 summary must exist even when empty"
        );
    }
    for d in [dir_single, dir_multi, sc_dir] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

#[test]
fn batched_scenario_shards_and_merges_byte_identically() {
    let dir_single = tmp_dir("batch_single");
    let dir_multi = tmp_dir("batch_multi");
    let sc_dir = tmp_dir("batch_file");
    let sc_file = sc_dir.join("batched.scenario.json");

    Scenario::builder("batched")
        .workloads("gptj,dlrm")
        .prims("baseline,d1")
        .levels("rf")
        .batch("1,8")
        .seed(7)
        .build()
        .expect("scenario builds")
        .write(&sc_file)
        .expect("scenario writes");
    let sc_file_s = sc_file.to_str().unwrap();

    run_ok(&["run", sc_file_s, "--out", dir_single.to_str().unwrap()]);
    run_ok(&[
        "orchestrate",
        sc_file_s,
        "--procs",
        "2",
        "--out",
        dir_multi.to_str().unwrap(),
    ]);
    let single = read(&dir_single.join("batched.csv"));
    assert_eq!(
        single,
        read(&dir_multi.join("batched.csv")),
        "batched shards must merge byte-identically"
    );
    assert!(single.contains("GPT-J@b8"), "batched rows carry @b labels:\n{single}");
    assert!(single.contains("DLRM@b8"), "batched rows carry @b labels:\n{single}");
    for d in [dir_single, dir_multi, sc_dir] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

#[test]
fn bare_cache_flag_keeps_the_scenario_name_positional() {
    // The `repro run --cache fig2` regression: the bare optional-value
    // flag used to swallow `fig2` as the cache path and then fail on a
    // missing scenario. It must run fig2 and persist the cache at the
    // conventional default path instead.
    let dir = tmp_dir("bare_cache");
    std::fs::create_dir_all(&dir).unwrap();
    let out = repro()
        .current_dir(&dir)
        .args(["run", "--cache", "fig2", "--quick", "--out", "out"])
        .output()
        .expect("spawning repro");
    assert!(
        out.status.success(),
        "repro run --cache fig2 failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    assert!(dir.join("out").join("fig2.csv").exists(), "fig2 must have run");
    assert!(
        dir.join("results").join("cache.bin").exists(),
        "bare --cache must persist to the default results/cache.bin"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_experiment_name_matches_repro_experiment() {
    let dir_run = tmp_dir("exp_run");
    let dir_classic = tmp_dir("exp_classic");
    // fig2 is cheap and timing-free (pure workload statistics).
    run_ok(&["run", "fig2", "--quick", "--out", dir_run.to_str().unwrap()]);
    run_ok(&[
        "experiment",
        "fig2",
        "--quick",
        "--out",
        dir_classic.to_str().unwrap(),
    ]);
    assert_eq!(
        read(&dir_run.join("fig2.csv")),
        read(&dir_classic.join("fig2.csv")),
        "`repro run fig2` must match `repro experiment fig2` byte-for-byte"
    );
    for d in [dir_run, dir_classic] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

#[test]
fn run_rejects_unknown_names_and_stale_schema_versions() {
    let out = repro().args(["run", "fig99"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("no built-in scenario"), "{err}");

    let dir = tmp_dir("schema");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("future.json");
    std::fs::write(
        &path,
        "{\"scenario_format\": 999, \"name\": \"future\", \"sweep\": {}}\n",
    )
    .unwrap();
    let out = repro().args(["run", path.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(err.contains("format v999"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_cache_cap_flag_is_honoured_end_to_end() {
    let dir = tmp_dir("cap");
    let dir_s = dir.to_str().unwrap();
    let cache = dir.join("cache.bin");
    let cache_s = cache.to_str().unwrap();
    let mut args: Vec<&str> = vec!["sweep"];
    args.extend(GRID);
    let cache_flag = format!("--cache={cache_s}");
    args.extend(["--out", dir_s, &cache_flag, "--cache-max-mb", "1"]);
    run_ok(&args);
    let size = std::fs::metadata(&cache).expect("cache file written").len();
    assert!(size > 0 && size <= 1024 * 1024, "cache size {size} violates the cap");
    // A warm rerun serves everything from the persisted file.
    let stdout = run_ok(&args);
    assert!(
        stdout.contains("cache: 0 unique"),
        "warm rerun must be fully cached:\n{stdout}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
