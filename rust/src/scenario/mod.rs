//! The declarative scenario API: one serializable description behind
//! every sweep, experiment, and multi-process orchestration.
//!
//! A [`Scenario`] completely describes a run as *data*: the workload
//! grid and system axes (in the same compact axis syntax the CLI
//! flags use), the mapper choice ([`MapperChoice::cli_spec`] syntax),
//! the seed, the cache policy (path + `max_bytes` LRU cap), the shard
//! plan and the output sinks. It round-trips through the in-tree JSON
//! util ([`crate::util::json`]) under a schema version
//! ([`SCENARIO_FORMAT_VERSION`]), builds fluently via
//! [`Scenario::builder`], and *lowers* to the existing
//! [`crate::sweep::SweepSpec`] / [`crate::experiments::Ctx`] machinery
//! — the engine, cache and golden-equivalence guarantees are reused,
//! not forked.
//!
//! The CLI surface on top:
//!
//! * `repro run <scenario.json|name>` executes any scenario — files or
//!   the [`builtin`] registry (every experiment id plus the default
//!   sweep);
//! * `repro sweep` *constructs* a scenario from its grid flags (and can
//!   `--emit-scenario` it instead of running);
//! * `repro orchestrate <scenario.json|name> --procs n` spawns the n
//!   shard subprocesses itself and merges on completion
//!   ([`orchestrate`]).
//!
//! ```no_run
//! use www_cim::scenario::Scenario;
//!
//! let sc = Scenario::builder("quick")
//!     .workloads("synthetic:12")
//!     .prims("baseline,d1")
//!     .levels("rf,smem-b")
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
//! www_cim::scenario::exec::execute(&sc, None).unwrap();
//! ```

pub mod exec;
pub mod orchestrate;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::experiments;
use crate::sweep::spec::{self, MapperChoice, SweepSpec};
use crate::util::json::Json;
use crate::workload::synthetic;

pub use orchestrate::orchestrate;

/// Version of the scenario JSON schema. Bump on any structural change;
/// files of other versions are rejected at load, never half-read.
/// History: v1 = the initial schema; v2 added the `sweep.batch` axis;
/// v3 added the `orchestrate` block (timeout_s, retries, hosts,
/// remote_exe).
pub const SCENARIO_FORMAT_VERSION: u32 = 3;

/// Largest integer the JSON number carrier (f64) holds exactly — the
/// bound on every integral scenario field.
const MAX_SAFE_INT: u64 = 9_007_199_254_740_992;

/// Grid axes of a sweep scenario, in the CLI axis syntax (the same
/// strings `repro sweep --workloads/--prims/--levels/--sms/--mapper`
/// accept). Kept as strings so a scenario serializes compactly and
/// lowers through the one battle-tested parser set in
/// [`crate::sweep::spec`]; validation happens at build/load time, not
/// first use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GridAxes {
    pub workloads: String,
    pub prims: String,
    pub levels: String,
    pub sms: String,
    /// Batch axis (`--batch` syntax, e.g. `"1,4,16"`). Default `"1"`,
    /// the paper's regime — and a strict no-op relative to schema v1.
    pub batch: String,
    pub mapper: String,
}

impl Default for GridAxes {
    fn default() -> Self {
        GridAxes {
            workloads: spec::DEFAULT_WORKLOADS.to_string(),
            prims: spec::DEFAULT_PRIMS.to_string(),
            levels: spec::DEFAULT_LEVELS.to_string(),
            sms: "1".to_string(),
            batch: "1".to_string(),
            mapper: "priority".to_string(),
        }
    }
}

/// What a scenario runs: a design-space sweep grid, or one registered
/// paper experiment (whose CSV shaping lives in
/// [`crate::experiments::REGISTRY`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScenarioKind {
    Sweep(GridAxes),
    Experiment { id: String, quick: bool },
}

/// Persistent-cache policy: where the shared design-point cache lives
/// (None = in-memory only) and the optional on-disk size cap that
/// [`crate::sweep::persist::save_capped`] trims to, LRU-first.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CachePolicy {
    pub path: Option<PathBuf>,
    pub max_bytes: Option<u64>,
}

/// Supervision policy for `repro orchestrate`: the per-shard
/// wall-clock timeout, the retry budget for failed/timed-out shards
/// (safe: shards are deterministic, so a retried shard's summary is
/// byte-identical), and the optional ssh host list that turns the
/// orchestrator multi-host — shard `i` runs on `hosts[i % len]` via
/// `ssh host <remote_exe> run <scenario> --shard i/n`, assuming a
/// shared filesystem for the scenario file and the output dir. CLI
/// flags (`--shard-timeout-s`, `--shard-retries`) override these.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct OrchestratePolicy {
    /// Kill and reap a shard running longer than this (None = no
    /// timeout).
    pub timeout_s: Option<u64>,
    /// Re-spawn a failed/timed-out shard up to this many times (None =
    /// the orchestrator's default of 1).
    pub retries: Option<u64>,
    /// ssh hosts to spread shards over (empty = local subprocesses).
    pub hosts: Vec<String>,
    /// Path of the `repro` binary on the remote hosts (None = `repro`
    /// on the remote PATH). Only meaningful with `hosts`.
    pub remote_exe: Option<String>,
}

/// Output sinks: the directory CSV/JSON mirrors land in, an optional
/// tag overriding the scenario name as the file base name, and whether
/// the machine-readable summary is also printed to stdout. `tag` and
/// `stdout_json` apply to sweep scenarios only — experiments name
/// their CSVs by experiment id and have no run-level JSON summary, so
/// validation rejects them there rather than ignoring them silently.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutputPolicy {
    pub dir: PathBuf,
    pub tag: Option<String>,
    pub stdout_json: bool,
}

impl Default for OutputPolicy {
    fn default() -> Self {
        OutputPolicy {
            dir: PathBuf::from("results"),
            tag: None,
            stdout_json: false,
        }
    }
}

/// A complete, serializable run description. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Scenario name: the default output base name and the display
    /// name (`SweepSpec::name` for sweep scenarios).
    pub name: String,
    pub kind: ScenarioKind,
    /// Seed for synthetic datasets and seeded mappers.
    pub seed: u64,
    /// Worker-thread count (None = one per core).
    pub threads: Option<usize>,
    pub cache: CachePolicy,
    /// Default process count for `repro orchestrate` (None = the
    /// orchestrator's own default).
    pub shards: Option<usize>,
    /// Supervision + multi-host policy for `repro orchestrate`.
    pub orchestrate: OrchestratePolicy,
    pub output: OutputPolicy,
}

impl Scenario {
    /// Start a fluent builder for a sweep scenario named `name` over
    /// the default grid (switch to an experiment with
    /// [`ScenarioBuilder::experiment`]).
    pub fn builder(name: &str) -> ScenarioBuilder {
        ScenarioBuilder {
            sc: Scenario {
                name: name.to_string(),
                kind: ScenarioKind::Sweep(GridAxes::default()),
                seed: synthetic::DEFAULT_SEED,
                threads: None,
                cache: CachePolicy::default(),
                shards: None,
                orchestrate: OrchestratePolicy::default(),
                output: OutputPolicy::default(),
            },
            quick_on_sweep: false,
        }
    }

    /// The output file base name: the tag if set, else the name.
    pub fn base_name(&self) -> &str {
        self.output.tag.as_deref().unwrap_or(&self.name)
    }

    /// Check every field, including that the grid axes / experiment id
    /// actually parse — a scenario that validates will lower. Grid
    /// validation works by lowering (one [`Self::sweep_spec`] call),
    /// which builds the workload lists; that is milliseconds even for
    /// the full zoo, a deliberate trade for having exactly one parser.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("scenario: empty name");
        }
        for (field, v) in [
            ("seed", Some(self.seed)),
            ("cache.max_bytes", self.cache.max_bytes),
            ("orchestrate.timeout_s", self.orchestrate.timeout_s),
            ("orchestrate.retries", self.orchestrate.retries),
        ] {
            if let Some(v) = v {
                if v > MAX_SAFE_INT {
                    bail!("scenario: {field} {v} exceeds the JSON-safe integer range");
                }
            }
        }
        if self.threads == Some(0) {
            bail!("scenario: threads must be >= 1");
        }
        if self.shards == Some(0) {
            bail!("scenario: shards must be >= 1");
        }
        if self.orchestrate.timeout_s == Some(0) {
            bail!("scenario: orchestrate.timeout_s must be >= 1");
        }
        if self.orchestrate.remote_exe.is_some() && self.orchestrate.hosts.is_empty() {
            bail!("scenario: orchestrate.remote_exe needs orchestrate.hosts");
        }
        if self.orchestrate.hosts.iter().any(String::is_empty) {
            bail!("scenario: orchestrate.hosts entries must be non-empty");
        }
        match &self.kind {
            ScenarioKind::Sweep(_) => {
                self.sweep_spec().map(|_| ())
            }
            ScenarioKind::Experiment { id, .. } => {
                if id != "all" && experiments::find(id).is_none() {
                    bail!(
                        "scenario: unknown experiment {id:?} (options: {}, all)",
                        experiments::ids().join(", ")
                    );
                }
                // Experiments name their CSVs by id, have no run-level
                // JSON summary, and cannot be orchestrated into shard
                // subprocesses; accepting these fields and ignoring
                // them would be a silent lie.
                if self.output.tag.is_some() {
                    bail!("scenario: output.tag applies to sweep scenarios");
                }
                if self.output.stdout_json {
                    bail!("scenario: output.stdout_json applies to sweep scenarios");
                }
                if self.shards.is_some() {
                    bail!("scenario: shards (the orchestrate plan) applies to sweep scenarios");
                }
                if self.orchestrate != OrchestratePolicy::default() {
                    bail!("scenario: the orchestrate block applies to sweep scenarios");
                }
                Ok(())
            }
        }
    }

    /// Lower a sweep scenario to the engine's [`SweepSpec`] (the
    /// existing grid expansion, cache keys and shard fingerprints are
    /// reused unchanged). Errors on experiment scenarios.
    pub fn sweep_spec(&self) -> Result<SweepSpec> {
        match &self.kind {
            ScenarioKind::Sweep(axes) => {
                let batches = spec::parse_batches(&axes.batch)?;
                Ok(SweepSpec::new(&self.name)
                    .workloads(spec::parse_workloads_batched(
                        &axes.workloads,
                        self.seed,
                        &batches,
                    )?)
                    .systems(spec::parse_systems(&axes.prims, &axes.levels)?)
                    .sm_counts(spec::parse_sm_counts(&axes.sms)?)
                    .mapper(MapperChoice::parse(&axes.mapper, self.seed)?)
                    .batches(batches))
            }
            ScenarioKind::Experiment { id, .. } => {
                bail!("experiment scenario {id:?} has no sweep grid to lower")
            }
        }
    }

    /// Serialize to the canonical JSON form. Deterministic — field
    /// order is fixed — so `to_json ∘ from_json ∘ to_json` is
    /// byte-identical (the round-trip property test).
    pub fn to_json(&self) -> String {
        let opt_str = |v: &Option<String>| match v {
            Some(s) => Json::Str(s.clone()),
            None => Json::Null,
        };
        let opt_num = |v: Option<u64>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        let mut fields = vec![
            (
                "scenario_format".to_string(),
                Json::Num(f64::from(SCENARIO_FORMAT_VERSION)),
            ),
            ("name".to_string(), Json::Str(self.name.clone())),
            ("seed".to_string(), Json::Num(self.seed as f64)),
            (
                "threads".to_string(),
                opt_num(self.threads.map(|t| t as u64)),
            ),
            ("shards".to_string(), opt_num(self.shards.map(|s| s as u64))),
            (
                "cache".to_string(),
                Json::Obj(vec![
                    (
                        "path".to_string(),
                        opt_str(&self
                            .cache
                            .path
                            .as_ref()
                            .map(|p| p.to_string_lossy().into_owned())),
                    ),
                    ("max_bytes".to_string(), opt_num(self.cache.max_bytes)),
                ]),
            ),
            (
                "orchestrate".to_string(),
                Json::Obj(vec![
                    (
                        "timeout_s".to_string(),
                        opt_num(self.orchestrate.timeout_s),
                    ),
                    ("retries".to_string(), opt_num(self.orchestrate.retries)),
                    (
                        "hosts".to_string(),
                        Json::Arr(
                            self.orchestrate
                                .hosts
                                .iter()
                                .map(|h| Json::Str(h.clone()))
                                .collect(),
                        ),
                    ),
                    (
                        "remote_exe".to_string(),
                        opt_str(&self.orchestrate.remote_exe),
                    ),
                ]),
            ),
            (
                "output".to_string(),
                Json::Obj(vec![
                    (
                        "dir".to_string(),
                        Json::Str(self.output.dir.to_string_lossy().into_owned()),
                    ),
                    ("tag".to_string(), opt_str(&self.output.tag)),
                    ("stdout_json".to_string(), Json::Bool(self.output.stdout_json)),
                ]),
            ),
        ];
        match &self.kind {
            ScenarioKind::Sweep(axes) => fields.push((
                "sweep".to_string(),
                Json::Obj(vec![
                    ("workloads".to_string(), Json::Str(axes.workloads.clone())),
                    ("prims".to_string(), Json::Str(axes.prims.clone())),
                    ("levels".to_string(), Json::Str(axes.levels.clone())),
                    ("sms".to_string(), Json::Str(axes.sms.clone())),
                    ("batch".to_string(), Json::Str(axes.batch.clone())),
                    ("mapper".to_string(), Json::Str(axes.mapper.clone())),
                ]),
            )),
            ScenarioKind::Experiment { id, quick } => fields.push((
                "experiment".to_string(),
                Json::Obj(vec![
                    ("id".to_string(), Json::Str(id.clone())),
                    ("quick".to_string(), Json::Bool(*quick)),
                ]),
            )),
        }
        Json::Obj(fields).encode()
    }

    /// Parse and validate a scenario document. Strict: an unsupported
    /// schema version or an unknown field is an error (catches typos
    /// before they silently fall back to defaults); every missing
    /// optional field takes its documented default.
    pub fn from_json(text: &str) -> Result<Scenario> {
        let doc = Json::parse(text).context("scenario: malformed JSON")?;
        let fields = match &doc {
            Json::Obj(fields) => fields,
            // Every non-object variant named so a future Json variant
            // must decide its meaning here (lint R5).
            Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) | Json::Arr(_) => {
                bail!("scenario: top level must be an object")
            }
        };
        const KNOWN: &[&str] = &[
            "scenario_format",
            "name",
            "seed",
            "threads",
            "shards",
            "cache",
            "orchestrate",
            "output",
            "sweep",
            "experiment",
        ];
        for (k, _) in fields {
            if !KNOWN.contains(&k.as_str()) {
                bail!(
                    "scenario: unknown field {k:?} (known: {})",
                    KNOWN.join(", ")
                );
            }
        }
        let version = doc
            .get("scenario_format")
            .and_then(Json::as_u64)
            .context("scenario: missing scenario_format version")?;
        if version != u64::from(SCENARIO_FORMAT_VERSION) {
            bail!(
                "scenario: format v{version}, this binary reads v{SCENARIO_FORMAT_VERSION}"
            );
        }
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .context("scenario: missing name")?
            .to_string();
        let seed = match present(&doc, "seed") {
            Some(v) => v.as_u64().context("scenario: seed must be an integer")?,
            None => synthetic::DEFAULT_SEED,
        };
        let threads = match present(&doc, "threads") {
            Some(v) => Some(v.as_u64().context("scenario: threads must be an integer")? as usize),
            None => None,
        };
        let shards = match present(&doc, "shards") {
            Some(v) => Some(v.as_u64().context("scenario: shards must be an integer")? as usize),
            None => None,
        };
        let cache = match present(&doc, "cache") {
            None => CachePolicy::default(),
            Some(c) => {
                check_keys(c, &["path", "max_bytes"], "cache")?;
                CachePolicy {
                    path: match present(c, "path") {
                        Some(v) => Some(PathBuf::from(
                            v.as_str().context("scenario: cache.path must be a string")?,
                        )),
                        None => None,
                    },
                    max_bytes: match present(c, "max_bytes") {
                        Some(v) => Some(
                            v.as_u64()
                                .context("scenario: cache.max_bytes must be an integer")?,
                        ),
                        None => None,
                    },
                }
            }
        };
        let orchestrate = match present(&doc, "orchestrate") {
            None => OrchestratePolicy::default(),
            Some(o) => {
                check_keys(o, &["timeout_s", "retries", "hosts", "remote_exe"], "orchestrate")?;
                OrchestratePolicy {
                    timeout_s: match present(o, "timeout_s") {
                        Some(v) => Some(
                            v.as_u64()
                                .context("scenario: orchestrate.timeout_s must be an integer")?,
                        ),
                        None => None,
                    },
                    retries: match present(o, "retries") {
                        Some(v) => Some(
                            v.as_u64()
                                .context("scenario: orchestrate.retries must be an integer")?,
                        ),
                        None => None,
                    },
                    hosts: match present(o, "hosts") {
                        None => Vec::new(),
                        Some(v) => {
                            let arr = v
                                .as_array()
                                .context("scenario: orchestrate.hosts must be an array")?;
                            let mut hosts = Vec::with_capacity(arr.len());
                            for h in arr {
                                hosts.push(
                                    h.as_str()
                                        .context(
                                            "scenario: orchestrate.hosts entries must be strings",
                                        )?
                                        .to_string(),
                                );
                            }
                            hosts
                        }
                    },
                    remote_exe: match present(o, "remote_exe") {
                        Some(v) => Some(
                            v.as_str()
                                .context("scenario: orchestrate.remote_exe must be a string")?
                                .to_string(),
                        ),
                        None => None,
                    },
                }
            }
        };
        let output = match present(&doc, "output") {
            None => OutputPolicy::default(),
            Some(o) => {
                check_keys(o, &["dir", "tag", "stdout_json"], "output")?;
                OutputPolicy {
                    dir: match present(o, "dir") {
                        Some(v) => PathBuf::from(
                            v.as_str().context("scenario: output.dir must be a string")?,
                        ),
                        None => OutputPolicy::default().dir,
                    },
                    tag: match present(o, "tag") {
                        Some(v) => Some(
                            v.as_str()
                                .context("scenario: output.tag must be a string")?
                                .to_string(),
                        ),
                        None => None,
                    },
                    stdout_json: match present(o, "stdout_json") {
                        Some(v) => v
                            .as_bool()
                            .context("scenario: output.stdout_json must be a boolean")?,
                        None => false,
                    },
                }
            }
        };
        let kind = match (present(&doc, "sweep"), present(&doc, "experiment")) {
            (Some(_), Some(_)) => {
                bail!("scenario: give either \"sweep\" or \"experiment\", not both")
            }
            (None, None) => bail!("scenario: missing \"sweep\" or \"experiment\" section"),
            (Some(s), None) => {
                check_keys(
                    s,
                    &["workloads", "prims", "levels", "sms", "batch", "mapper"],
                    "sweep",
                )?;
                let axis = |key: &str, default: &str| -> Result<String> {
                    match present(s, key) {
                        Some(v) => Ok(v
                            .as_str()
                            .with_context(|| format!("scenario: sweep.{key} must be a string"))?
                            .to_string()),
                        None => Ok(default.to_string()),
                    }
                };
                let defaults = GridAxes::default();
                ScenarioKind::Sweep(GridAxes {
                    workloads: axis("workloads", &defaults.workloads)?,
                    prims: axis("prims", &defaults.prims)?,
                    levels: axis("levels", &defaults.levels)?,
                    sms: axis("sms", &defaults.sms)?,
                    batch: axis("batch", &defaults.batch)?,
                    mapper: axis("mapper", &defaults.mapper)?,
                })
            }
            (None, Some(e)) => {
                check_keys(e, &["id", "quick"], "experiment")?;
                ScenarioKind::Experiment {
                    id: e
                        .get("id")
                        .and_then(Json::as_str)
                        .context("scenario: missing experiment.id")?
                        .to_string(),
                    quick: match present(e, "quick") {
                        Some(v) => v
                            .as_bool()
                            .context("scenario: experiment.quick must be a boolean")?,
                        None => false,
                    },
                }
            }
        };
        let sc = Scenario {
            name,
            kind,
            seed,
            threads,
            cache,
            shards,
            orchestrate,
            output,
        };
        sc.validate()?;
        Ok(sc)
    }

    /// Load a scenario from a JSON file.
    pub fn from_json_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        Scenario::from_json(&text)
            .with_context(|| format!("scenario file {}", path.display()))
    }

    /// Write the canonical JSON form to `path`, creating parent dirs.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating scenario dir {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing scenario {}", path.display()))
    }
}

/// Field access treating an explicit `null` like a missing field.
fn present<'a>(obj: &'a Json, key: &str) -> Option<&'a Json> {
    match obj.get(key) {
        None | Some(Json::Null) => None,
        Some(v) => Some(v),
    }
}

/// Reject unknown keys in a nested section.
fn check_keys(obj: &Json, known: &[&str], section: &str) -> Result<()> {
    if let Json::Obj(fields) = obj {
        for (k, _) in fields {
            if !known.contains(&k.as_str()) {
                bail!(
                    "scenario: unknown field {section}.{k} (known: {})",
                    known.join(", ")
                );
            }
        }
        Ok(())
    } else {
        bail!("scenario: {section} must be an object")
    }
}

/// Fluent [`Scenario`] construction; terminate with
/// [`ScenarioBuilder::build`], which validates.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    sc: Scenario,
    /// Quick mode requested while the scenario is (still) a sweep —
    /// adopted by a later [`Self::experiment`] call, rejected by
    /// [`Self::build`] otherwise (the CLI makes the same request a
    /// hard error; the builder must not silently drop it).
    quick_on_sweep: bool,
}

impl ScenarioBuilder {
    fn axes_mut(&mut self) -> &mut GridAxes {
        if let ScenarioKind::Experiment { .. } = self.sc.kind {
            self.sc.kind = ScenarioKind::Sweep(GridAxes::default());
        }
        match &mut self.sc.kind {
            ScenarioKind::Sweep(axes) => axes,
            ScenarioKind::Experiment { .. } => unreachable!("replaced above"),
        }
    }

    /// Workload axis (`repro sweep --workloads` syntax).
    pub fn workloads(mut self, v: &str) -> Self {
        self.axes_mut().workloads = v.to_string();
        self
    }

    /// Primitive axis (`--prims` syntax).
    pub fn prims(mut self, v: &str) -> Self {
        self.axes_mut().prims = v.to_string();
        self
    }

    /// Integration-level axis (`--levels` syntax).
    pub fn levels(mut self, v: &str) -> Self {
        self.axes_mut().levels = v.to_string();
        self
    }

    /// SM-count axis (`--sms` syntax).
    pub fn sms(mut self, v: &str) -> Self {
        self.axes_mut().sms = v.to_string();
        self
    }

    /// Batch axis (`--batch` syntax, e.g. `"1,4,16"`).
    pub fn batch(mut self, v: &str) -> Self {
        self.axes_mut().batch = v.to_string();
        self
    }

    /// Mapper axis (`--mapper` syntax; see [`MapperChoice::parse`]).
    pub fn mapper(mut self, v: &str) -> Self {
        self.axes_mut().mapper = v.to_string();
        self
    }

    /// Mapper axis from a typed choice (spelled via
    /// [`MapperChoice::cli_spec`], so every variant serializes).
    ///
    /// The heuristic mapper is the one variant whose spelling does not
    /// carry its whole identity: a scenario has exactly one seed, so
    /// [`MapperChoice::Heuristic`]'s embedded seed is *adopted as the
    /// scenario seed* here (matching how the CLI derives the heuristic
    /// seed from `--seed`) rather than silently replaced at lowering.
    /// Call [`ScenarioBuilder::seed`] afterwards only if you mean to
    /// re-seed both the workloads and the heuristic together.
    pub fn mapper_choice(mut self, mc: &MapperChoice) -> Self {
        if let MapperChoice::Heuristic { seed, .. } = mc {
            self.sc.seed = *seed;
        }
        let spelled = mc.cli_spec();
        self.mapper(&spelled)
    }

    /// Turn this scenario into a registered experiment run (adopting
    /// any [`Self::quick`] request made before this call).
    pub fn experiment(mut self, id: &str) -> Self {
        self.sc.kind = ScenarioKind::Experiment {
            id: id.to_string(),
            quick: std::mem::take(&mut self.quick_on_sweep),
        };
        self
    }

    /// Quick mode for experiment scenarios. Calling it on a sweep
    /// scenario is an error at [`Self::build`] (mirroring the CLI's
    /// `--quick` behavior) unless a later [`Self::experiment`] call
    /// adopts the request.
    pub fn quick(mut self, quick: bool) -> Self {
        match &mut self.sc.kind {
            ScenarioKind::Experiment { quick: q, .. } => *q = quick,
            ScenarioKind::Sweep(_) => self.quick_on_sweep = quick,
        }
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.sc.seed = seed;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.sc.threads = Some(threads);
        self
    }

    pub fn cache_path(mut self, path: &Path) -> Self {
        self.sc.cache.path = Some(path.to_path_buf());
        self
    }

    pub fn cache_max_bytes(mut self, max_bytes: u64) -> Self {
        self.sc.cache.max_bytes = Some(max_bytes);
        self
    }

    /// Shard plan: the default `repro orchestrate` process count.
    pub fn shards(mut self, shards: usize) -> Self {
        self.sc.shards = Some(shards);
        self
    }

    /// Per-shard wall-clock timeout for `repro orchestrate`, seconds.
    pub fn shard_timeout_s(mut self, timeout_s: u64) -> Self {
        self.sc.orchestrate.timeout_s = Some(timeout_s);
        self
    }

    /// Retry budget for failed/timed-out shards.
    pub fn shard_retries(mut self, retries: u64) -> Self {
        self.sc.orchestrate.retries = Some(retries);
        self
    }

    /// ssh hosts for multi-host orchestration (round-robin over
    /// shards). Empty = local subprocesses.
    pub fn hosts(mut self, hosts: &[&str]) -> Self {
        self.sc.orchestrate.hosts = hosts.iter().map(|h| h.to_string()).collect();
        self
    }

    /// Path of the `repro` binary on the remote hosts.
    pub fn remote_exe(mut self, exe: &str) -> Self {
        self.sc.orchestrate.remote_exe = Some(exe.to_string());
        self
    }

    pub fn out_dir(mut self, dir: &Path) -> Self {
        self.sc.output.dir = dir.to_path_buf();
        self
    }

    pub fn tag(mut self, tag: &str) -> Self {
        self.sc.output.tag = Some(tag.to_string());
        self
    }

    pub fn stdout_json(mut self, on: bool) -> Self {
        self.sc.output.stdout_json = on;
        self
    }

    /// Validate and produce the scenario.
    pub fn build(self) -> Result<Scenario> {
        if self.quick_on_sweep {
            bail!("scenario: quick mode applies to experiment scenarios");
        }
        self.sc.validate()?;
        Ok(self.sc)
    }
}

/// The built-in scenario registry: every experiment id (lowered from
/// [`crate::experiments::REGISTRY`], so the two can never drift) plus
/// `sweep`, the default full-grid sweep. `repro run <name>` and
/// `repro orchestrate <name>` accept these names directly.
pub fn builtin(name: &str) -> Result<Scenario> {
    if name == "sweep" {
        return Scenario::builder("sweep").build();
    }
    if experiments::find(name).is_some() {
        return Scenario::builder(name).experiment(name).build();
    }
    bail!(
        "no built-in scenario {name:?} (built-ins: {})",
        builtin_names().join(", ")
    )
}

/// Names [`builtin`] accepts, in listing order.
pub fn builtin_names() -> Vec<&'static str> {
    let mut names = vec!["sweep"];
    names.extend(experiments::ids());
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn arbitrary_scenario(rng: &mut Rng) -> Scenario {
        let name = format!("sc-{}", rng.gen_range(0, 1000));
        let mut b = Scenario::builder(&name).seed(rng.gen_range(1, 1 << 20));
        let experiment_kind = rng.gen_range(0, 2) == 0;
        if experiment_kind {
            let ids = experiments::ids();
            b = b
                .experiment(ids[rng.index(ids.len())])
                .quick(rng.gen_range(0, 2) == 0);
        } else {
            let workloads = ["bert", "synthetic:9", "bert,dlrm", "real"];
            let prims = ["d1", "baseline,d1", "all", "baseline,a2"];
            let levels = ["rf", "rf,smem-b", "all"];
            let sms = ["1", "1,2,4", "2"];
            let batches = ["1", "1,4", "2,8", "16"];
            let mappers = [
                "priority",
                "dup:t3",
                "priority:t7",
                "priority:order-kmn",
                "heuristic:60",
                "exhaustive:edp",
            ];
            b = b
                .workloads(workloads[rng.index(workloads.len())])
                .prims(prims[rng.index(prims.len())])
                .levels(levels[rng.index(levels.len())])
                .sms(sms[rng.index(sms.len())])
                .batch(batches[rng.index(batches.len())])
                .mapper(mappers[rng.index(mappers.len())]);
        }
        if rng.gen_range(0, 2) == 0 {
            b = b.threads(rng.gen_range(1, 16) as usize);
        }
        if !experiment_kind && rng.gen_range(0, 2) == 0 {
            b = b.shards(rng.gen_range(1, 8) as usize);
        }
        // The orchestrate block is sweep-only, like shards.
        if !experiment_kind {
            if rng.gen_range(0, 2) == 0 {
                b = b.shard_timeout_s(rng.gen_range(1, 3600));
            }
            if rng.gen_range(0, 2) == 0 {
                b = b.shard_retries(rng.gen_range(0, 5));
            }
            if rng.gen_range(0, 2) == 0 {
                b = b.hosts(&["cim-a", "cim-b.local"]);
                if rng.gen_range(0, 2) == 0 {
                    b = b.remote_exe("/opt/www-cim/bin/repro");
                }
            }
        }
        if rng.gen_range(0, 2) == 0 {
            b = b.cache_path(Path::new("results/cache \"x\".bin"));
        }
        if rng.gen_range(0, 2) == 0 {
            b = b.cache_max_bytes(rng.gen_range(1, 1 << 30));
        }
        if rng.gen_range(0, 2) == 0 {
            b = b.out_dir(Path::new("out/dir"));
        }
        // tag / stdout_json are sweep-only fields (validation rejects
        // them on experiment scenarios).
        if !experiment_kind {
            if rng.gen_range(0, 2) == 0 {
                b = b.tag(&format!("tag-{}", rng.gen_range(0, 100)));
            }
            if rng.gen_range(0, 2) == 0 {
                b = b.stdout_json(true);
            }
        }
        b.build().expect("arbitrary scenario must validate")
    }

    /// Tentpole property: Scenario → json → Scenario → json is exact —
    /// the value round-trips and the re-serialization is byte-identical.
    #[test]
    fn prop_json_round_trip_is_byte_identical() {
        let mut rng = Rng::new(0x5eed_5ca1e);
        for _ in 0..200 {
            let sc = arbitrary_scenario(&mut rng);
            let text = sc.to_json();
            let back = Scenario::from_json(&text)
                .unwrap_or_else(|e| panic!("round trip failed: {e:#}\n{text}"));
            assert_eq!(back, sc, "value round trip\n{text}");
            assert_eq!(back.to_json(), text, "byte round trip");
        }
    }

    #[test]
    fn unsupported_schema_version_is_rejected() {
        let sc = Scenario::builder("v").workloads("bert").prims("d1").build().unwrap();
        let bumped = sc
            .to_json()
            .replace("\"scenario_format\": 3", "\"scenario_format\": 4");
        let err = Scenario::from_json(&bumped).unwrap_err();
        assert!(
            format!("{err:#}").contains("format v4"),
            "must reject v4: {err:#}"
        );
        // v2 files predate the orchestrate block; they are rejected at
        // load (with the version named) rather than half-read.
        let old = sc
            .to_json()
            .replace("\"scenario_format\": 3", "\"scenario_format\": 2");
        let err = Scenario::from_json(&old).unwrap_err();
        assert!(
            format!("{err:#}").contains("format v2"),
            "must reject v2: {err:#}"
        );
        let missing = sc.to_json().replace("  \"scenario_format\": 3,\n", "");
        assert!(Scenario::from_json(&missing).is_err(), "version is mandatory");
    }

    #[test]
    fn unknown_fields_are_rejected() {
        let sc = Scenario::builder("u").build().unwrap();
        let tweaked = sc.to_json().replace("\"seed\"", "\"sede\"");
        let err = Scenario::from_json(&tweaked).unwrap_err();
        assert!(format!("{err:#}").contains("unknown field"), "{err:#}");
        let tweaked = sc.to_json().replace("\"mapper\"", "\"mappre\"");
        let err = Scenario::from_json(&tweaked).unwrap_err();
        assert!(format!("{err:#}").contains("sweep.mappre"), "{err:#}");
    }

    #[test]
    fn validation_rejects_bad_scenarios() {
        assert!(Scenario::builder("x").workloads("quantum").build().is_err());
        assert!(Scenario::builder("x").mapper("magic").build().is_err());
        assert!(Scenario::builder("x").sms("0").build().is_err());
        assert!(Scenario::builder("x").experiment("fig99").build().is_err());
        assert!(Scenario::builder("").build().is_err());
        // tag / stdout_json / shards are sweep-only: rejected, never
        // ignored.
        assert!(Scenario::builder("x").experiment("fig2").tag("t").build().is_err());
        assert!(Scenario::builder("x")
            .experiment("fig2")
            .stdout_json(true)
            .build()
            .is_err());
        assert!(Scenario::builder("x").experiment("fig2").shards(2).build().is_err());
        // ...as is the whole orchestrate block.
        assert!(Scenario::builder("x")
            .experiment("fig2")
            .shard_retries(3)
            .build()
            .is_err());
        // remote_exe without hosts, empty host names, and a zero
        // timeout are all malformed orchestrate blocks.
        assert!(Scenario::builder("x").remote_exe("/usr/bin/repro").build().is_err());
        assert!(Scenario::builder("x").hosts(&["a", ""]).build().is_err());
        assert!(Scenario::builder("x").shard_timeout_s(0).build().is_err());
        // ...and quick is experiment-only: a sweep build errors rather
        // than silently dropping the request, while a later
        // .experiment() adopts it regardless of call order.
        assert!(Scenario::builder("x").quick(true).build().is_err());
        let adopted = Scenario::builder("x").quick(true).experiment("fig2").build().unwrap();
        assert_eq!(
            adopted.kind,
            ScenarioKind::Experiment { id: "fig2".to_string(), quick: true }
        );
        let mut sc = Scenario::builder("x").build().unwrap();
        sc.threads = Some(0);
        assert!(sc.validate().is_err());
        sc.threads = None;
        sc.shards = Some(0);
        assert!(sc.validate().is_err());
        sc.shards = None;
        sc.seed = MAX_SAFE_INT + 1;
        assert!(sc.validate().is_err());
        sc.seed = 7;
        sc.orchestrate.retries = Some(MAX_SAFE_INT + 1);
        assert!(sc.validate().is_err());
    }

    #[test]
    fn missing_optional_fields_take_defaults() {
        let sc = Scenario::from_json(
            r#"{"scenario_format": 3, "name": "minimal",
                "sweep": {"workloads": "bert", "prims": "d1", "levels": "rf"}}"#,
        )
        .unwrap();
        assert_eq!(sc.seed, synthetic::DEFAULT_SEED);
        assert_eq!(sc.threads, None);
        assert_eq!(sc.cache, CachePolicy::default());
        assert_eq!(sc.orchestrate, OrchestratePolicy::default());
        assert_eq!(sc.output, OutputPolicy::default());
        match &sc.kind {
            ScenarioKind::Sweep(axes) => {
                assert_eq!(axes.sms, "1");
                assert_eq!(axes.batch, "1");
                assert_eq!(axes.mapper, "priority");
            }
            other => panic!("expected sweep kind, got {other:?}"),
        }
        assert_eq!(sc.base_name(), "minimal");
    }

    #[test]
    fn sweep_and_experiment_are_mutually_exclusive() {
        let err = Scenario::from_json(
            r#"{"scenario_format": 3, "name": "both", "sweep": {},
                "experiment": {"id": "fig9"}}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("not both"), "{err:#}");
        let err = Scenario::from_json(r#"{"scenario_format": 3, "name": "neither"}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("missing"), "{err:#}");
    }

    #[test]
    fn builtin_registry_covers_every_experiment_and_the_default_sweep() {
        assert_eq!(builtin_names().len(), experiments::ids().len() + 1);
        for name in builtin_names() {
            let sc = builtin(name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(sc.name, name);
            // Every built-in serializes and round-trips like any other
            // scenario.
            assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
            match (&sc.kind, name) {
                (ScenarioKind::Sweep(_), "sweep") => {}
                (ScenarioKind::Experiment { id, quick }, _) => {
                    assert_eq!(id, name);
                    assert!(!*quick, "built-ins default to full fidelity");
                }
                (kind, name) => panic!("{name}: unexpected kind {kind:?}"),
            }
        }
        assert!(builtin("fig99").is_err());
    }

    #[test]
    fn lowering_matches_the_cli_parsers() {
        let sc = Scenario::builder("lower")
            .workloads("bert,dlrm")
            .prims("baseline,d1")
            .levels("rf,smem-b")
            .sms("1,4")
            .mapper("priority:t7")
            .seed(11)
            .build()
            .unwrap();
        let spec = sc.sweep_spec().unwrap();
        assert_eq!(spec.name, "lower");
        assert_eq!(spec.workloads.len(), 2);
        assert_eq!(spec.systems.len(), 3);
        assert_eq!(spec.sm_counts, vec![1, 4]);
        assert_eq!(spec.batches, vec![1]);
        assert_eq!(
            spec.mapper,
            MapperChoice::PriorityThreshold { threshold: 7 }
        );
        assert!(builtin("fig9").unwrap().sweep_spec().is_err());
    }

    #[test]
    fn batch_axis_lowers_and_validates() {
        let sc = Scenario::builder("batched")
            .workloads("gptj,bert")
            .prims("baseline,d1")
            .levels("rf")
            .batch("1,16")
            .seed(7)
            .build()
            .unwrap();
        let spec = sc.sweep_spec().unwrap();
        assert_eq!(spec.batches, vec![1, 16]);
        // 2 workloads x 2 batches, batch-major, suffixed past batch 1.
        let names: Vec<&str> = spec.workloads.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["GPT-J", "BERT-Large", "GPT-J@b16", "BERT-Large@b16"]);
        // Round-trips like any axis, and a bad axis fails validation.
        assert_eq!(Scenario::from_json(&sc.to_json()).unwrap(), sc);
        assert!(Scenario::builder("x").batch("0").build().is_err());
        assert!(Scenario::builder("x").batch("nope").build().is_err());
    }

    #[test]
    fn mapper_choice_builder_spells_every_variant() {
        let mc = MapperChoice::PriorityFixedOrder {
            order: [
                crate::mapping::loopnest::Dim::K,
                crate::mapping::loopnest::Dim::N,
                crate::mapping::loopnest::Dim::M,
            ],
        };
        let sc = Scenario::builder("m")
            .workloads("bert")
            .prims("d1")
            .levels("rf")
            .mapper_choice(&mc)
            .build()
            .unwrap();
        assert_eq!(sc.sweep_spec().unwrap().mapper, mc);

        // The heuristic's embedded seed is adopted as the scenario
        // seed, so lowering reproduces the exact typed mapper instead
        // of silently re-seeding it.
        let h = MapperChoice::Heuristic { budget: 60, seed: 99 };
        let sc = Scenario::builder("h")
            .workloads("bert")
            .prims("d1")
            .levels("rf")
            .seed(7)
            .mapper_choice(&h)
            .build()
            .unwrap();
        assert_eq!(sc.seed, 99);
        assert_eq!(sc.sweep_spec().unwrap().mapper, h);
    }
}
