//! Scenario execution: lower a [`Scenario`] onto the existing engine
//! machinery and run it.
//!
//! * Sweep scenarios lower to a [`crate::sweep::SweepSpec`] and run
//!   through [`SweepEngine`] exactly the way `repro sweep` always has —
//!   same console output, same CSV/JSON sinks, same `--shard i/n`
//!   slicing — so a flag-built sweep and its `--emit-scenario`'d file
//!   produce byte-identical artifacts (pinned by the integration
//!   tests).
//! * Experiment scenarios lower to a [`Ctx`] and dispatch through the
//!   experiment registry, identically to `repro experiment <id>`
//!   (pinned by the golden-equivalence suite for every registered id).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::arch::Architecture;
use crate::experiments::{self, Ctx};
use crate::sweep::{output, persist, shard, EvalCache, ShardId, SweepEngine};
use crate::util::pool;

use super::{Scenario, ScenarioKind};

/// Execute a scenario; `shard` (sweep scenarios only) runs one
/// deterministic 1/n slice of the grid and writes the per-shard
/// summary instead of the merged artifacts.
pub fn execute(sc: &Scenario, shard: Option<ShardId>) -> Result<()> {
    sc.validate()?;
    match &sc.kind {
        ScenarioKind::Experiment { id, .. } => {
            if shard.is_some() {
                bail!(
                    "--shard slices sweep grids; experiment scenarios parallelize \
                     internally (run {id:?} without --shard)"
                );
            }
            run_experiment(sc, id)
        }
        ScenarioKind::Sweep(_) => run_sweep(sc, shard),
    }
}

/// Lower an experiment scenario to its [`Ctx`].
pub fn experiment_ctx(sc: &Scenario) -> Ctx {
    let mut ctx = Ctx::default();
    if let ScenarioKind::Experiment { quick, .. } = &sc.kind {
        ctx.quick = *quick;
    }
    ctx.out_dir = sc.output.dir.clone();
    if let Some(threads) = sc.threads {
        ctx.threads = threads;
    }
    ctx.seed = sc.seed;
    ctx.cache_path = sc.cache.path.clone();
    ctx.cache_max_bytes = sc.cache.max_bytes;
    ctx
}

fn run_experiment(sc: &Scenario, id: &str) -> Result<()> {
    let ctx = experiment_ctx(sc);
    ctx.load_persistent_cache()?;
    let result = experiments::run(id, &ctx);
    // Run-level cache accounting: on a warm persisted cache this must
    // read "0 misses (100.0% hit rate), 0 mapper call(s)" — the CI e2e
    // step greps for it to prove no experiment bypasses the engine.
    println!("{}", ctx.cache_stats_line());
    // Persist whatever was scored even if one experiment failed — the
    // cache entries themselves are valid. A save failure must not mask
    // the experiment's own error, so it is reported, not propagated.
    if let Err(e) = ctx.save_persistent_cache() {
        eprintln!("warning: could not persist the sweep cache: {e:#}");
    }
    result
}

fn run_sweep(sc: &Scenario, shard_id: Option<ShardId>) -> Result<()> {
    let arch = Architecture::default_sm();
    let threads = sc.threads.unwrap_or_else(pool::default_threads);
    let sweep_spec = sc.sweep_spec()?;

    println!(
        "sweep: {} grid points ({} workload(s) x {} system(s) x {} SM count(s)), {} threads",
        sweep_spec.n_points(),
        sweep_spec.workloads.len(),
        sweep_spec.systems.len(),
        sweep_spec.sm_counts.len(),
        threads
    );
    // The batch axis is already folded into the workload list (one
    // entry per workload x batch); announce it only when non-trivial so
    // batch-1 runs keep their historical output byte-for-byte.
    if sweep_spec.batches.len() > 1 {
        println!(
            "sweep: batch axis {:?} expanded into the {} workload entries",
            sweep_spec.batches,
            sweep_spec.workloads.len()
        );
    }
    let engine = SweepEngine::new(arch).threads(threads);

    // Persistent cache: warm from disk if a compatible file exists.
    if let Some(path) = &sc.cache.path {
        let load = persist::load_into(engine.cache(), path)?;
        println!("[cache] {} ({})", load.describe(), path.display());
    }

    // Shard slicing: expand the full grid, run the deterministic
    // round-robin slice (the whole grid without a shard).
    let all_jobs = sweep_spec.jobs();
    let run = match shard_id {
        None => engine.run_jobs_named(&sweep_spec.name, &all_jobs),
        Some(s) => {
            let slice = s.slice(&all_jobs);
            println!("shard {s}: {} of {} grid points", slice.len(), all_jobs.len());
            engine.run_jobs_named(&sweep_spec.name, &slice)
        }
    };
    println!(
        "evaluated {} points in {:.3}s (cache: {} unique, {} duplicate hits)",
        run.n_points(),
        run.elapsed.as_secs_f64(),
        run.cache_misses,
        run.cache_hits
    );
    if let Some(path) = &sc.cache.path {
        let outcome = persist::save_capped(engine.cache(), path, sc.cache.max_bytes)?;
        println!("[cache] {} -> {}", outcome.describe(), path.display());
    }

    // Small grids get the full per-point table; every run gets the
    // per-system summary.
    if run.results.len() <= 80 {
        print!("{}", output::detail_table(&run.results));
    }
    print!("{}", output::summary_table(&run.results));

    // CSV + JSON mirrors, named by the scenario's base name (tag, else
    // name) and the shard identity — successive tagged or sharded
    // sweeps never overwrite each other.
    let out_dir = &sc.output.dir;
    let base = sc.base_name();
    let csv = output::results_csv(&run.results)?;
    match shard_id {
        None => {
            let csv_path = out_dir.join(format!("{base}.csv"));
            csv.write(&csv_path)?;
            println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
            let json_path = out_dir.join(format!("{base}.json"));
            output::write_json_summary(&run, &json_path)?;
            println!("[json] summary -> {}", json_path.display());
            if sc.output.stdout_json {
                print!("{}", output::json_summary(&run));
            }
        }
        Some(s) => {
            let fp = shard::sweep_fingerprint(engine.arch(), &sweep_spec);
            let csv_path = out_dir.join(format!("{base}-{}.csv", s.file_tag()));
            csv.write(&csv_path)?;
            println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
            let json_path = out_dir.join(format!("{base}-{}.json", s.file_tag()));
            shard::write_shard_json(&run, s, &fp, all_jobs.len(), &json_path)?;
            println!(
                "[json] shard summary -> {} (merge all {} shards with `repro merge` \
                 or let `repro orchestrate` do it)",
                json_path.display(),
                s.count
            );
            if sc.output.stdout_json {
                print!("{}", shard::shard_json(&run, s, &fp, all_jobs.len()));
            }
        }
    }
    Ok(())
}

/// One in-memory sweep evaluation: everything `repro run` would have
/// produced for the same scenario, minus the console output and file
/// sinks. The serve daemon streams `csv` back to clients — it must stay
/// byte-identical to the `<base>.csv` that [`execute`] writes (pinned
/// by the serve integration tests and the CI e2e `cmp`).
#[derive(Debug, Clone)]
pub struct SweepEval {
    /// Output base name (`tag`, else scenario name).
    pub name: String,
    /// Full CSV document (header + rows, trailing newline).
    pub csv: String,
    /// Grid points evaluated.
    pub points: usize,
    /// Cache hits attributable to this run (delta of the shared
    /// counters; approximate when other requests run concurrently —
    /// the daemon's `stats` op reads the exact global totals).
    pub hits: u64,
    /// Cache misses attributable to this run (see `hits`).
    pub misses: u64,
    /// Mapper invocations attributable to this run (see `hits`).
    pub mapper_calls: u64,
    /// Wall-clock time of the sweep itself.
    pub elapsed: std::time::Duration,
}

/// Evaluate a sweep scenario against a caller-owned [`EvalCache`] and
/// return the rows instead of writing them — the library entry behind
/// [`crate::serve`]. The daemon owns cache persistence and output
/// policy, so the scenario's `cache`/`output` sections are ignored
/// here; experiment scenarios (multi-file artifact writers) are
/// refused.
pub fn eval_sweep(sc: &Scenario, cache: Arc<EvalCache>) -> Result<SweepEval> {
    sc.validate()?;
    if let ScenarioKind::Experiment { id, .. } = &sc.kind {
        bail!(
            "serve evaluates sweep scenarios; experiment {id:?} writes \
             multi-file artifacts — run it locally with `repro run`"
        );
    }
    let threads = sc.threads.unwrap_or_else(pool::default_threads);
    let sweep_spec = sc.sweep_spec()?;
    let engine =
        SweepEngine::with_cache(Architecture::default_sm(), cache).threads(threads);
    let mapper_calls_before = engine.cache().mapper_calls();
    let all_jobs = sweep_spec.jobs();
    let run = engine.run_jobs_named(&sweep_spec.name, &all_jobs);
    let csv = output::results_csv(&run.results)?.encode();
    Ok(SweepEval {
        name: sc.base_name().to_string(),
        csv,
        points: run.n_points(),
        hits: run.cache_hits,
        misses: run.cache_misses,
        mapper_calls: engine.cache().mapper_calls() - mapper_calls_before,
        elapsed: run.elapsed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;
    use std::path::{Path, PathBuf};

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("www_cim_scenario_exec_{tag}"))
    }

    #[test]
    fn sweep_scenario_writes_the_csv_and_json_sinks() {
        let dir = tmp_dir("sweep");
        let _ = std::fs::remove_dir_all(&dir);
        let sc = Scenario::builder("mini")
            .workloads("synthetic:4")
            .prims("baseline,d1")
            .levels("rf")
            .seed(7)
            .threads(2)
            .out_dir(&dir)
            .build()
            .unwrap();
        execute(&sc, None).unwrap();
        let csv = std::fs::read_to_string(dir.join("mini.csv")).unwrap();
        assert!(csv.starts_with("workload,m,n,k,system,"));
        assert_eq!(csv.lines().count(), 1 + 8, "4 GEMMs x 2 systems + header");
        assert!(dir.join("mini.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batched_sweep_scenario_expands_and_labels_batch_rows() {
        let dir = tmp_dir("batched");
        let _ = std::fs::remove_dir_all(&dir);
        let sc = Scenario::builder("bt")
            .workloads("dlrm")
            .prims("baseline,d1")
            .levels("rf")
            .batch("1,8")
            .seed(7)
            .threads(2)
            .out_dir(&dir)
            .build()
            .unwrap();
        execute(&sc, None).unwrap();
        let csv = std::fs::read_to_string(dir.join("bt.csv")).unwrap();
        // DLRM has 2 unique layers; 2 batches x 2 systems -> 8 rows.
        assert_eq!(csv.lines().count(), 1 + 8);
        assert!(csv.contains("DLRM@b8,8,256,512"), "batched row labeled:\n{csv}");
        assert!(csv.contains("DLRM,1,256,512"), "batch-1 rows keep plain names:\n{csv}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tag_overrides_the_output_base_name() {
        let dir = tmp_dir("tag");
        let _ = std::fs::remove_dir_all(&dir);
        let sc = Scenario::builder("mini")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .seed(7)
            .tag("renamed")
            .out_dir(&dir)
            .build()
            .unwrap();
        execute(&sc, None).unwrap();
        assert!(dir.join("renamed.csv").exists());
        assert!(!dir.join("mini.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_execution_writes_per_shard_summaries_that_merge_back() {
        use crate::sweep::shard::{merge_files, ShardId};
        let dir = tmp_dir("shards");
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |tag: &str| {
            Scenario::builder("sh")
                .workloads("synthetic:5")
                .prims("baseline,d1")
                .levels("rf")
                .seed(7)
                .tag(tag)
                .out_dir(&dir)
                .build()
                .unwrap()
        };
        // Full run.
        execute(&mk("full"), None).unwrap();
        // Two shard runs of the same grid.
        for i in 0..2 {
            execute(&mk("part"), Some(ShardId { index: i, count: 2 })).unwrap();
        }
        let merged = merge_files(&[
            dir.join("part-shard0of2.json"),
            dir.join("part-shard1of2.json"),
        ])
        .unwrap();
        let merged_csv = crate::sweep::output::results_csv(&merged.results)
            .unwrap()
            .encode();
        let full_csv = std::fs::read_to_string(dir.join("full.csv")).unwrap();
        assert_eq!(merged_csv, full_csv, "shard merge must reproduce the full run");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn experiment_scenario_lowers_to_the_equivalent_ctx() {
        let sc = Scenario::builder("fig2")
            .experiment("fig2")
            .quick(true)
            .seed(11)
            .threads(3)
            .out_dir(Path::new("elsewhere"))
            .cache_path(Path::new("elsewhere/cache.bin"))
            .cache_max_bytes(1 << 20)
            .build()
            .unwrap();
        let ctx = experiment_ctx(&sc);
        assert!(ctx.quick);
        assert_eq!(ctx.seed, 11);
        assert_eq!(ctx.threads, 3);
        assert_eq!(ctx.out_dir, PathBuf::from("elsewhere"));
        assert_eq!(ctx.cache_path, Some(PathBuf::from("elsewhere/cache.bin")));
        assert_eq!(ctx.cache_max_bytes, Some(1 << 20));
    }

    #[test]
    fn shard_on_an_experiment_scenario_is_refused() {
        let sc = Scenario::builder("fig2").experiment("fig2").build().unwrap();
        let err = execute(&sc, Some(crate::sweep::ShardId { index: 0, count: 2 }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("--shard"), "{err:#}");
    }
}
