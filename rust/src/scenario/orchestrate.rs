//! The fault-tolerant multi-process sweep orchestrator: `repro
//! orchestrate <scenario.json|name>` in library form.
//!
//! PR 2 made distributed sweeps *possible* (`--shard i/n` + `repro
//! merge`) but left the choreography manual; PR 4's first orchestrator
//! automated it but died wholesale on any shard failure, could
//! deadlock on its own children's output, and only knew how to spawn
//! local subprocesses. This version supervises every shard:
//!
//! * **Streaming child I/O** — two reader threads per shard relay
//!   stdout/stderr line-by-line (prefixed `[shard i/n]`) as the child
//!   produces them. The old sequential `wait_with_output` loop could
//!   deadlock: a later-index shard blocks writing to its full 64 KiB
//!   pipe while the parent is still waiting on shard 0.
//! * **Supervision** — a per-shard wall-clock timeout
//!   ([`OrchestrateOptions::timeout`]) kills and reaps hung shards;
//!   failed, timed-out or invalid-summary shards are re-spawned up to
//!   [`OrchestrateOptions::retries`] times with exponential backoff.
//!   Retrying is safe because shards are deterministic: a retried
//!   shard's summary is byte-identical, so the shard/merge guarantee
//!   holds.
//! * **Resume** — [`OrchestrateOptions::resume`] fingerprints the
//!   existing `<base>-shard<i>of<n>.json` summaries and re-runs only
//!   the missing or invalid shards.
//! * **Manifest** — every orchestration (success or failure) writes
//!   `<base>.orchestrate.json` recording per-shard status, every
//!   attempt's locus/outcome/wall-time, and the sweep fingerprint.
//! * **Pluggable spawning** — the [`Spawner`] trait abstracts *where*
//!   a shard runs: [`LocalSpawner`] forks this binary,
//!   [`SshSpawner`] round-robins shards over `orchestrate.hosts` via
//!   non-interactive ssh (shared-filesystem deployments).
//!
//! Subprocess (not thread) sharding is deliberate: it exercises the
//! same process boundary a multi-host deployment has, and each shard
//! gets its own address space. A shared cache path is safe across
//! concurrent shards: saves serialize on a sidecar lock file and union
//! the entries already on disk (see [`crate::sweep::persist::save`]).
//! Sweep correctness never depends on the cache either way: the merged
//! CSV is assembled from the shard summaries, not the cache file.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::arch::Architecture;
use crate::sweep::{output, shard};
use crate::util::json::Json;
use crate::util::{faults, fsx};

use super::{Scenario, ScenarioKind};

/// Version of the `<base>.orchestrate.json` run-manifest layout.
pub const ORCHESTRATE_FORMAT_VERSION: u32 = 1;

/// Default retry budget: one re-spawn per shard. Deterministic shards
/// make retries safe, so a single transient failure (OOM kill, a
/// dropped ssh connection) should not abort a long sweep.
pub const DEFAULT_RETRIES: u32 = 1;

/// First retry backoff; doubles per subsequent attempt of that shard.
const BACKOFF_BASE: Duration = Duration::from_millis(250);

/// Supervision poll interval.
const POLL: Duration = Duration::from_millis(15);

/// How the orchestrator supervises its shards. Scenario defaults come
/// from [`OrchestrateOptions::from_scenario`]; CLI flags override the
/// individual fields afterwards.
#[derive(Debug, Clone)]
pub struct OrchestrateOptions {
    /// Shard count (one subprocess per shard).
    pub procs: usize,
    /// Kill a shard running longer than this (None = no timeout).
    pub timeout: Option<Duration>,
    /// Re-spawns allowed per shard after a failure/timeout.
    pub retries: u32,
    /// Keep shards whose on-disk summary already validates.
    pub resume: bool,
}

impl OrchestrateOptions {
    /// Options seeded from the scenario's `orchestrate` block.
    pub fn from_scenario(sc: &Scenario, procs: usize) -> OrchestrateOptions {
        OrchestrateOptions {
            procs,
            timeout: sc.orchestrate.timeout_s.map(Duration::from_secs),
            retries: match sc.orchestrate.retries {
                Some(r) => r.min(u64::from(u32::MAX)) as u32,
                None => DEFAULT_RETRIES,
            },
            resume: false,
        }
    }
}

/// Where and how a shard subprocess starts. Implementations must hand
/// back a [`Child`] with piped stdout/stderr (the orchestrator streams
/// both) running `repro run <scenario> --shard i/n`.
pub trait Spawner {
    fn spawn_shard(&self, shard: shard::ShardId, scenario: &Path) -> Result<Child>;

    /// Human-readable execution locus for logs and the manifest
    /// (`"local"`, `"ssh host-a"`, ...).
    fn locus(&self, shard: shard::ShardId) -> String;
}

/// Spawns shards as local subprocesses of one `repro` binary.
#[derive(Debug, Clone)]
pub struct LocalSpawner {
    exe: PathBuf,
}

impl LocalSpawner {
    /// Spawn shards from an explicit binary path (tests pass
    /// `env!("CARGO_BIN_EXE_repro")`; inside an integration test,
    /// `current_exe` would be the *test* binary).
    pub fn new(exe: impl Into<PathBuf>) -> LocalSpawner {
        LocalSpawner { exe: exe.into() }
    }

    /// Spawn shards from the currently running binary.
    pub fn from_current_exe() -> Result<LocalSpawner> {
        let exe = std::env::current_exe()
            .context("locating the repro binary for shard subprocesses")?;
        Ok(LocalSpawner { exe })
    }
}

impl Spawner for LocalSpawner {
    fn spawn_shard(&self, shard: shard::ShardId, scenario: &Path) -> Result<Child> {
        Command::new(&self.exe)
            .arg("run")
            .arg(scenario)
            .arg("--shard")
            .arg(shard.to_string())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning shard {shard}"))
    }

    fn locus(&self, _shard: shard::ShardId) -> String {
        "local".to_string()
    }
}

/// Spawns shards over non-interactive ssh, round-robin across a host
/// list: shard `i` runs on `hosts[i % len]` as
/// `ssh -o BatchMode=yes <host> '<remote_exe>' run '<scenario>' --shard i/n`.
///
/// The scenario file and the output directory must resolve on every
/// host (a shared filesystem, or identical layouts): the remote shard
/// reads the scenario path and writes its summary where the
/// orchestrator will merge it.
#[derive(Debug, Clone)]
pub struct SshSpawner {
    hosts: Vec<String>,
    remote_exe: String,
}

impl SshSpawner {
    pub fn new(hosts: Vec<String>, remote_exe: Option<String>) -> Result<SshSpawner> {
        if hosts.is_empty() {
            bail!("ssh spawner needs at least one host");
        }
        if hosts.iter().any(String::is_empty) {
            bail!("ssh spawner host names must be non-empty");
        }
        Ok(SshSpawner {
            hosts,
            remote_exe: remote_exe.unwrap_or_else(|| "repro".to_string()),
        })
    }

    fn host(&self, shard: shard::ShardId) -> &str {
        &self.hosts[shard.index % self.hosts.len()]
    }
}

/// Single-quote `s` for the remote shell.
fn sh_quote(s: &str) -> String {
    format!("'{}'", s.replace('\'', "'\\''"))
}

impl Spawner for SshSpawner {
    fn spawn_shard(&self, shard: shard::ShardId, scenario: &Path) -> Result<Child> {
        let remote_cmd = format!(
            "{} run {} --shard {}",
            sh_quote(&self.remote_exe),
            sh_quote(&scenario.to_string_lossy()),
            shard
        );
        Command::new("ssh")
            .arg("-o")
            .arg("BatchMode=yes")
            .arg(self.host(shard))
            .arg(remote_cmd)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning shard {shard} via ssh {}", self.host(shard)))
    }

    fn locus(&self, shard: shard::ShardId) -> String {
        format!("ssh {}", self.host(shard))
    }
}

/// One spawn of one shard, as recorded in the run manifest.
#[derive(Debug, Clone)]
struct Attempt {
    locus: String,
    /// `ok`, `exit:<code/signal>`, `timeout`, `wait-error: ...` or
    /// `invalid-summary: ...`.
    outcome: String,
    wall_s: f64,
}

/// Supervision state of one shard.
enum State {
    /// Waiting to (re)spawn; not before the backoff deadline.
    Pending { not_before: Instant },
    Running {
        child: Child,
        started: Instant,
        readers: Vec<thread::JoinHandle<()>>,
    },
    /// Resume found a valid summary; never spawned.
    Skipped,
    /// Exited 0 with a validated summary.
    Done,
    /// Retry budget exhausted.
    GivenUp,
}

struct Task {
    id: shard::ShardId,
    state: State,
    spawned: u32,
    attempts: Vec<Attempt>,
}

impl Task {
    fn status(&self) -> &'static str {
        match &self.state {
            State::Skipped => "skipped",
            State::Done => "ok",
            State::GivenUp => {
                if self.attempts.last().is_some_and(|a| a.outcome == "timeout") {
                    "timeout"
                } else {
                    "failed"
                }
            }
            State::Pending { .. } | State::Running { .. } => "aborted",
        }
    }
}

/// What a finished shard's summary file must agree with.
struct Expected {
    name: String,
    fingerprint: String,
    points_total: usize,
}

impl Expected {
    fn check(&self, path: &Path, id: shard::ShardId) -> Result<()> {
        let s = shard::read_shard_file(path)?;
        if s.sweep != self.name {
            bail!("summary names sweep {:?}, expected {:?}", s.sweep, self.name);
        }
        if s.fingerprint != self.fingerprint {
            bail!(
                "summary fingerprint {} does not match the scenario's {}",
                s.fingerprint,
                self.fingerprint
            );
        }
        if s.points_total != self.points_total {
            bail!(
                "summary points_total {} does not match the scenario's {}",
                s.points_total,
                self.points_total
            );
        }
        if s.shard != id {
            bail!("summary carries shard identity {}, expected {id}", s.shard);
        }
        Ok(())
    }
}

/// Relay one child stream line-by-line under the shard prefix. Reader
/// threads (instead of a post-exit drain) are what keep a chatty shard
/// from blocking on a full pipe while the parent waits on another.
fn stream_reader<R: std::io::Read + Send + 'static>(
    source: R,
    prefix: String,
    to_stderr: bool,
) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let reader = std::io::BufReader::new(source);
        for line in reader.lines() {
            match line {
                Ok(line) => {
                    if to_stderr {
                        eprintln!("{prefix} {line}");
                    } else {
                        println!("{prefix} {line}");
                    }
                }
                Err(_) => break,
            }
        }
    })
}

fn spawn_task(task: &mut Task, spawner: &dyn Spawner, sc_path: &Path) -> Result<()> {
    // Chaos hook: a deterministic stand-in for fork/exec failure
    // (EAGAIN, a dead ssh host) — exercises the retry/giving-up path.
    if faults::check("shard.spawn") == faults::FaultAction::Fail {
        bail!("injected fault: shard.spawn refusing to spawn shard {}", task.id);
    }
    let mut child = spawner.spawn_shard(task.id, sc_path)?;
    let mut readers = Vec::with_capacity(2);
    let prefix = format!("[shard {}]", task.id);
    if let Some(stdout) = child.stdout.take() {
        readers.push(stream_reader(stdout, prefix.clone(), false));
    }
    if let Some(stderr) = child.stderr.take() {
        readers.push(stream_reader(stderr, prefix, true));
    }
    task.spawned += 1;
    task.state = State::Running {
        child,
        started: Instant::now(),
        readers,
    };
    Ok(())
}

/// Kill and reap a running child, joining its reader threads.
fn reap(child: &mut Child, readers: Vec<thread::JoinHandle<()>>) {
    let _ = child.kill();
    let _ = child.wait();
    for r in readers {
        let _ = r.join();
    }
}

/// Kill every still-running shard (the spawn-error cleanup path: no
/// zombies survive a failed orchestration).
fn kill_all(tasks: &mut [Task]) {
    for task in tasks {
        if !matches!(task.state, State::Running { .. }) {
            continue;
        }
        if let State::Running { child, readers, started } =
            std::mem::replace(&mut task.state, State::GivenUp)
        {
            let mut child = child;
            let wall_s = started.elapsed().as_secs_f64();
            reap(&mut child, readers);
            task.attempts.push(Attempt {
                locus: String::new(),
                outcome: "killed: orchestration aborted".to_string(),
                wall_s,
            });
        }
    }
}

/// Record a failed attempt and either schedule a retry (exponential
/// backoff) or give the shard up.
fn after_failure(task: &mut Task, opts: &OrchestrateOptions, outcome: String, wall_s: f64) {
    task.attempts.push(Attempt { locus: String::new(), outcome: outcome.clone(), wall_s });
    if task.spawned <= opts.retries {
        let backoff = BACKOFF_BASE * 2u32.saturating_pow(task.spawned.saturating_sub(1));
        println!(
            "orchestrate: shard {} attempt {} failed ({outcome}); retrying in {}ms",
            task.id,
            task.spawned,
            backoff.as_millis()
        );
        task.state = State::Pending { not_before: Instant::now() + backoff };
    } else {
        println!(
            "orchestrate: shard {} failed after {} attempt(s) ({outcome}); giving up",
            task.id, task.spawned
        );
        task.state = State::GivenUp;
    }
}

/// Drive every shard to Done/Skipped/GivenUp. Returns Err only for
/// orchestration-level errors (a spawn failure) — and only after every
/// already-running child has been killed and reaped. Per-shard *run*
/// failures drain normally so `--resume` can pick up the survivors.
fn supervise(
    tasks: &mut [Task],
    opts: &OrchestrateOptions,
    spawner: &dyn Spawner,
    sc_path: &Path,
    shard_path: &dyn Fn(shard::ShardId) -> PathBuf,
    expected: &Expected,
) -> Result<()> {
    loop {
        let mut active = false;
        for i in 0..tasks.len() {
            let task = &mut tasks[i];
            match &mut task.state {
                State::Skipped | State::Done | State::GivenUp => {}
                State::Pending { not_before } => {
                    active = true;
                    if Instant::now() >= *not_before {
                        let locus = spawner.locus(task.id);
                        if let Err(e) = spawn_task(task, spawner, sc_path) {
                            task.attempts.push(Attempt {
                                locus,
                                outcome: format!("spawn-error: {e:#}"),
                                wall_s: 0.0,
                            });
                            task.state = State::GivenUp;
                            kill_all(tasks);
                            return Err(e);
                        }
                    }
                }
                State::Running { child, started, readers } => {
                    active = true;
                    let wall = started.elapsed();
                    match child.try_wait() {
                        Ok(None) => {
                            // Still running; enforce the timeout.
                            if opts.timeout.is_some_and(|t| wall > t) {
                                let readers = std::mem::take(readers);
                                reap(child, readers);
                                let locus = spawner.locus(task.id);
                                after_failure(
                                    task,
                                    opts,
                                    "timeout".to_string(),
                                    wall.as_secs_f64(),
                                );
                                stamp_locus(task, locus);
                            }
                        }
                        Ok(Some(status)) => {
                            let readers = std::mem::take(readers);
                            for r in readers {
                                let _ = r.join();
                            }
                            let locus = spawner.locus(task.id);
                            if status.success() {
                                // Exit 0 still only counts with a
                                // valid summary on disk.
                                match expected.check(&shard_path(task.id), task.id) {
                                    Ok(()) => {
                                        task.attempts.push(Attempt {
                                            locus,
                                            outcome: "ok".to_string(),
                                            wall_s: wall.as_secs_f64(),
                                        });
                                        task.state = State::Done;
                                    }
                                    Err(e) => {
                                        after_failure(
                                            task,
                                            opts,
                                            format!("invalid-summary: {e:#}"),
                                            wall.as_secs_f64(),
                                        );
                                        stamp_locus(task, locus);
                                    }
                                }
                            } else {
                                after_failure(
                                    task,
                                    opts,
                                    format!("exit:{status}"),
                                    wall.as_secs_f64(),
                                );
                                stamp_locus(task, locus);
                            }
                        }
                        Err(e) => {
                            let readers = std::mem::take(readers);
                            reap(child, readers);
                            let locus = spawner.locus(task.id);
                            after_failure(
                                task,
                                opts,
                                format!("wait-error: {e}"),
                                wall.as_secs_f64(),
                            );
                            stamp_locus(task, locus);
                        }
                    }
                }
            }
        }
        if !active {
            return Ok(());
        }
        thread::sleep(POLL);
    }
}

/// `after_failure` records the attempt before it knows the locus (it
/// borrows the task mutably); fill it in on the freshly pushed record.
fn stamp_locus(task: &mut Task, locus: String) {
    if let Some(last) = task.attempts.last_mut() {
        if last.locus.is_empty() {
            last.locus = locus;
        }
    }
}

/// Encode the run manifest.
fn manifest_json(
    sc: &Scenario,
    expected: &Expected,
    opts: &OrchestrateOptions,
    tasks: &[Task],
    status: &str,
) -> String {
    let shards: Vec<Json> = tasks
        .iter()
        .map(|t| {
            let attempts: Vec<Json> = t
                .attempts
                .iter()
                .map(|a| {
                    Json::Obj(vec![
                        ("locus".to_string(), Json::Str(a.locus.clone())),
                        ("outcome".to_string(), Json::Str(a.outcome.clone())),
                        ("wall_s".to_string(), Json::Num(a.wall_s)),
                    ])
                })
                .collect();
            Json::Obj(vec![
                ("index".to_string(), Json::Num(t.id.index as f64)),
                ("status".to_string(), Json::Str(t.status().to_string())),
                ("attempts".to_string(), Json::Arr(attempts)),
            ])
        })
        .collect();
    Json::Obj(vec![
        (
            "orchestrate_format".to_string(),
            Json::Num(f64::from(ORCHESTRATE_FORMAT_VERSION)),
        ),
        ("scenario".to_string(), Json::Str(sc.name.clone())),
        ("base".to_string(), Json::Str(sc.base_name().to_string())),
        (
            "fingerprint".to_string(),
            Json::Str(expected.fingerprint.clone()),
        ),
        ("procs".to_string(), Json::Num(opts.procs as f64)),
        ("status".to_string(), Json::Str(status.to_string())),
        ("shards".to_string(), Json::Arr(shards)),
    ])
    .encode()
}

/// Run `sc` as shard subprocesses of this binary and merge the
/// results, with the scenario's own supervision policy. Sweep
/// scenarios only — experiments parallelize internally.
pub fn orchestrate(sc: &Scenario, procs: usize) -> Result<()> {
    let opts = OrchestrateOptions::from_scenario(sc, procs);
    orchestrate_scenario(sc, &opts)
}

/// [`orchestrate`] with explicit options (the CLI path: flag overrides
/// already folded in). Picks the spawner from the scenario:
/// `orchestrate.hosts` → ssh, else local subprocesses.
pub fn orchestrate_scenario(sc: &Scenario, opts: &OrchestrateOptions) -> Result<()> {
    if sc.orchestrate.hosts.is_empty() {
        let spawner = LocalSpawner::from_current_exe()?;
        orchestrate_with(sc, opts, &spawner)
    } else {
        let spawner = SshSpawner::new(
            sc.orchestrate.hosts.clone(),
            sc.orchestrate.remote_exe.clone(),
        )?;
        orchestrate_with(sc, opts, &spawner)
    }
}

/// The full orchestration against any [`Spawner`]: validate + persist
/// the scenario, (optionally) adopt resumable shard summaries,
/// supervise the rest to completion, write the run manifest, merge.
pub fn orchestrate_with(
    sc: &Scenario,
    opts: &OrchestrateOptions,
    spawner: &dyn Spawner,
) -> Result<()> {
    if let ScenarioKind::Experiment { id, .. } = &sc.kind {
        bail!(
            "orchestrate drives sweep scenarios; experiment {id:?} already \
             parallelizes internally — use `repro run {id}`"
        );
    }
    let procs = opts.procs;
    if procs == 0 {
        bail!("--procs must be >= 1");
    }
    // Lowering doubles as validation for a sweep scenario (a scenario
    // that lowers is a scenario that runs); the grid here feeds the
    // point count and the fingerprint — each shard expands its own.
    let spec = sc.sweep_spec()?;
    sc.validate()?;
    let expected = Expected {
        name: spec.name.clone(),
        fingerprint: shard::sweep_fingerprint(&Architecture::default_sm(), &spec),
        points_total: spec.n_points(),
    };

    // Persist the canonical scenario the shard subprocesses will run:
    // the children re-load exactly what we validated, and the file
    // documents the run afterwards.
    let out_dir = &sc.output.dir;
    let base = sc.base_name();
    let sc_path = out_dir.join(format!("{base}.scenario.json"));
    sc.write(&sc_path)?;
    let shard_path = |id: shard::ShardId| -> PathBuf {
        out_dir.join(format!("{base}-{}.json", id.file_tag()))
    };
    println!(
        "orchestrate: {procs} shard process(es) over {} grid points ({})",
        expected.points_total,
        sc_path.display()
    );

    let mut tasks: Vec<Task> = (0..procs)
        .map(|index| Task {
            id: shard::ShardId { index, count: procs },
            state: State::Pending { not_before: Instant::now() },
            spawned: 0,
            attempts: Vec::new(),
        })
        .collect();

    // Resume: a shard whose summary already validates against this
    // scenario (format, fingerprint, identity, result count) is
    // adopted as-is; anything missing or invalid re-runs.
    if opts.resume {
        for task in &mut tasks {
            let path = shard_path(task.id);
            if path.exists() {
                match expected.check(&path, task.id) {
                    Ok(()) => {
                        println!("orchestrate: shard {} already valid; skipping", task.id);
                        task.state = State::Skipped;
                    }
                    Err(e) => {
                        println!(
                            "orchestrate: shard {} summary invalid ({e:#}); re-running",
                            task.id
                        );
                    }
                }
            }
        }
    }

    let run = supervise(&mut tasks, opts, spawner, &sc_path, &shard_path, &expected);
    let failed: Vec<String> = tasks
        .iter()
        .filter(|t| !matches!(t.state, State::Done | State::Skipped))
        .map(|t| format!("shard {} {}", t.id, t.status()))
        .collect();
    let status = if run.is_ok() && failed.is_empty() { "ok" } else { "failed" };

    // The manifest documents every orchestration, failures included —
    // that is what makes an aborted run diagnosable and resumable.
    let manifest_path = out_dir.join(format!("{base}.orchestrate.json"));
    fsx::write_atomic(&manifest_path, &manifest_json(sc, &expected, opts, &tasks, status))
        .with_context(|| format!("writing run manifest {}", manifest_path.display()))?;
    println!("[manifest] {}", manifest_path.display());

    run?;
    if !failed.is_empty() {
        bail!(
            "orchestrate failed: {} (resume with `repro orchestrate ... --resume` \
             after fixing the cause; see {})",
            failed.join("; "),
            manifest_path.display()
        );
    }

    // Merge the per-shard summaries back into the unsharded artifacts
    // (the validated, byte-identical combine of `repro merge`).
    let shard_paths: Vec<PathBuf> = tasks.iter().map(|t| shard_path(t.id)).collect();
    let merged = shard::merge_files(&shard_paths)?;
    println!(
        "orchestrate: merged {} shard(s) of {:?}: {} points (fingerprint {})",
        merged.shard_count,
        merged.spec_name,
        merged.results.len(),
        merged.fingerprint
    );
    print!("{}", output::summary_table(&merged.results));

    let csv = output::results_csv(&merged.results)?;
    let csv_path = out_dir.join(format!("{base}.csv"));
    csv.write(&csv_path)?;
    println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
    let json_path = out_dir.join(format!("{base}.json"));
    fsx::write_atomic(&json_path, &shard::merged_json(&merged))
        .with_context(|| format!("writing merged summary {}", json_path.display()))?;
    println!("[json] merged summary -> {}", json_path.display());
    if sc.output.stdout_json {
        print!("{}", shard::merged_json(&merged));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    #[test]
    fn experiment_scenarios_and_zero_procs_are_refused() {
        let exp = Scenario::builder("fig2").experiment("fig2").build().unwrap();
        let err = orchestrate(&exp, 2).unwrap_err();
        assert!(format!("{err:#}").contains("sweep scenarios"), "{err:#}");
        let sweep = Scenario::builder("s")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .build()
            .unwrap();
        assert!(orchestrate(&sweep, 0).is_err());
    }

    #[test]
    fn options_inherit_the_scenario_orchestrate_block() {
        let sc = Scenario::builder("o")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .shard_timeout_s(90)
            .shard_retries(4)
            .build()
            .unwrap();
        let opts = OrchestrateOptions::from_scenario(&sc, 3);
        assert_eq!(opts.procs, 3);
        assert_eq!(opts.timeout, Some(Duration::from_secs(90)));
        assert_eq!(opts.retries, 4);
        assert!(!opts.resume);
        let plain = Scenario::builder("p")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .build()
            .unwrap();
        let opts = OrchestrateOptions::from_scenario(&plain, 2);
        assert_eq!(opts.timeout, None);
        assert_eq!(opts.retries, DEFAULT_RETRIES);
    }

    #[test]
    fn ssh_spawner_round_robins_hosts_and_quotes() {
        let sp = SshSpawner::new(
            vec!["a".to_string(), "b".to_string()],
            Some("/opt/repro".to_string()),
        )
        .unwrap();
        let id = |index| shard::ShardId { index, count: 5 };
        assert_eq!(sp.locus(id(0)), "ssh a");
        assert_eq!(sp.locus(id(1)), "ssh b");
        assert_eq!(sp.locus(id(4)), "ssh a");
        assert!(SshSpawner::new(vec![], None).is_err());
        assert_eq!(sh_quote("it's"), "'it'\\''s'");
    }
}
