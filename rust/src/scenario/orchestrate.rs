//! The multi-process sweep orchestrator: `repro orchestrate
//! <scenario.json|name> --procs n` in library form.
//!
//! PR 2 made distributed sweeps *possible* (`--shard i/n` + `repro
//! merge`) but left the choreography manual. The orchestrator closes
//! the loop: it writes the canonical scenario file, spawns one `repro
//! run <scenario> --shard i/n` subprocess per shard, waits for all of
//! them, and merges the per-shard summaries into the final
//! `<base>.csv` / `<base>.json` — byte-identical to a single-process
//! `repro run` of the same scenario (the shard/merge guarantee, now
//! exercised end-to-end in CI).
//!
//! Subprocess (not thread) sharding is deliberate: it exercises the
//! same process boundary a multi-host deployment has, and each shard
//! gets its own address space. A shared cache path is safe but only
//! best-effort across *concurrent* shards: each save merges the
//! entries already on disk, yet the final rename is last-writer-wins
//! (see [`crate::sweep::persist::save`]), so shards finishing at the
//! same instant can drop each other's entries from the file — they are
//! recomputed on the next run, never corrupted. Sweep correctness
//! never depends on the cache: the merged CSV is assembled from the
//! shard summaries, not the cache file.

use std::path::PathBuf;
use std::process::{Command, Stdio};

use anyhow::{bail, Context, Result};

use crate::sweep::{output, shard};

use super::{Scenario, ScenarioKind};

/// Run `sc` as `procs` shard subprocesses of this binary and merge the
/// results. Sweep scenarios only — experiments parallelize internally.
pub fn orchestrate(sc: &Scenario, procs: usize) -> Result<()> {
    if let ScenarioKind::Experiment { id, .. } = &sc.kind {
        bail!(
            "orchestrate drives sweep scenarios; experiment {id:?} already \
             parallelizes internally — use `repro run {id}`"
        );
    }
    if procs == 0 {
        bail!("--procs must be >= 1");
    }
    // Lowering doubles as validation for a sweep scenario (a scenario
    // that lowers is a scenario that runs); the grid is only needed
    // for the point count here — each shard expands its own.
    let spec = sc.sweep_spec()?;
    sc.validate()?;

    // Persist the canonical scenario the shard subprocesses will run:
    // the children re-load exactly what we validated, and the file
    // documents the run afterwards.
    let out_dir = &sc.output.dir;
    let base = sc.base_name();
    let sc_path = out_dir.join(format!("{base}.scenario.json"));
    sc.write(&sc_path)?;
    let exe = std::env::current_exe()
        .context("locating the repro binary for shard subprocesses")?;
    println!(
        "orchestrate: {procs} shard process(es) over {} grid points ({})",
        spec.n_points(),
        sc_path.display()
    );

    // Spawn every shard, then collect: shards run concurrently and a
    // failure anywhere fails the whole orchestration (after every
    // child has been reaped — no zombies, and all diagnostics print).
    let mut children = Vec::with_capacity(procs);
    for index in 0..procs {
        let child = Command::new(&exe)
            .arg("run")
            .arg(&sc_path)
            .arg("--shard")
            .arg(format!("{index}/{procs}"))
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning shard {index}/{procs}"))?;
        children.push((index, child));
    }
    let mut failures = Vec::new();
    for (index, child) in children {
        let out = child
            .wait_with_output()
            .with_context(|| format!("waiting for shard {index}/{procs}"))?;
        // Replay the child's output prefixed with its shard identity,
        // so concurrent shards stay readable.
        for line in String::from_utf8_lossy(&out.stdout).lines() {
            println!("[shard {index}/{procs}] {line}");
        }
        for line in String::from_utf8_lossy(&out.stderr).lines() {
            eprintln!("[shard {index}/{procs}] {line}");
        }
        if !out.status.success() {
            failures.push(format!("shard {index}/{procs} exited with {}", out.status));
        }
    }
    if !failures.is_empty() {
        bail!("orchestrate failed: {}", failures.join("; "));
    }

    // Merge the per-shard summaries back into the unsharded artifacts
    // (the validated, byte-identical combine of `repro merge`).
    let shard_paths: Vec<PathBuf> = (0..procs)
        .map(|index| {
            out_dir.join(format!(
                "{base}-{}.json",
                shard::ShardId {
                    index,
                    count: procs
                }
                .file_tag()
            ))
        })
        .collect();
    let merged = shard::merge_files(&shard_paths)?;
    println!(
        "orchestrate: merged {} shard(s) of {:?}: {} points (fingerprint {})",
        merged.shard_count,
        merged.spec_name,
        merged.results.len(),
        merged.fingerprint
    );
    print!("{}", output::summary_table(&merged.results));

    let csv = output::results_csv(&merged.results)?;
    let csv_path = out_dir.join(format!("{base}.csv"));
    csv.write(&csv_path)?;
    println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
    let json_path = out_dir.join(format!("{base}.json"));
    std::fs::write(&json_path, shard::merged_json(&merged))
        .with_context(|| format!("writing merged summary {}", json_path.display()))?;
    println!("[json] merged summary -> {}", json_path.display());
    if sc.output.stdout_json {
        print!("{}", shard::merged_json(&merged));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    #[test]
    fn experiment_scenarios_and_zero_procs_are_refused() {
        let exp = Scenario::builder("fig2").experiment("fig2").build().unwrap();
        let err = orchestrate(&exp, 2).unwrap_err();
        assert!(format!("{err:#}").contains("sweep scenarios"), "{err:#}");
        let sweep = Scenario::builder("s")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .build()
            .unwrap();
        assert!(orchestrate(&sweep, 0).is_err());
    }
}
