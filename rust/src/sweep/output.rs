//! Sweep result sinks: the CSV mirror, paper-style summary tables, and
//! a machine-readable JSON summary (hand-rolled encoder — serde is
//! unavailable offline).

use std::path::Path;

use anyhow::Result;

use crate::util::csv::Csv;
use crate::util::stats::geomean;
use crate::util::table::Table;

use super::engine::SweepRun;
use super::spec::SweepResult;

/// Per-point CSV mirror: one row per evaluated grid point.
pub fn results_csv(results: &[SweepResult]) -> Result<Csv> {
    let mut csv = Csv::new(vec![
        "workload",
        "m",
        "n",
        "k",
        "system",
        "sms",
        "tops_w",
        "gflops",
        "utilization",
        "energy_pj",
        "total_cycles",
        "bound",
    ]);
    for r in results {
        csv.row(vec![
            r.workload.clone(),
            r.gemm.m.to_string(),
            r.gemm.n.to_string(),
            r.gemm.k.to_string(),
            r.system.clone(),
            r.sms.to_string(),
            format!("{:.4}", r.metrics.tops_per_watt),
            format!("{:.1}", r.metrics.gflops),
            format!("{:.4}", r.metrics.utilization),
            format!("{:.1}", r.metrics.energy_pj),
            r.metrics.total_cycles.to_string(),
            if r.metrics.memory_bound() { "memory" } else { "compute" }.to_string(),
        ])?;
    }
    Ok(csv)
}

/// Group keys `(system, sms)` in first-appearance order.
fn group_order(results: &[SweepResult]) -> Vec<(String, u64)> {
    let mut order: Vec<(String, u64)> = Vec::new();
    for r in results {
        let key = (r.system.clone(), r.sms);
        if !order.contains(&key) {
            order.push(key);
        }
    }
    order
}

/// Per-group aggregate of a sweep (one row of the summary table / one
/// entry of the JSON `systems` array).
#[derive(Debug, Clone)]
pub struct SystemSummary {
    pub system: String,
    pub sms: u64,
    pub points: usize,
    pub geomean_tops_w: f64,
    pub geomean_gflops: f64,
    pub mean_utilization: f64,
    pub peak_gflops: f64,
}

/// Aggregate results per `(system, sms)` group.
pub fn summarize(results: &[SweepResult]) -> Vec<SystemSummary> {
    group_order(results)
        .into_iter()
        .map(|(system, sms)| {
            let group: Vec<&SweepResult> = results
                .iter()
                .filter(|r| r.system == system && r.sms == sms)
                .collect();
            let t: Vec<f64> = group.iter().map(|r| r.metrics.tops_per_watt).collect();
            let f: Vec<f64> = group.iter().map(|r| r.metrics.gflops).collect();
            let u: f64 =
                group.iter().map(|r| r.metrics.utilization).sum::<f64>() / group.len() as f64;
            SystemSummary {
                system,
                sms,
                points: group.len(),
                geomean_tops_w: geomean(&t),
                geomean_gflops: geomean(&f),
                mean_utilization: u,
                peak_gflops: f.iter().cloned().fold(0.0, f64::max),
            }
        })
        .collect()
}

/// Paper-style summary table, one row per `(system, sms)` group.
pub fn summary_table(results: &[SweepResult]) -> Table {
    let mut t = Table::new(vec![
        "system",
        "SMs",
        "points",
        "geomean TOPS/W",
        "geomean GFLOPS",
        "mean util",
        "peak GFLOPS",
    ]);
    for s in summarize(results) {
        t.row(vec![
            s.system,
            s.sms.to_string(),
            s.points.to_string(),
            format!("{:.3}", s.geomean_tops_w),
            format!("{:.0}", s.geomean_gflops),
            format!("{:.2}", s.mean_utilization),
            format!("{:.0}", s.peak_gflops),
        ]);
    }
    t
}

/// Per-point detail table (for small grids).
pub fn detail_table(results: &[SweepResult]) -> Table {
    let mut t = Table::new(vec![
        "workload", "GEMM", "system", "SMs", "TOPS/W", "GFLOPS", "util", "bound",
    ]);
    for r in results {
        t.row(vec![
            r.workload.clone(),
            r.gemm.to_string(),
            r.system.clone(),
            r.sms.to_string(),
            format!("{:.3}", r.metrics.tops_per_watt),
            format!("{:.0}", r.metrics.gflops),
            format!("{:.2}", r.metrics.utilization),
            if r.metrics.memory_bound() { "memory" } else { "compute" }.to_string(),
        ]);
    }
    t
}

/// Escape a string for a JSON string literal (shared with the shard
/// summary writer; canonical implementation lives next to the reader
/// in [`crate::util::json`] so the pair can never drift).
pub(crate) fn json_escape(s: &str) -> String {
    crate::util::json::escape(s)
}

pub(crate) fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

/// Machine-readable summary of a sweep run.
pub fn json_summary(run: &SweepRun) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"sweep\": \"{}\",\n", json_escape(&run.spec_name)));
    out.push_str(&format!("  \"points\": {},\n", run.n_points()));
    out.push_str(&format!("  \"threads\": {},\n", run.threads));
    out.push_str(&format!(
        "  \"elapsed_s\": {},\n",
        json_f64(run.elapsed.as_secs_f64())
    ));
    out.push_str(&format!(
        "  \"cache\": {{\"hits\": {}, \"misses\": {}}},\n",
        run.cache_hits, run.cache_misses
    ));
    out.push_str("  \"systems\": [\n");
    let summaries = summarize(&run.results);
    for (i, s) in summaries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"system\": \"{}\", \"sms\": {}, \"points\": {}, \
             \"geomean_tops_w\": {}, \"geomean_gflops\": {}, \
             \"mean_utilization\": {}, \"peak_gflops\": {}}}{}\n",
            json_escape(&s.system),
            s.sms,
            s.points,
            json_f64(s.geomean_tops_w),
            json_f64(s.geomean_gflops),
            json_f64(s.mean_utilization),
            json_f64(s.peak_gflops),
            if i + 1 < summaries.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON summary to `path`, creating parent directories.
pub fn write_json_summary(run: &SweepRun, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, json_summary(run))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;
    use crate::coordinator::jobs::SystemSpec;
    use crate::sweep::engine::SweepEngine;
    use crate::sweep::spec::SweepSpec;
    use crate::workload::Gemm;

    fn run() -> SweepRun {
        let spec = SweepSpec::new("unit-output")
            .workload("w", vec![Gemm::new(64, 64, 64), Gemm::new(256, 256, 256)])
            .systems(vec![
                SystemSpec::Baseline,
                SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            ]);
        SweepEngine::new(Architecture::default_sm()).run_spec(&spec)
    }

    #[test]
    fn csv_has_one_row_per_point() {
        let run = run();
        let csv = results_csv(&run.results).unwrap();
        assert_eq!(csv.n_rows(), run.n_points());
        let text = csv.encode();
        assert!(text.starts_with("workload,m,n,k,system,sms,"));
        assert!(text.contains("Tensor-core"));
    }

    #[test]
    fn summary_groups_by_system() {
        let run = run();
        let s = summarize(&run.results);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].system, "Tensor-core");
        assert_eq!(s[0].points, 2);
        assert!(s.iter().all(|g| g.geomean_tops_w > 0.0));
        assert_eq!(summary_table(&run.results).n_rows(), 2);
        assert_eq!(detail_table(&run.results).n_rows(), 4);
    }

    #[test]
    fn json_summary_is_well_formed() {
        let run = run();
        let j = json_summary(&run);
        assert!(j.contains("\"sweep\": \"unit-output\""));
        assert!(j.contains("\"points\": 4"));
        assert!(j.contains("\"systems\": ["));
        assert!(j.contains("Tensor-core"));
        // braces balance
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
        assert_eq!(json_f64(f64::NAN), "null");
    }
}
