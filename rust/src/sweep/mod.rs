//! Design-space sweep engine (the framework's DSE subsystem).
//!
//! The paper's What/When/Where questions are answered by sweeping grids
//! of (workload × CiM primitive × memory level × mapper × SM count)
//! through the analytical cost model. This module provides that sweep
//! as a reusable engine instead of per-figure loops:
//!
//! * [`spec::SweepSpec`] — a declarative cartesian grid that expands
//!   into an evaluation job list ([`spec::SweepJob`]);
//! * [`cache::EvalCache`] — a sharded memoization cache keyed by
//!   (system fingerprint, GEMM) holding `(Mapping, Metrics)` entries,
//!   so duplicate points across experiments are scored once per process
//!   and post-hoc analyses reuse cached mappings;
//! * [`engine::SweepEngine`] — the parallel executor over
//!   [`crate::util::pool`], deterministic across thread counts;
//! * [`persist`] — versioned disk persistence of the cache
//!   (`--cache`), embedding the cost-model version so stale files are
//!   discarded, not served;
//! * [`shard`] — deterministic `--shard i/n` slicing of the job list,
//!   fingerprint-tagged per-shard summaries and the `repro merge`
//!   validator/combiner;
//! * [`output`] — CSV mirrors, summary tables and a machine-readable
//!   JSON summary.
//!
//! The experiment regenerators ([`crate::experiments`]), the
//! coordinator grid ([`crate::coordinator::jobs::Grid`]) and the
//! `repro sweep` CLI all evaluate through this engine.
//!
//! ```no_run
//! use www_cim::arch::Architecture;
//! use www_cim::cim::CimPrimitive;
//! use www_cim::coordinator::jobs::SystemSpec;
//! use www_cim::sweep::{SweepEngine, SweepSpec};
//! use www_cim::workload::synthetic;
//!
//! let spec = SweepSpec::new("example")
//!     .workload("synthetic", synthetic::dataset(7, 100))
//!     .systems(vec![
//!         SystemSpec::Baseline,
//!         SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
//!     ]);
//! let run = SweepEngine::new(Architecture::default_sm()).run_spec(&spec);
//! println!("{} points in {:?}", run.n_points(), run.elapsed);
//! ```

pub mod cache;
pub mod engine;
pub mod output;
pub mod persist;
pub mod shard;
pub mod spec;

pub use cache::{
    arch_fingerprint, point_key, spec_fingerprint, system_fingerprint, CacheEntry, EvalCache,
    BASELINE_MAPPER_FP,
};
pub use engine::{SweepEngine, SweepRun};
pub use persist::{CacheLoad, CACHE_FORMAT_VERSION};
pub use shard::{sweep_fingerprint, MergedSweep, ShardId};
pub use spec::{MapperChoice, SweepJob, SweepResult, SweepSpec};
