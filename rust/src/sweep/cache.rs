//! Memoization cache for design-space evaluations.
//!
//! Every point of the What/When/Where design space is identified by a
//! *system fingerprint* — a stable string naming the system
//! configuration (integration point + primitive + SM count + mapper) —
//! plus the GEMM shape. The analytical evaluation of a point is a pure
//! function of that key, so duplicate points across experiments (fig9's
//! synthetic sweep, fig11/fig12's workload grids, the zoo, the serving
//! router all revisit the same (system, GEMM) pairs) are scored exactly
//! once per process.
//!
//! Fingerprints must be *injective*: now that cache entries persist
//! across runs ([`super::persist`]), a key collision is silent
//! cross-run data corruption, not just an unlucky in-process hit. Every
//! floating-point model parameter is therefore fingerprinted by its
//! exact bit pattern ([`f64::to_bits`] hex) rather than a truncated
//! decimal rendering.
//!
//! The cache is sharded: each shard is an independent `Mutex<HashMap>`,
//! picked by key hash, so parallel sweeps do not serialize on one lock.
//! Within a shard the map is two-level (point key → GEMM → entry), so
//! lookups borrow the caller's `&str` key instead of forcing an owned
//! `String` per probe. An entry is a [`CacheEntry`]: the metrics *and*
//! the [`Mapping`] that produced them (None for baseline points), so
//! consumers can run post-hoc cost analyses on cached mappings without
//! re-invoking the mapper.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use crate::cim::isoarea;
use crate::coordinator::jobs::SystemSpec;
use crate::cost::Metrics;
use crate::mapping::Mapping;
use crate::workload::Gemm;

/// Number of independent shards (power of two).
const SHARDS: usize = 16;

/// The wall-clock second (unix time) this process first touched an
/// [`EvalCache`]. One stamp per *process*, not per cache or per access:
/// every entry used in a run carries the same last-used value, so
/// serializing a cache stays deterministic within a process (the
/// byte-identity properties the persistence tests pin), while across
/// runs the stamps order entries by recency — the signal the
/// `max_bytes` LRU eviction in [`super::persist`] trims on.
fn process_stamp() -> u64 {
    static STAMP: OnceLock<u64> = OnceLock::new();
    *STAMP.get_or_init(|| {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0)
    })
}

/// Mapper fingerprint fragment for baseline points: the mapper cannot
/// influence the tensor-core baseline, so every mapper choice shares
/// one baseline cache entry under this marker.
pub const BASELINE_MAPPER_FP: &str = "n/a";

/// Exact fingerprint fragment of one `f64` model parameter: the IEEE-754
/// bit pattern in hex. Unlike a `{:.4}`-style decimal rendering this is
/// injective — two parameters differing by even 1 ulp fingerprint
/// differently, so they can never alias one persisted cache entry.
pub fn f64_bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Stable fingerprint of an [`Architecture`]: capacities, bandwidths,
/// per-element energies and baseline peak. Cached metrics are only
/// valid for the architecture they were computed on, so this prefixes
/// every cache key (engines over different architectures may share one
/// [`EvalCache`] without cross-talk).
pub fn arch_fingerprint(arch: &Architecture) -> String {
    let lv = |l: MemLevel| {
        let s = arch.level(l);
        format!(
            "{}:{}:{}",
            s.capacity_bytes,
            f64_bits_hex(s.bandwidth_bytes_per_cycle),
            f64_bits_hex(arch.energy.elem_pj(l))
        )
    };
    format!(
        "arch[{};{};{};{};red{};mac{};tc{}x{}x{}]",
        lv(MemLevel::Dram),
        lv(MemLevel::Smem),
        lv(MemLevel::RegisterFile),
        lv(MemLevel::PeBuffer),
        f64_bits_hex(arch.energy.reduction_pj),
        f64_bits_hex(arch.energy.mac_pj),
        arch.tensor_core.subcores,
        arch.tensor_core.pe_rows,
        arch.tensor_core.pe_cols
    )
}

/// Fingerprint of a CiM primitive: name *and* every model parameter,
/// so user-defined primitives sharing a name but not parameters never
/// share cache entries. Float parameters use their exact bit patterns.
fn prim_fingerprint(p: &crate::cim::CimPrimitive) -> String {
    format!(
        "{}({},{},{},{},{},{},{},{})",
        p.name,
        p.rp,
        p.cp,
        p.rh,
        p.ch,
        p.capacity_bytes,
        f64_bits_hex(p.latency_ns),
        f64_bits_hex(p.mac_energy_pj),
        f64_bits_hex(p.area_overhead)
    )
}

/// Stable fingerprint of a [`SystemSpec`] — cheap (no system
/// instantiation) and equal to [`system_fingerprint`] of the
/// `CimSystem` the spec builds.
pub fn spec_fingerprint(spec: &SystemSpec) -> String {
    match spec {
        SystemSpec::Baseline => "baseline".to_string(),
        SystemSpec::CimAtRf(p) => format!("rf:{}", prim_fingerprint(p)),
        SystemSpec::CimAtSmem(p, SmemConfig::ConfigA) => {
            format!("smem-a:{}", prim_fingerprint(p))
        }
        SystemSpec::CimAtSmem(p, SmemConfig::ConfigB) => {
            format!("smem-b:{}", prim_fingerprint(p))
        }
    }
}

/// Stable fingerprint of an instantiated [`CimSystem`]; matches
/// [`spec_fingerprint`] of the spec that would build it.
///
/// The match is exhaustive over the SMEM configurations: a `CimSystem`
/// at SMEM whose `smem_config` is `None` is malformed (every
/// constructor sets it), and silently mapping it onto ConfigB's entries
/// would alias a broken system onto real cached metrics — so it panics
/// instead.
pub fn system_fingerprint(sys: &CimSystem) -> String {
    let p = prim_fingerprint(&sys.primitive);
    match (sys.level, sys.smem_config) {
        (MemLevel::RegisterFile, _) => format!("rf:{p}"),
        (MemLevel::Smem, Some(SmemConfig::ConfigA)) => format!("smem-a:{p}"),
        (MemLevel::Smem, Some(SmemConfig::ConfigB)) => format!("smem-b:{p}"),
        // lint: allow(R4): aliasing a malformed system onto a real cache entry is worse than aborting (doc above)
        (MemLevel::Smem, None) => panic!(
            "CimSystem at SMEM without an smem_config cannot be fingerprinted \
             (it would silently alias a ConfigA/ConfigB cache entry)"
        ),
        (other, _) => format!("{}:{p}", other.short_name()),
    }
}

/// Full cache key string for one single-SM design point (everything
/// but the GEMM). Multi-SM metrics are a pure post-transform of the
/// single-SM entry ([`crate::arch::MultiSm::scale`]), so the SM count
/// is deliberately *not* part of the key — every SM-count axis value
/// shares one cached evaluation.
pub fn point_key(arch_fp: &str, system_fp: &str, mapper_fp: &str) -> String {
    format!("{arch_fp}|{system_fp}|{mapper_fp}")
}

/// Human-readable system label for a spec, identical to
/// `CimSystem::label()` of the instantiated system but computed without
/// cloning the architecture (the label is needed on cache hits too).
pub fn spec_label(spec: &SystemSpec, arch: &crate::arch::Architecture) -> String {
    match spec {
        SystemSpec::Baseline => "Tensor-core".to_string(),
        SystemSpec::CimAtRf(p) => {
            let count = isoarea::primitives_fitting(arch.capacity(MemLevel::RegisterFile), p);
            format!("{}@RF x{count}", p.name)
        }
        SystemSpec::CimAtSmem(p, cfg) => {
            let (tag, cap_level) = match cfg {
                SmemConfig::ConfigA => ("A", MemLevel::RegisterFile),
                SmemConfig::ConfigB => ("B", MemLevel::Smem),
            };
            let count = isoarea::primitives_fitting(arch.capacity(cap_level), p);
            format!("{}@SMEM/config{tag} x{count}", p.name)
        }
    }
}

/// One memoized design-point evaluation: the metrics *and* the mapping
/// that produced them, so post-hoc cost analyses (NoC sensitivity,
/// duplication factors) can consume cached mappings without re-running
/// the mapper. Baseline (tensor-core) points have no mapping.
///
/// The mapping is behind an [`Arc`] so cloning an entry — which
/// [`EvalCache::get_or_compute`] does on every hit, *inside* the shard
/// critical section — is one atomic increment plus a `Metrics` copy,
/// never a loop-nest deep copy under the lock.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub mapping: Option<Arc<Mapping>>,
    pub metrics: Metrics,
}

impl CacheEntry {
    /// A mapper-less entry (the baseline, and tests that only care
    /// about metrics).
    pub fn metrics_only(metrics: Metrics) -> Self {
        CacheEntry {
            mapping: None,
            metrics,
        }
    }
}

/// One cached entry plus its recency metadata: the unix second it was
/// last served or computed. Preserved across save/load round trips so
/// LRU eviction orders by *use*, not by when a file happened to be
/// rewritten.
#[derive(Debug, Clone)]
struct Slot {
    entry: CacheEntry,
    last_used: u64,
}

/// One shard: point key → GEMM → slot. Two-level so a probe borrows the
/// point key (`&str`) and only allocates on a miss.
type Shard = HashMap<String, HashMap<Gemm, Slot>>;

/// Lock one shard — the single place the cache touches a `Mutex`.
fn locked(shard: &Mutex<Shard>) -> std::sync::MutexGuard<'_, Shard> {
    // lint: allow(R4): a poisoned lock means a sibling eval thread already panicked; there is no cache state to recover
    shard.lock().expect("cache shard poisoned")
}

/// Sharded (system fingerprint, GEMM) → [`CacheEntry`] memoization
/// cache with hit/miss accounting and per-entry last-used stamps.
#[derive(Debug)]
pub struct EvalCache {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Mapper invocations performed by cached evaluation paths (the
    /// sweep engine and the hybrid router): every cache miss on a CiM
    /// point costs exactly one, so a fully warm run reports zero — the
    /// invariant the warm-start tests pin.
    mapper_calls: AtomicU64,
    /// Probes that waited for a concurrent identical computation
    /// instead of evaluating redundantly (single-flight coalescing).
    /// Each coalesced probe also counts as a hit — it was served a
    /// memoized value — so `misses` stays exactly "unique points
    /// computed" even under concurrent duplicate traffic (the property
    /// the serve daemon's warm-pass checks rely on).
    coalesced: AtomicU64,
    /// Keys currently being computed by some thread. A probe that
    /// misses first claims its key here; duplicates wait on
    /// [`Self::in_flight_done`] and are then served the freshly
    /// inserted entry.
    in_flight: Mutex<HashSet<(String, Gemm)>>,
    in_flight_done: Condvar,
    /// Last-used stamp applied to every entry touched by this run
    /// (see [`process_stamp`]).
    run_stamp: u64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    pub fn new() -> Self {
        EvalCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            mapper_calls: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            in_flight: Mutex::new(HashSet::new()),
            in_flight_done: Condvar::new(),
            run_stamp: process_stamp(),
        }
    }

    /// The last-used stamp this cache applies to every entry it serves
    /// or computes (one value per process — see [`process_stamp`]).
    pub fn run_stamp(&self) -> u64 {
        self.run_stamp
    }

    fn shard_of(point: &str, gemm: &Gemm) -> usize {
        let mut h = DefaultHasher::new();
        point.hash(&mut h);
        gemm.hash(&mut h);
        (h.finish() as usize) % SHARDS
    }

    /// Serve a hit from the shard holding `(point, gemm)`, refreshing
    /// its recency stamp. `coalesced` marks a probe that waited for a
    /// concurrent identical computation (counted separately so the
    /// serve daemon can prove duplicates evaluated once).
    fn probe(&self, point: &str, gemm: &Gemm, coalesced: bool) -> Option<CacheEntry> {
        let shard = &self.shards[Self::shard_of(point, gemm)];
        let mut guard = locked(shard);
        let slot = guard.get_mut(point)?.get_mut(gemm)?;
        slot.last_used = self.run_stamp;
        self.hits.fetch_add(1, Ordering::Relaxed);
        if coalesced {
            self.coalesced.fetch_add(1, Ordering::Relaxed);
        }
        Some(slot.entry.clone())
    }

    /// Lock the in-flight registry (single-flight bookkeeping).
    fn in_flight_locked(&self) -> std::sync::MutexGuard<'_, HashSet<(String, Gemm)>> {
        // lint: allow(R4): a poisoned registry means a sibling eval thread already panicked
        self.in_flight.lock().expect("in-flight registry poisoned")
    }

    /// Return the memoized entry for `(point, gemm)`, computing it with
    /// `f` on a miss. The evaluation runs outside every lock so
    /// concurrent misses on other keys proceed; concurrent misses on
    /// the *same* key are single-flighted — exactly one thread
    /// evaluates, the rest wait and are served the fresh entry (counted
    /// in [`Self::coalesced`], and as hits). The hit-path clone is
    /// cheap (`Arc` bump + `Metrics` copy — see [`CacheEntry`]).
    pub fn get_or_compute<F: FnOnce() -> CacheEntry>(
        &self,
        point: &str,
        gemm: Gemm,
        f: F,
    ) -> CacheEntry {
        if let Some(entry) = self.probe(point, &gemm, false) {
            return entry;
        }
        let key = (point.to_string(), gemm);
        loop {
            {
                let mut in_flight = self.in_flight_locked();
                if !in_flight.contains(&key) {
                    in_flight.insert(key.clone());
                    break; // this thread owns the computation
                }
                // Another thread is computing this key: wait it out.
                while in_flight.contains(&key) {
                    in_flight = self
                        .in_flight_done
                        .wait(in_flight)
                        // lint: allow(R4): same poisoning contract as in_flight_locked
                        .expect("in-flight registry poisoned");
                }
            }
            // The computation finished (or its thread unwound without
            // inserting): re-probe, else claim the key ourselves.
            if let Some(entry) = self.probe(point, &gemm, true) {
                return entry;
            }
        }
        // Release the claim even if `f` unwinds, so waiters never hang.
        let _claim = InFlightClaim { cache: self, key: &key };
        let e = f();
        self.misses.fetch_add(1, Ordering::Relaxed);
        let shard = &self.shards[Self::shard_of(point, &gemm)];
        let mut guard = locked(shard);
        let slot = guard
            .entry(point.to_string())
            .or_default()
            .entry(gemm)
            .or_insert(Slot {
                entry: e,
                last_used: self.run_stamp,
            });
        slot.last_used = self.run_stamp;
        slot.entry.clone()
    }

    /// Metrics-only variant of [`Self::get_or_compute`]: serves hits by
    /// copying the `Metrics` (a `Copy` type) without holding onto the
    /// cached mapping. The hybrid router's hot path — it prices
    /// thousands of trace layers and never reads the mapping — uses
    /// this; the engine, whose results carry the mapping, uses
    /// `get_or_compute`.
    pub fn get_or_compute_metrics<F: FnOnce() -> CacheEntry>(
        &self,
        point: &str,
        gemm: Gemm,
        f: F,
    ) -> Metrics {
        self.get_or_compute(point, gemm, f).metrics
    }

    /// Insert an entry without touching the hit/miss counters (cache
    /// warm-up from a persisted file). An existing entry wins — the
    /// live-computed value and the persisted one are identical by the
    /// purity contract, so keeping the first avoids surprises. The
    /// entry is stamped as used *now*; to preserve a persisted stamp
    /// use [`Self::preload_stamped`].
    pub fn preload(&self, point: &str, gemm: Gemm, entry: CacheEntry) {
        self.preload_stamped(point, gemm, entry, self.run_stamp);
    }

    /// [`Self::preload`] preserving a persisted last-used stamp: an
    /// entry loaded from disk but never used by this run keeps its old
    /// recency, so the LRU cap evicts it before anything the run
    /// actually touched. An existing in-memory entry wins, stamp
    /// included.
    pub fn preload_stamped(&self, point: &str, gemm: Gemm, entry: CacheEntry, last_used: u64) {
        let shard = &self.shards[Self::shard_of(point, &gemm)];
        locked(shard)
            .entry(point.to_string())
            .or_default()
            .entry(gemm)
            .or_insert(Slot { entry, last_used });
    }

    /// All cached entries, sorted by (point key, GEMM) so the snapshot
    /// — and any file serialized from it — is deterministic regardless
    /// of insertion order and shard hashing.
    pub fn snapshot(&self) -> Vec<(String, Gemm, CacheEntry)> {
        self.snapshot_stamped()
            .into_iter()
            .map(|(point, gemm, _, entry)| (point, gemm, entry))
            .collect()
    }

    /// [`Self::snapshot`] with each entry's last-used stamp (the
    /// persistence layer serializes these; LRU trimming orders on
    /// them). Same deterministic (point key, GEMM) order.
    pub fn snapshot_stamped(&self) -> Vec<(String, Gemm, u64, CacheEntry)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let shard = locked(s);
            for (point, per_gemm) in shard.iter() {
                for (gemm, slot) in per_gemm {
                    out.push((point.clone(), *gemm, slot.last_used, slot.entry.clone()));
                }
            }
        }
        out.sort_by(|a, b| {
            (a.0.as_str(), a.1.m, a.1.n, a.1.k).cmp(&(b.0.as_str(), b.1.m, b.1.n, b.1.k))
        });
        out
    }

    /// Number of distinct cached points.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| locked(s).values().map(HashMap::len).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Record one mapper invocation by a cached evaluation path (called
    /// by the evaluators, inside their miss closures).
    pub fn note_mapper_call(&self) {
        self.mapper_calls.fetch_add(1, Ordering::Relaxed);
    }

    /// Mapper invocations performed so far by cached evaluation paths.
    /// Zero on a fully warm run — cached mappings make re-mapping
    /// unnecessary, which this counter lets tests assert directly.
    pub fn mapper_calls(&self) -> u64 {
        self.mapper_calls.load(Ordering::Relaxed)
    }

    /// Probes served by waiting on a concurrent identical computation
    /// instead of evaluating redundantly (see [`Self::get_or_compute`]).
    /// The serve daemon's concurrency tests pin `misses == unique
    /// points` through this mechanism.
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Drop all cached entries and reset the counters.
    pub fn clear(&self) {
        for s in &self.shards {
            locked(s).clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.mapper_calls.store(0, Ordering::Relaxed);
        self.coalesced.store(0, Ordering::Relaxed);
    }
}

/// Unwind-safe release of a single-flight claim: removing the key and
/// waking waiters happens on drop, so a panicking evaluation closure
/// can never leave duplicates blocked forever.
struct InFlightClaim<'a> {
    cache: &'a EvalCache,
    key: &'a (String, Gemm),
}

impl Drop for InFlightClaim<'_> {
    fn drop(&mut self) {
        self.cache.in_flight_locked().remove(self.key);
        self.cache.in_flight_done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;

    fn dummy_metrics(x: f64) -> Metrics {
        Metrics {
            macs: 1,
            ops: 2,
            energy_pj: x,
            breakdown: Default::default(),
            tops_per_watt: 2.0 / x,
            compute_cycles: 1,
            dram_cycles: 1,
            smem_cycles: 0,
            total_cycles: 1,
            gflops: 2.0,
            utilization: 1.0,
            dram_bytes: 3,
            smem_bytes: 0,
        }
    }

    fn dummy_entry(x: f64) -> CacheEntry {
        CacheEntry::metrics_only(dummy_metrics(x))
    }

    /// One ulp up — the smallest possible parameter perturbation.
    fn ulp_up(x: f64) -> f64 {
        f64::from_bits(x.to_bits() + 1)
    }

    #[test]
    fn hit_returns_first_computation() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        let a = cache.get_or_compute("p", g, || dummy_entry(1.0));
        let b = cache.get_or_compute("p", g, || dummy_entry(999.0));
        assert_eq!(a, b, "second call must be served from the cache");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_points_distinct_entries() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        cache.get_or_compute("a", g, || dummy_entry(1.0));
        cache.get_or_compute("b", g, || dummy_entry(2.0));
        cache.get_or_compute("a", Gemm::new(32, 32, 32), || dummy_entry(3.0));
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn clear_resets() {
        let cache = EvalCache::new();
        cache.get_or_compute("a", Gemm::new(8, 8, 8), || dummy_entry(1.0));
        cache.note_mapper_call();
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.hits() + cache.misses() + cache.mapper_calls(), 0);
    }

    #[test]
    fn preload_serves_hits_without_counting_a_miss() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        cache.preload("p", g, dummy_entry(5.0));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits() + cache.misses(), 0);
        let e = cache.get_or_compute("p", g, || panic!("preloaded entry must hit"));
        assert_eq!(e, dummy_entry(5.0));
        assert_eq!(cache.hits(), 1);
        // preload never overwrites an existing entry
        cache.preload("p", g, dummy_entry(9.0));
        let again = cache.get_or_compute("p", g, || unreachable!());
        assert_eq!(again, dummy_entry(5.0));
    }

    #[test]
    fn stamps_track_use_and_survive_preload() {
        let cache = EvalCache::new();
        let g = Gemm::new(8, 8, 8);
        let old = cache.run_stamp().saturating_sub(1000);
        cache.preload_stamped("stale", g, dummy_entry(1.0), old);
        cache.preload("fresh", g, dummy_entry(2.0));
        let snap = cache.snapshot_stamped();
        assert_eq!(snap[0].0, "fresh");
        assert_eq!(snap[0].2, cache.run_stamp());
        assert_eq!(snap[1].0, "stale");
        assert_eq!(snap[1].2, old, "preload_stamped must keep the persisted stamp");
        // A hit refreshes the stale entry's recency to this run.
        cache.get_or_compute("stale", g, || unreachable!());
        assert_eq!(cache.snapshot_stamped()[1].2, cache.run_stamp());
        // An existing in-memory entry wins over a late preload, stamp
        // included.
        cache.preload_stamped("stale", g, dummy_entry(9.0), old);
        assert_eq!(cache.snapshot_stamped()[1].2, cache.run_stamp());
    }

    #[test]
    fn concurrent_identical_probes_single_flight() {
        use std::sync::atomic::AtomicU64;
        let cache = Arc::new(EvalCache::new());
        let g = Gemm::new(16, 16, 16);
        let computes = Arc::new(AtomicU64::new(0));
        let n = 8;
        let mut handles = Vec::new();
        for _ in 0..n {
            let cache = Arc::clone(&cache);
            let computes = Arc::clone(&computes);
            handles.push(std::thread::spawn(move || {
                cache.get_or_compute("p", g, || {
                    computes.fetch_add(1, Ordering::SeqCst);
                    // Widen the race window so duplicates overlap.
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    dummy_entry(1.0)
                })
            }));
        }
        let entries: Vec<CacheEntry> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(entries.iter().all(|e| *e == dummy_entry(1.0)));
        assert_eq!(computes.load(Ordering::SeqCst), 1, "exactly one evaluation");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), n - 1, "duplicates served as hits");
        assert_eq!(cache.coalesced(), n - 1, "duplicates waited, not recomputed");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn panicking_computation_releases_the_single_flight_claim() {
        let cache = Arc::new(EvalCache::new());
        let g = Gemm::new(8, 8, 8);
        let c2 = Arc::clone(&cache);
        let panicker = std::thread::spawn(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                c2.get_or_compute("p", g, || panic!("evaluation blew up"))
            }));
            assert!(result.is_err());
        });
        panicker.join().unwrap();
        // The claim was released on unwind: a later probe computes
        // normally instead of deadlocking on the in-flight registry.
        let e = cache.get_or_compute("p", g, || dummy_entry(2.0));
        assert_eq!(e, dummy_entry(2.0));
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let cache = EvalCache::new();
        cache.get_or_compute("b", Gemm::new(8, 8, 8), || dummy_entry(1.0));
        cache.get_or_compute("a", Gemm::new(32, 32, 32), || dummy_entry(2.0));
        cache.get_or_compute("a", Gemm::new(8, 8, 8), || dummy_entry(3.0));
        let snap = cache.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(
            snap.iter()
                .map(|(p, g, _)| (p.as_str(), g.m))
                .collect::<Vec<_>>(),
            vec![("a", 8), ("a", 32), ("b", 8)]
        );
    }

    #[test]
    fn metrics_only_probe_shares_entries_with_the_full_probe() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        let m = cache.get_or_compute_metrics("p", g, || dummy_entry(3.0));
        assert_eq!(m, dummy_metrics(3.0));
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        // Hits are served to either probe from the one shared entry.
        assert_eq!(
            cache.get_or_compute("p", g, || unreachable!()),
            dummy_entry(3.0)
        );
        assert_eq!(
            cache.get_or_compute_metrics("p", g, || unreachable!()),
            dummy_metrics(3.0)
        );
        assert_eq!((cache.hits(), cache.misses()), (2, 1));
    }

    #[test]
    fn entries_carry_their_mapping() {
        use crate::arch::CimSystem;
        use crate::mapping::PriorityMapper;
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        let g = Gemm::new(512, 1024, 1024);
        let mapping = PriorityMapper::new(&sys).map(&g);
        let cache = EvalCache::new();
        cache.get_or_compute("cim", g, || CacheEntry {
            mapping: Some(Arc::new(mapping.clone())),
            metrics: dummy_metrics(1.0),
        });
        let hit = cache.get_or_compute("cim", g, || unreachable!());
        assert_eq!(hit.mapping.as_deref(), Some(&mapping));
        let (_, _, snap) = cache.snapshot().pop().expect("one entry");
        assert_eq!(snap.mapping, Some(Arc::new(mapping)));
    }

    #[test]
    fn spec_and_system_fingerprints_agree() {
        let arch = Architecture::default_sm();
        let specs = [
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_8t(), SmemConfig::ConfigA),
            SystemSpec::CimAtSmem(CimPrimitive::digital_8t(), SmemConfig::ConfigB),
        ];
        for spec in specs {
            let sys = spec.system(&arch).expect("cim spec builds a system");
            assert_eq!(spec_fingerprint(&spec), system_fingerprint(&sys));
        }
        assert_eq!(spec_fingerprint(&SystemSpec::Baseline), "baseline");
    }

    #[test]
    #[should_panic(expected = "smem_config")]
    fn smem_system_without_config_fails_loudly() {
        // Regression: (Smem, None) used to silently fingerprint as
        // "smem-b", aliasing a malformed system onto ConfigB's entries.
        let arch = Architecture::default_sm();
        let mut sys = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        sys.smem_config = None;
        let _ = system_fingerprint(&sys);
    }

    #[test]
    fn prim_fingerprint_distinguishes_one_ulp() {
        // Regression: {:.6}-truncated float rendering let two primitives
        // differing below 1e-6 share a fingerprint (and, once persisted,
        // each other's metrics).
        let p = CimPrimitive::digital_6t();
        for field in 0..3 {
            let mut q = p.clone();
            match field {
                0 => q.latency_ns = ulp_up(q.latency_ns),
                1 => q.mac_energy_pj = ulp_up(q.mac_energy_pj),
                _ => q.area_overhead = ulp_up(q.area_overhead),
            }
            assert_ne!(
                spec_fingerprint(&SystemSpec::CimAtRf(p.clone())),
                spec_fingerprint(&SystemSpec::CimAtRf(q)),
                "field {field}: 1-ulp perturbation must change the fingerprint"
            );
        }
    }

    #[test]
    fn arch_fingerprint_distinguishes_one_ulp() {
        let arch = Architecture::default_sm();
        let fp = arch_fingerprint(&arch);

        let mut mac = arch.clone();
        mac.energy.mac_pj = ulp_up(mac.energy.mac_pj);
        assert_ne!(fp, arch_fingerprint(&mac));

        let mut red = arch.clone();
        red.energy.reduction_pj = ulp_up(red.energy.reduction_pj);
        assert_ne!(fp, arch_fingerprint(&red));

        let mut bw = arch.clone();
        for l in &mut bw.levels {
            if l.level == MemLevel::Smem {
                l.bandwidth_bytes_per_cycle = ulp_up(l.bandwidth_bytes_per_cycle);
            }
        }
        assert_ne!(fp, arch_fingerprint(&bw));
    }

    #[test]
    fn fingerprints_distinguish_sub_truncation_deltas() {
        // The old {:.4} bandwidth rendering collapsed 42.0 and 42.00001.
        let arch = Architecture::default_sm();
        let mut close = arch.clone();
        for l in &mut close.levels {
            if l.level == MemLevel::Smem {
                l.bandwidth_bytes_per_cycle += 1e-5;
            }
        }
        assert_ne!(arch_fingerprint(&arch), arch_fingerprint(&close));
    }

    #[test]
    fn f64_bits_hex_is_exact() {
        assert_eq!(f64_bits_hex(1.0), format!("{:016x}", 1.0f64.to_bits()));
        assert_ne!(f64_bits_hex(0.0), f64_bits_hex(-0.0));
        assert_ne!(f64_bits_hex(42.0), f64_bits_hex(ulp_up(42.0)));
    }

    #[test]
    fn spec_label_matches_instantiated_system_label() {
        // Guard against drift from the ground truth: the label of the
        // actually-instantiated CimSystem (SystemSpec::label delegates
        // to spec_label, so compare against CimSystem::label directly).
        let arch = Architecture::default_sm();
        assert_eq!(spec_label(&SystemSpec::Baseline, &arch), "Tensor-core");
        for p in CimPrimitive::all() {
            for spec in [
                SystemSpec::CimAtRf(p.clone()),
                SystemSpec::CimAtSmem(p.clone(), SmemConfig::ConfigA),
                SystemSpec::CimAtSmem(p.clone(), SmemConfig::ConfigB),
            ] {
                let sys = spec.system(&arch).expect("cim spec builds a system");
                assert_eq!(spec_label(&spec, &arch), sys.label(), "{spec:?}");
            }
        }
    }
}
