//! Memoization cache for design-space evaluations.
//!
//! Every point of the What/When/Where design space is identified by a
//! *system fingerprint* — a stable string naming the system
//! configuration (integration point + primitive + SM count + mapper) —
//! plus the GEMM shape. The analytical evaluation of a point is a pure
//! function of that key, so duplicate points across experiments (fig9's
//! synthetic sweep, fig11/fig12's workload grids, the zoo, the serving
//! router all revisit the same (system, GEMM) pairs) are scored exactly
//! once per process.
//!
//! The cache is sharded: each shard is an independent `Mutex<HashMap>`,
//! picked by key hash, so parallel sweeps do not serialize on one lock.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use crate::cim::isoarea;
use crate::coordinator::jobs::SystemSpec;
use crate::cost::Metrics;
use crate::workload::Gemm;

/// Number of independent shards (power of two).
const SHARDS: usize = 16;

/// Mapper fingerprint fragment for baseline points: the mapper cannot
/// influence the tensor-core baseline, so every mapper choice shares
/// one baseline cache entry under this marker.
pub const BASELINE_MAPPER_FP: &str = "n/a";

/// Stable fingerprint of an [`Architecture`]: capacities, bandwidths,
/// per-element energies and baseline peak. Cached metrics are only
/// valid for the architecture they were computed on, so this prefixes
/// every cache key (engines over different architectures may share one
/// [`EvalCache`] without cross-talk).
pub fn arch_fingerprint(arch: &Architecture) -> String {
    let lv = |l: MemLevel| {
        let s = arch.level(l);
        format!(
            "{}:{:.4}:{:.6}",
            s.capacity_bytes,
            s.bandwidth_bytes_per_cycle,
            arch.energy.elem_pj(l)
        )
    };
    format!(
        "arch[{};{};{};{};red{:.6};mac{:.6};tc{}x{}x{}]",
        lv(MemLevel::Dram),
        lv(MemLevel::Smem),
        lv(MemLevel::RegisterFile),
        lv(MemLevel::PeBuffer),
        arch.energy.reduction_pj,
        arch.energy.mac_pj,
        arch.tensor_core.subcores,
        arch.tensor_core.pe_rows,
        arch.tensor_core.pe_cols
    )
}

/// Fingerprint of a CiM primitive: name *and* every model parameter,
/// so user-defined primitives sharing a name but not parameters never
/// share cache entries.
fn prim_fingerprint(p: &crate::cim::CimPrimitive) -> String {
    format!(
        "{}({},{},{},{},{},{},{},{})",
        p.name,
        p.rp,
        p.cp,
        p.rh,
        p.ch,
        p.capacity_bytes,
        p.latency_ns,
        p.mac_energy_pj,
        p.area_overhead
    )
}

/// Stable fingerprint of a [`SystemSpec`] — cheap (no system
/// instantiation) and equal to [`system_fingerprint`] of the
/// `CimSystem` the spec builds.
pub fn spec_fingerprint(spec: &SystemSpec) -> String {
    match spec {
        SystemSpec::Baseline => "baseline".to_string(),
        SystemSpec::CimAtRf(p) => format!("rf:{}", prim_fingerprint(p)),
        SystemSpec::CimAtSmem(p, SmemConfig::ConfigA) => {
            format!("smem-a:{}", prim_fingerprint(p))
        }
        SystemSpec::CimAtSmem(p, SmemConfig::ConfigB) => {
            format!("smem-b:{}", prim_fingerprint(p))
        }
    }
}

/// Stable fingerprint of an instantiated [`CimSystem`]; matches
/// [`spec_fingerprint`] of the spec that would build it.
pub fn system_fingerprint(sys: &CimSystem) -> String {
    let p = prim_fingerprint(&sys.primitive);
    match (sys.level, sys.smem_config) {
        (MemLevel::RegisterFile, _) => format!("rf:{p}"),
        (MemLevel::Smem, Some(SmemConfig::ConfigA)) => format!("smem-a:{p}"),
        (MemLevel::Smem, _) => format!("smem-b:{p}"),
        (other, _) => format!("{}:{p}", other.short_name()),
    }
}

/// Full cache key string for one single-SM design point (everything
/// but the GEMM). Multi-SM metrics are a pure post-transform of the
/// single-SM entry ([`crate::arch::MultiSm::scale`]), so the SM count
/// is deliberately *not* part of the key — every SM-count axis value
/// shares one cached evaluation.
pub fn point_key(arch_fp: &str, system_fp: &str, mapper_fp: &str) -> String {
    format!("{arch_fp}|{system_fp}|{mapper_fp}")
}

/// Human-readable system label for a spec, identical to
/// `CimSystem::label()` of the instantiated system but computed without
/// cloning the architecture (the label is needed on cache hits too).
pub fn spec_label(spec: &SystemSpec, arch: &crate::arch::Architecture) -> String {
    match spec {
        SystemSpec::Baseline => "Tensor-core".to_string(),
        SystemSpec::CimAtRf(p) => {
            let count = isoarea::primitives_fitting(arch.capacity(MemLevel::RegisterFile), p);
            format!("{}@RF x{count}", p.name)
        }
        SystemSpec::CimAtSmem(p, cfg) => {
            let (tag, cap_level) = match cfg {
                SmemConfig::ConfigA => ("A", MemLevel::RegisterFile),
                SmemConfig::ConfigB => ("B", MemLevel::Smem),
            };
            let count = isoarea::primitives_fitting(arch.capacity(cap_level), p);
            format!("{}@SMEM/config{tag} x{count}", p.name)
        }
    }
}

type Key = (String, Gemm);

/// Sharded (system fingerprint, GEMM) → [`Metrics`] memoization cache
/// with hit/miss accounting.
#[derive(Debug)]
pub struct EvalCache {
    shards: Vec<Mutex<HashMap<Key, Metrics>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    pub fn new() -> Self {
        EvalCache {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard_of(key: &Key) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % SHARDS
    }

    /// Return the memoized metrics for `(point, gemm)`, computing them
    /// with `f` on a miss. The evaluation runs outside the shard lock so
    /// concurrent misses on other keys proceed; a racing duplicate miss
    /// computes redundantly but deterministically (first insert wins).
    pub fn get_or_compute<F: FnOnce() -> Metrics>(
        &self,
        point: String,
        gemm: Gemm,
        f: F,
    ) -> Metrics {
        let key = (point, gemm);
        let shard = &self.shards[Self::shard_of(&key)];
        if let Some(m) = shard.lock().expect("cache shard poisoned").get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return *m;
        }
        let m = f();
        self.misses.fetch_add(1, Ordering::Relaxed);
        *shard
            .lock()
            .expect("cache shard poisoned")
            .entry(key)
            .or_insert(m)
    }

    /// Number of distinct cached points.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("cache shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop all cached entries and reset the counters.
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().expect("cache shard poisoned").clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;

    fn dummy_metrics(x: f64) -> Metrics {
        Metrics {
            macs: 1,
            ops: 2,
            energy_pj: x,
            breakdown: Default::default(),
            tops_per_watt: 2.0 / x,
            compute_cycles: 1,
            dram_cycles: 1,
            smem_cycles: 0,
            total_cycles: 1,
            gflops: 2.0,
            utilization: 1.0,
            dram_bytes: 3,
            smem_bytes: 0,
        }
    }

    #[test]
    fn hit_returns_first_computation() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        let a = cache.get_or_compute("p".into(), g, || dummy_metrics(1.0));
        let b = cache.get_or_compute("p".into(), g, || dummy_metrics(999.0));
        assert_eq!(a, b, "second call must be served from the cache");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_points_distinct_entries() {
        let cache = EvalCache::new();
        let g = Gemm::new(16, 16, 16);
        cache.get_or_compute("a".into(), g, || dummy_metrics(1.0));
        cache.get_or_compute("b".into(), g, || dummy_metrics(2.0));
        cache.get_or_compute("a".into(), Gemm::new(32, 32, 32), || dummy_metrics(3.0));
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn clear_resets() {
        let cache = EvalCache::new();
        cache.get_or_compute("a".into(), Gemm::new(8, 8, 8), || dummy_metrics(1.0));
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.hits() + cache.misses(), 0);
    }

    #[test]
    fn spec_and_system_fingerprints_agree() {
        let arch = Architecture::default_sm();
        let specs = [
            SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            SystemSpec::CimAtSmem(CimPrimitive::analog_8t(), SmemConfig::ConfigA),
            SystemSpec::CimAtSmem(CimPrimitive::digital_8t(), SmemConfig::ConfigB),
        ];
        for spec in specs {
            let sys = spec.system(&arch).expect("cim spec builds a system");
            assert_eq!(spec_fingerprint(&spec), system_fingerprint(&sys));
        }
        assert_eq!(spec_fingerprint(&SystemSpec::Baseline), "baseline");
    }

    #[test]
    fn spec_label_matches_instantiated_system_label() {
        // Guard against drift from the ground truth: the label of the
        // actually-instantiated CimSystem (SystemSpec::label delegates
        // to spec_label, so compare against CimSystem::label directly).
        let arch = Architecture::default_sm();
        assert_eq!(spec_label(&SystemSpec::Baseline, &arch), "Tensor-core");
        for p in CimPrimitive::all() {
            for spec in [
                SystemSpec::CimAtRf(p.clone()),
                SystemSpec::CimAtSmem(p.clone(), SmemConfig::ConfigA),
                SystemSpec::CimAtSmem(p.clone(), SmemConfig::ConfigB),
            ] {
                let sys = spec.system(&arch).expect("cim spec builds a system");
                assert_eq!(spec_label(&spec, &arch), sys.label(), "{spec:?}");
            }
        }
    }
}
