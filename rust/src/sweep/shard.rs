//! Sweep sharding: deterministic slicing of a sweep's job list across
//! processes/hosts, per-shard summary files, and the merge tool.
//!
//! `repro sweep --shard i/n` expands the *full* [`SweepSpec`], takes
//! the deterministic round-robin slice `{g : g mod n == i}` of the job
//! list, and writes a per-shard JSON summary tagged with the shard
//! identity and a **sweep fingerprint** (architecture + every grid
//! axis). `repro merge` then validates that all shards carry the same
//! fingerprint, that the indices cover `0..n` exactly once, and
//! re-interleaves the per-point results into the original job order —
//! the merged `sweep.csv` is byte-identical to an unsharded run's.
//!
//! Metrics travel through the shard files as exact bit patterns
//! (see [`super::persist::metrics_fields`]), so merging is lossless.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::arch::Architecture;
use crate::cost::COST_MODEL_VERSION;
use crate::mapping::Mapping;
use crate::util::hash::fnv1a;
use crate::util::json::Json;
use crate::workload::Gemm;

use super::cache;
use super::engine::SweepRun;
use super::output::{json_escape, json_f64, summarize};
use super::persist;
use super::spec::{SweepResult, SweepSpec};

/// Version of the shard-summary JSON layout. Bump on any change to the
/// document structure; `repro merge` refuses other versions.
/// v2: per-point results carry the canonical mapping (or `null`).
pub const SHARD_FORMAT_VERSION: u32 = 2;

/// One shard of an `n`-way sweep: `index` ∈ `0..count`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardId {
    pub index: usize,
    pub count: usize,
}

impl ShardId {
    /// Parse the CLI form `i/n` (e.g. `0/4`).
    pub fn parse(s: &str) -> Result<ShardId> {
        let (i, n) = match s.split_once('/') {
            Some(parts) => parts,
            None => bail!("--shard wants i/n (e.g. 0/4), got {s:?}"),
        };
        let index: usize = i
            .trim()
            .parse()
            .ok()
            .with_context(|| format!("--shard {s:?}: bad shard index {i:?}"))?;
        let count: usize = n
            .trim()
            .parse()
            .ok()
            .with_context(|| format!("--shard {s:?}: bad shard count {n:?}"))?;
        if count == 0 {
            bail!("--shard {s:?}: shard count must be >= 1");
        }
        if index >= count {
            bail!("--shard {s:?}: shard index must be < count");
        }
        Ok(ShardId { index, count })
    }

    /// Deterministic round-robin slice of a job list: global job `g`
    /// belongs to shard `g % count`. Round-robin (not contiguous
    /// blocks) keeps shard runtimes balanced when a grid orders its
    /// jobs from cheap to expensive GEMMs.
    pub fn slice<T: Clone>(&self, jobs: &[T]) -> Vec<T> {
        jobs.iter()
            .enumerate()
            .filter(|(g, _)| g % self.count == self.index)
            .map(|(_, j)| j.clone())
            .collect()
    }

    /// Number of jobs this shard takes from a list of `total`.
    pub fn len_of(&self, total: usize) -> usize {
        (total + self.count - self.index - 1) / self.count
    }

    /// Filename fragment (`shard0of4`).
    pub fn file_tag(&self) -> String {
        format!("shard{}of{}", self.index, self.count)
    }
}

impl fmt::Display for ShardId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// Stable fingerprint of (architecture, sweep spec): every grid axis —
/// workloads with their GEMM lists, systems, SM counts, mapper — plus
/// the architecture fingerprint. Shards carry it so `repro merge`
/// refuses to combine shards of different sweeps, and so two shards of
/// one sweep run on different hosts still match.
pub fn sweep_fingerprint(arch: &Architecture, spec: &SweepSpec) -> String {
    let mut desc = String::new();
    desc.push_str(&cache::arch_fingerprint(arch));
    desc.push('|');
    desc.push_str(&spec.mapper.fingerprint());
    for (name, gemms) in &spec.workloads {
        desc.push('|');
        desc.push_str(name);
        for g in gemms {
            desc.push_str(&format!(";{}x{}x{}", g.m, g.n, g.k));
        }
    }
    for s in &spec.systems {
        desc.push('|');
        desc.push_str(&cache::spec_fingerprint(s));
    }
    for &n in &spec.sm_counts {
        desc.push_str(&format!("|sms{n}"));
    }
    format!("{:016x}", fnv1a(desc.as_bytes()))
}

/// Encode one shard's run as the per-shard JSON summary document.
pub fn shard_json(
    run: &SweepRun,
    shard: ShardId,
    fingerprint: &str,
    points_total: usize,
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!(
        "  \"sweep\": \"{}\",\n",
        json_escape(&run.spec_name)
    ));
    out.push_str(&format!("  \"format\": {SHARD_FORMAT_VERSION},\n"));
    out.push_str(&format!("  \"cost_model\": {COST_MODEL_VERSION},\n"));
    out.push_str(&format!(
        "  \"fingerprint\": \"{}\",\n",
        json_escape(fingerprint)
    ));
    out.push_str(&format!("  \"points_total\": {points_total},\n"));
    out.push_str(&format!(
        "  \"shard\": {{\"index\": {}, \"count\": {}, \"points\": {}}},\n",
        shard.index,
        shard.count,
        run.n_points()
    ));
    out.push_str(&format!("  \"threads\": {},\n", run.threads));
    out.push_str(&format!(
        "  \"elapsed_s\": {},\n",
        json_f64(run.elapsed.as_secs_f64())
    ));
    out.push_str(&format!(
        "  \"cache\": {{\"hits\": {}, \"misses\": {}}},\n",
        run.cache_hits, run.cache_misses
    ));
    out.push_str("  \"results\": [\n");
    for (i, r) in run.results.iter().enumerate() {
        let metrics: Vec<String> = persist::metrics_fields(&r.metrics)
            .into_iter()
            .map(|f| format!("\"{f}\""))
            .collect();
        let mapping = match &r.mapping {
            Some(m) => format!("\"{}\"", json_escape(&m.canonical())),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \
             \"system\": \"{}\", \"sms\": {}, \"mapping\": {}, \"metrics\": [{}]}}{}\n",
            json_escape(&r.workload),
            r.gemm.m,
            r.gemm.n,
            r.gemm.k,
            json_escape(&r.system),
            r.sms,
            mapping,
            metrics.join(", "),
            if i + 1 < run.results.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the per-shard JSON summary to `path`, creating parent dirs.
pub fn write_shard_json(
    run: &SweepRun,
    shard: ShardId,
    fingerprint: &str,
    points_total: usize,
    path: &Path,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    fs::write(path, shard_json(run, shard, fingerprint, points_total))
        .with_context(|| format!("writing shard summary {}", path.display()))?;
    Ok(())
}

/// A validated, re-interleaved merge of every shard of one sweep.
#[derive(Debug, Clone)]
pub struct MergedSweep {
    pub spec_name: String,
    pub fingerprint: String,
    pub shard_count: usize,
    pub cost_model: u64,
    /// Per-point results in the original (unsharded) job order.
    pub results: Vec<SweepResult>,
}

/// One parsed + structurally validated shard summary file. Shared by
/// `repro merge` / the orchestrator's post-run validation / `--resume`
/// (which treats an unreadable summary as "shard must re-run").
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub sweep: String,
    pub fingerprint: String,
    pub points_total: usize,
    pub cost_model: u64,
    pub shard: ShardId,
    /// This shard's results in local (sliced) order.
    pub results: Vec<SweepResult>,
}

/// Read and validate one per-shard summary file: format version,
/// required header fields, a sane shard identity, and a result count
/// matching the shard's slice of `points_total`.
pub fn read_shard_file(path: &Path) -> Result<ShardSummary> {
    let loc = format!("shard file {}", path.display());
    let text = fs::read_to_string(path).with_context(|| loc.clone())?;
    let doc = Json::parse(&text).with_context(|| loc.clone())?;
    let format = doc
        .get("format")
        .and_then(Json::as_u64)
        .with_context(|| format!("{loc}: missing shard format version"))?;
    if format != u64::from(SHARD_FORMAT_VERSION) {
        bail!("{loc}: shard format v{format}, this binary reads v{SHARD_FORMAT_VERSION}");
    }
    let sweep = doc
        .get("sweep")
        .and_then(Json::as_str)
        .with_context(|| format!("{loc}: missing sweep name"))?
        .to_string();
    let fingerprint = doc
        .get("fingerprint")
        .and_then(Json::as_str)
        .with_context(|| format!("{loc}: missing sweep fingerprint"))?
        .to_string();
    let points_total = doc
        .get("points_total")
        .and_then(Json::as_u64)
        .with_context(|| format!("{loc}: missing points_total"))? as usize;
    let cost_model = doc
        .get("cost_model")
        .and_then(Json::as_u64)
        .with_context(|| format!("{loc}: missing cost_model version"))?;
    let shard_obj = doc
        .get("shard")
        .with_context(|| format!("{loc}: missing shard identity"))?;
    let shard = ShardId {
        index: shard_obj
            .get("index")
            .and_then(Json::as_u64)
            .with_context(|| format!("{loc}: missing shard index"))? as usize,
        count: shard_obj
            .get("count")
            .and_then(Json::as_u64)
            .with_context(|| format!("{loc}: missing shard count"))? as usize,
    };
    if shard.count == 0 || shard.index >= shard.count {
        bail!("{loc}: bad shard identity {shard}");
    }
    let rows = doc
        .get("results")
        .and_then(Json::as_array)
        .with_context(|| format!("{loc}: missing results"))?;
    let expect = shard.len_of(points_total);
    if rows.len() != expect {
        bail!(
            "{loc}: shard {shard} carries {} results, expected {expect}",
            rows.len()
        );
    }
    let results = rows
        .iter()
        .map(result_from_json)
        .collect::<Result<Vec<SweepResult>>>()
        .with_context(|| loc.clone())?;
    Ok(ShardSummary {
        sweep,
        fingerprint,
        points_total,
        cost_model,
        shard,
        results,
    })
}

fn result_from_json(v: &Json) -> Result<SweepResult> {
    let workload = v
        .get("workload")
        .and_then(Json::as_str)
        .context("result missing \"workload\"")?
        .to_string();
    let m = v.get("m").and_then(Json::as_u64).context("result missing \"m\"")?;
    let n = v.get("n").and_then(Json::as_u64).context("result missing \"n\"")?;
    let k = v.get("k").and_then(Json::as_u64).context("result missing \"k\"")?;
    let system = v
        .get("system")
        .and_then(Json::as_str)
        .context("result missing \"system\"")?
        .to_string();
    let sms = v
        .get("sms")
        .and_then(Json::as_u64)
        .context("result missing \"sms\"")?;
    let arr = v
        .get("metrics")
        .and_then(Json::as_array)
        .context("result missing \"metrics\"")?;
    let fields = arr
        .iter()
        .map(|j| j.as_str().context("metrics fields must be strings"))
        .collect::<Result<Vec<&str>>>()?;
    let metrics = persist::metrics_from_fields(&fields)?;
    let mapping = match v.get("mapping").context("result missing \"mapping\"")? {
        Json::Null => None,
        j => {
            let s = j
                .as_str()
                .context("result \"mapping\" must be a string or null")?;
            Some(Arc::new(Mapping::from_canonical(s)?))
        }
    };
    Ok(SweepResult {
        workload,
        gemm: Gemm::new(m, n, k),
        system,
        sms,
        metrics,
        mapping,
    })
}

/// Read, validate and merge per-shard summary files. Every shard of the
/// sweep must be present exactly once, and all shards must carry the
/// same sweep fingerprint (same spec + architecture), points total and
/// cost-model version.
pub fn merge_files(paths: &[PathBuf]) -> Result<MergedSweep> {
    if paths.is_empty() {
        bail!("merge: no shard files given");
    }
    let mut name: Option<String> = None;
    let mut fingerprint: Option<String> = None;
    let mut points_total: Option<usize> = None;
    let mut cost_model: Option<u64> = None;
    let mut docs: Vec<ShardSummary> = Vec::new();
    for path in paths {
        let loc = format!("shard file {}", path.display());
        let summary = read_shard_file(path)?;
        match &fingerprint {
            None => {
                name = Some(summary.sweep.clone());
                fingerprint = Some(summary.fingerprint.clone());
                points_total = Some(summary.points_total);
                cost_model = Some(summary.cost_model);
            }
            Some(fp) => {
                if *fp != summary.fingerprint {
                    bail!(
                        "{loc}: sweep fingerprint {} does not match the first \
                         shard's {fp} — shards come from different spec/arch",
                        summary.fingerprint
                    );
                }
                if points_total != Some(summary.points_total) {
                    bail!(
                        "{loc}: points_total {} disagrees with the first shard",
                        summary.points_total
                    );
                }
                if cost_model != Some(summary.cost_model) {
                    bail!("{loc}: cost-model version disagrees with the first shard");
                }
            }
        }
        docs.push(summary);
    }

    let count = docs[0].shard.count;
    if docs.iter().any(|d| d.shard.count != count) {
        bail!("merge: shard files disagree on the shard count");
    }
    if docs.len() != count {
        bail!(
            "merge: got {} shard file(s) for a {count}-way sweep — every shard \
             0..{count} is required exactly once",
            docs.len()
        );
    }
    let mut by_index: Vec<Option<ShardSummary>> = (0..count).map(|_| None).collect();
    for d in docs {
        let i = d.shard.index;
        if by_index[i].is_some() {
            bail!("merge: shard {i}/{count} given more than once");
        }
        by_index[i] = Some(d);
    }
    let shards: Vec<ShardSummary> = by_index
        .into_iter()
        .collect::<Option<Vec<ShardSummary>>>()
        .context("merge: internal error — a shard index was left unfilled")?;

    // Re-interleave: global point g was computed by shard g % count at
    // local position g / count.
    let total = points_total.unwrap_or(0);
    let mut results = Vec::with_capacity(total);
    for g in 0..total {
        results.push(shards[g % count].results[g / count].clone());
    }
    Ok(MergedSweep {
        spec_name: name.context("merge: no shard file recorded a spec name")?,
        fingerprint: fingerprint.context("merge: no shard file recorded a fingerprint")?,
        shard_count: count,
        cost_model: cost_model.context("merge: no shard file recorded a cost-model version")?,
        results,
    })
}

/// Machine-readable summary of a merged sweep (the merged counterpart
/// of [`super::output::json_summary`]).
pub fn merged_json(m: &MergedSweep) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"sweep\": \"{}\",\n", json_escape(&m.spec_name)));
    out.push_str(&format!("  \"merged_from_shards\": {},\n", m.shard_count));
    out.push_str(&format!(
        "  \"fingerprint\": \"{}\",\n",
        json_escape(&m.fingerprint)
    ));
    out.push_str(&format!("  \"cost_model\": {},\n", m.cost_model));
    out.push_str(&format!("  \"points\": {},\n", m.results.len()));
    out.push_str("  \"systems\": [\n");
    let summaries = summarize(&m.results);
    for (i, s) in summaries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"system\": \"{}\", \"sms\": {}, \"points\": {}, \
             \"geomean_tops_w\": {}, \"geomean_gflops\": {}, \
             \"mean_utilization\": {}, \"peak_gflops\": {}}}{}\n",
            json_escape(&s.system),
            s.sms,
            s.points,
            json_f64(s.geomean_tops_w),
            json_f64(s.geomean_gflops),
            json_f64(s.mean_utilization),
            json_f64(s.peak_gflops),
            if i + 1 < summaries.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimPrimitive;
    use crate::coordinator::jobs::SystemSpec;
    use crate::sweep::engine::SweepEngine;
    use crate::sweep::output;

    fn spec() -> SweepSpec {
        SweepSpec::new("unit-shard")
            .workload(
                "w",
                vec![
                    Gemm::new(32, 32, 32),
                    Gemm::new(64, 64, 64),
                    Gemm::new(96, 96, 96),
                ],
            )
            .systems(vec![
                SystemSpec::Baseline,
                SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            ])
    }

    #[test]
    fn shard_id_parsing() {
        assert_eq!(ShardId::parse("0/2").unwrap(), ShardId { index: 0, count: 2 });
        assert_eq!(ShardId::parse("3/4").unwrap(), ShardId { index: 3, count: 4 });
        for bad in ["", "2", "2/2", "5/4", "a/2", "1/b", "1/0", "-1/2"] {
            assert!(ShardId::parse(bad).is_err(), "{bad:?} must not parse");
        }
        assert_eq!(ShardId { index: 1, count: 4 }.file_tag(), "shard1of4");
        assert_eq!(ShardId { index: 1, count: 4 }.to_string(), "1/4");
    }

    #[test]
    fn slices_partition_the_job_list() {
        let jobs: Vec<u32> = (0..11).collect();
        for count in 1..=4usize {
            let mut seen: Vec<u32> = Vec::new();
            for index in 0..count {
                let shard = ShardId { index, count };
                let slice = shard.slice(&jobs);
                assert_eq!(slice.len(), shard.len_of(jobs.len()), "{shard}");
                seen.extend(&slice);
            }
            seen.sort_unstable();
            assert_eq!(seen, jobs, "count={count}: shards must partition");
        }
    }

    #[test]
    fn fingerprint_tracks_every_axis() {
        let arch = Architecture::default_sm();
        let base = sweep_fingerprint(&arch, &spec());
        assert_eq!(base, sweep_fingerprint(&arch, &spec()), "deterministic");
        let mut s = spec();
        s.workloads[0].1.pop();
        assert_ne!(base, sweep_fingerprint(&arch, &s));
        let mut s = spec();
        s.systems.pop();
        assert_ne!(base, sweep_fingerprint(&arch, &s));
        let mut s = spec();
        s.sm_counts = vec![1, 4];
        assert_ne!(base, sweep_fingerprint(&arch, &s));
        let s = spec().mapper(crate::sweep::spec::MapperChoice::duplication());
        assert_ne!(base, sweep_fingerprint(&arch, &s));
    }

    #[test]
    fn two_shards_merge_byte_identical_to_unsharded() {
        let arch = Architecture::default_sm();
        let spec = spec();
        let fp = sweep_fingerprint(&arch, &spec);
        let jobs = spec.jobs();

        let full = SweepEngine::new(arch.clone()).run_spec(&spec);
        let full_csv = output::results_csv(&full.results).unwrap().encode();

        let dir = std::env::temp_dir().join("www_cim_shard_unit");
        let _ = fs::remove_dir_all(&dir);
        let mut paths = Vec::new();
        for index in 0..2 {
            let shard = ShardId { index, count: 2 };
            let engine = SweepEngine::new(arch.clone());
            let run = engine.run_jobs_named(&spec.name, &shard.slice(&jobs));
            let path = dir.join(format!("{}.json", shard.file_tag()));
            write_shard_json(&run, shard, &fp, jobs.len(), &path).unwrap();
            paths.push(path);
        }

        // Merge order must not matter.
        paths.reverse();
        let merged = merge_files(&paths).unwrap();
        assert_eq!(merged.spec_name, "unit-shard");
        assert_eq!(merged.shard_count, 2);
        assert_eq!(merged.results.len(), full.results.len());
        for (a, b) in merged.results.iter().zip(&full.results) {
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.system, b.system);
            assert_eq!(a.gemm, b.gemm);
            assert_eq!(a.workload, b.workload);
            // Mappings travel through the shard files bit-exactly.
            assert_eq!(a.mapping, b.mapping);
        }
        assert!(
            merged.results.iter().any(|r| r.mapping.is_some()),
            "CiM rows must carry mappings through the merge"
        );
        let merged_csv = output::results_csv(&merged.results).unwrap().encode();
        assert_eq!(merged_csv, full_csv, "merged CSV must be byte-identical");

        let j = merged_json(&merged);
        assert!(j.contains("\"merged_from_shards\": 2"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn more_shards_than_points_merge_with_empty_shards() {
        // 6 grid points, 8-way sharding: shards 6 and 7 take zero jobs.
        // Their summaries must still encode, parse and merge, and the
        // merged CSV must stay byte-identical to the unsharded run.
        let arch = Architecture::default_sm();
        let spec = spec();
        let fp = sweep_fingerprint(&arch, &spec);
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 6);

        let full = SweepEngine::new(arch.clone()).run_spec(&spec);
        let full_csv = output::results_csv(&full.results).unwrap().encode();

        let dir = std::env::temp_dir().join("www_cim_shard_unit_empty");
        let _ = fs::remove_dir_all(&dir);
        let mut paths = Vec::new();
        for index in 0..8 {
            let shard = ShardId { index, count: 8 };
            let engine = SweepEngine::new(arch.clone());
            let run = engine.run_jobs_named(&spec.name, &shard.slice(&jobs));
            if index >= jobs.len() {
                assert_eq!(run.n_points(), 0, "shard {shard} must be empty");
            }
            let path = dir.join(format!("{}.json", shard.file_tag()));
            write_shard_json(&run, shard, &fp, jobs.len(), &path).unwrap();
            paths.push(path);
        }
        let merged = merge_files(&paths).unwrap();
        assert_eq!(merged.shard_count, 8);
        assert_eq!(merged.results.len(), jobs.len());
        let merged_csv = output::results_csv(&merged.results).unwrap().encode();
        assert_eq!(merged_csv, full_csv, "empty shards must not perturb the merge");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_rejects_mismatched_and_incomplete_shards() {
        let arch = Architecture::default_sm();
        let spec_a = spec();
        let spec_b = spec().sm_counts(vec![1, 2]);
        let dir = std::env::temp_dir().join("www_cim_shard_unit_reject");
        let _ = fs::remove_dir_all(&dir);

        let mk = |spec: &SweepSpec, shard: ShardId, tag: &str| -> PathBuf {
            let jobs = spec.jobs();
            let engine = SweepEngine::new(arch.clone());
            let run = engine.run_jobs_named(&spec.name, &shard.slice(&jobs));
            let path = dir.join(format!("{tag}.json"));
            write_shard_json(
                &run,
                shard,
                &sweep_fingerprint(&arch, spec),
                jobs.len(),
                &path,
            )
            .unwrap();
            path
        };

        let a0 = mk(&spec_a, ShardId { index: 0, count: 2 }, "a0");
        let a1 = mk(&spec_a, ShardId { index: 1, count: 2 }, "a1");
        let b1 = mk(&spec_b, ShardId { index: 1, count: 2 }, "b1");

        // Different spec -> different fingerprint -> refused.
        let err = merge_files(&[a0.clone(), b1]).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        // Missing shard -> refused.
        let err = merge_files(&[a0.clone()]).unwrap_err();
        assert!(format!("{err:#}").contains("required exactly once"), "{err:#}");
        // Duplicate shard -> refused.
        let err = merge_files(&[a0.clone(), a0.clone()]).unwrap_err();
        assert!(format!("{err:#}").contains("more than once"), "{err:#}");
        // The healthy pair still merges.
        assert!(merge_files(&[a0, a1]).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }
}
