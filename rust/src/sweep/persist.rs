//! Disk persistence for the [`EvalCache`] — warm sweeps across
//! processes.
//!
//! The cache serializes to a versioned line-oriented file
//! (`results/cache.bin` by convention): a header line embedding the
//! cache-format version, the **cost-model version**
//! ([`crate::cost::COST_MODEL_VERSION`]) and the **mapper version**
//! ([`crate::mapping::MAPPER_VERSION`]), then one tab-separated line
//! per entry (point key, GEMM dims, last-used stamp, canonical
//! mapping, metrics). Float metrics — and the mapping's occupancy
//! field — are stored as IEEE-754 bit patterns in hex, so a save →
//! load round trip is bit-identical and a warm run reproduces a cold
//! run exactly. The mapping column is the [`Mapping::canonical`] form,
//! or `-` for baseline points.
//!
//! The last-used stamp (unix seconds, preserved across round trips and
//! refreshed whenever an entry is served or computed) powers the
//! optional **size cap**: [`save_capped`] trims the written union
//! least-recently-used first until the file fits `max_bytes`, so a
//! long-lived shared cache file stops growing without bound
//! (`--cache-max-mb` on the CLI, `cache.max_bytes` in a scenario).
//!
//! Loading is *compatible-or-salvaged*: a file whose header does not
//! match the running binary's versions is ignored wholesale
//! ([`CacheLoad::Discarded`]) rather than trusted partially or turned
//! into a hard error — a bumped cost-model version (or mapper version,
//! or cache-format version; pre-v4 files fall here) invalidates every
//! persisted entry instead of serving stale metrics or mapper-less
//! entries. A file with a *compatible* header whose body is damaged —
//! a torn tail from a crashed writer, a flipped byte — is salvaged
//! line by line instead: every entry carries a trailing fnv1a-64
//! checksum (format v4), lines that verify are kept, corrupt lines
//! are dropped, and the damaged original is moved aside to
//! `<cache>.quarantine.<pid>` for post-mortem
//! ([`CacheLoad::Salvaged`]). One interrupted save can therefore no
//! longer cost hours of cached mapper searches. Saves are atomic
//! (pid-unique temp file + rename, via [`crate::util::fsx`] with the
//! `persist.write`/`persist.rename` fault points), so a crash mid-save
//! can corrupt at worst a temp file, never the cache — and each save's
//! read-union-write cycle holds a sidecar lock file
//! (`<cache>.lock`, create-exclusive with bounded retry), so processes
//! sharing one `--cache` path accumulate a true union even when their
//! saves race: the rename-loser's entries are merged by the winner
//! instead of dropped (see [`save`]).

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cost::{EnergyBreakdown, Metrics, COST_MODEL_VERSION};
use crate::mapping::{Mapping, MAPPER_VERSION};
use crate::util::{fsx, hash::fnv1a};
use crate::workload::Gemm;

use super::cache::{f64_bits_hex, CacheEntry, EvalCache};

/// Version of the on-disk cache layout itself (header + line format).
/// Bump on any format change; old files are then discarded on load.
/// v2: entries gained the canonical-mapping column and the header the
/// `mapper=` token (v1 files — PR 2's format — are discarded).
/// v3: entries gained the last-used stamp column (unix seconds), the
/// recency signal for `max_bytes` LRU eviction (v2 files discarded).
/// v4: entries gained a trailing fnv1a-64 checksum column over the rest
/// of the line, the per-line integrity signal that lets `load_into`
/// salvage intact entries from a damaged file (v3 files discarded).
pub const CACHE_FORMAT_VERSION: u32 = 4;

/// First token of the header line — identifies the file type.
const MAGIC: &str = "www-cim-cache";

/// Fields per serialized [`Metrics`] (see [`metrics_fields`] order).
const METRIC_FIELDS: usize = 18;

/// Fields per entry line: point key, 3 GEMM dims, last-used stamp,
/// mapping, metrics, trailing checksum.
const ENTRY_FIELDS: usize = 7 + METRIC_FIELDS;

/// Mapping column marker for entries without a mapping (baseline).
const NO_MAPPING: &str = "-";

/// Outcome of [`load_into`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheLoad {
    /// No cache file at the path (a cold start, not an error).
    Missing,
    /// Compatible file; `entries` points preloaded.
    Loaded { entries: usize },
    /// Compatible header but a damaged body: `kept` checksum-verified
    /// entries preloaded, `dropped` corrupt lines skipped, and the
    /// damaged original moved to `<cache>.quarantine.<pid>` when
    /// `quarantined` (the move is best-effort).
    Salvaged {
        kept: usize,
        dropped: usize,
        quarantined: bool,
    },
    /// Incompatible or unrecognizable file; nothing was preloaded.
    Discarded { reason: String },
}

impl CacheLoad {
    /// One-line human-readable description for CLI status output.
    pub fn describe(&self) -> String {
        match self {
            CacheLoad::Missing => "no persisted cache (cold start)".to_string(),
            CacheLoad::Loaded { entries } => {
                format!("loaded {entries} persisted design points")
            }
            CacheLoad::Salvaged {
                kept,
                dropped,
                quarantined,
            } => {
                let tail = if *quarantined {
                    "; damaged original quarantined"
                } else {
                    ""
                };
                format!(
                    "salvaged {kept} of {} persisted design points \
                     ({dropped} corrupt line(s) dropped{tail})",
                    kept + dropped
                )
            }
            CacheLoad::Discarded { reason } => {
                format!("discarded persisted cache: {reason}")
            }
        }
    }
}

/// The header line the running binary writes and accepts.
fn header() -> String {
    format!(
        "{MAGIC}\tformat={CACHE_FORMAT_VERSION}\tcost-model={COST_MODEL_VERSION}\t\
         mapper={MAPPER_VERSION}"
    )
}

/// Serialize one [`Metrics`] to its stable field list: integers in
/// decimal, floats as exact bit patterns. The order is part of the
/// persisted format — extend only together with
/// [`CACHE_FORMAT_VERSION`].
pub fn metrics_fields(m: &Metrics) -> Vec<String> {
    vec![
        m.macs.to_string(),
        m.ops.to_string(),
        f64_bits_hex(m.energy_pj),
        f64_bits_hex(m.breakdown.dram_pj),
        f64_bits_hex(m.breakdown.smem_pj),
        f64_bits_hex(m.breakdown.rf_pj),
        f64_bits_hex(m.breakdown.pe_buf_pj),
        f64_bits_hex(m.breakdown.mac_pj),
        f64_bits_hex(m.breakdown.reduction_pj),
        f64_bits_hex(m.tops_per_watt),
        m.compute_cycles.to_string(),
        m.dram_cycles.to_string(),
        m.smem_cycles.to_string(),
        m.total_cycles.to_string(),
        f64_bits_hex(m.gflops),
        f64_bits_hex(m.utilization),
        m.dram_bytes.to_string(),
        m.smem_bytes.to_string(),
    ]
}

fn parse_u64(s: &str) -> Result<u64> {
    s.parse::<u64>()
        .with_context(|| format!("bad integer field {s:?}"))
}

fn parse_f64_bits(s: &str) -> Result<f64> {
    let bits =
        u64::from_str_radix(s, 16).with_context(|| format!("bad float bit pattern {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// Inverse of [`metrics_fields`].
pub fn metrics_from_fields(fields: &[&str]) -> Result<Metrics> {
    if fields.len() != METRIC_FIELDS {
        bail!(
            "metrics want {METRIC_FIELDS} fields, got {}",
            fields.len()
        );
    }
    Ok(Metrics {
        macs: parse_u64(fields[0])?,
        ops: parse_u64(fields[1])?,
        energy_pj: parse_f64_bits(fields[2])?,
        breakdown: EnergyBreakdown {
            dram_pj: parse_f64_bits(fields[3])?,
            smem_pj: parse_f64_bits(fields[4])?,
            rf_pj: parse_f64_bits(fields[5])?,
            pe_buf_pj: parse_f64_bits(fields[6])?,
            mac_pj: parse_f64_bits(fields[7])?,
            reduction_pj: parse_f64_bits(fields[8])?,
        },
        tops_per_watt: parse_f64_bits(fields[9])?,
        compute_cycles: parse_u64(fields[10])?,
        dram_cycles: parse_u64(fields[11])?,
        smem_cycles: parse_u64(fields[12])?,
        total_cycles: parse_u64(fields[13])?,
        gflops: parse_f64_bits(fields[14])?,
        utilization: parse_f64_bits(fields[15])?,
        dram_bytes: parse_u64(fields[16])?,
        smem_bytes: parse_u64(fields[17])?,
    })
}

/// One serialized entry line (no trailing newline). The final column
/// is an fnv1a-64 checksum (16 hex digits) over everything before it
/// — the per-line integrity signal salvaging loads verify.
fn encode_entry(point: &str, gemm: &Gemm, last_used: u64, entry: &CacheEntry) -> String {
    let mut line = String::new();
    line.push_str(point);
    line.push('\t');
    line.push_str(&format!(
        "{}\t{}\t{}\t{last_used}\t",
        gemm.m, gemm.n, gemm.k
    ));
    match &entry.mapping {
        Some(m) => line.push_str(&m.canonical()),
        None => line.push_str(NO_MAPPING),
    }
    for field in metrics_fields(&entry.metrics) {
        line.push('\t');
        line.push_str(&field);
    }
    let sum = fnv1a(line.as_bytes());
    line.push('\t');
    line.push_str(&format!("{sum:016x}"));
    line
}

/// Serialize the whole cache (header + sorted entries). Deterministic:
/// equal cache contents (stamps included — one stamp per process, see
/// `EvalCache::run_stamp`) produce byte-identical files.
pub fn encode(cache: &EvalCache) -> String {
    encode_capped(cache, None).0
}

/// [`encode`] under an optional size cap: when the full serialization
/// exceeds `max_bytes`, entries are evicted least-recently-used first
/// (oldest last-used stamp; ties broken toward the entry latest in the
/// canonical (point, GEMM) order, so trimming is deterministic) until
/// the file fits. Returns the encoded text and the eviction count. The
/// header always survives — a cap smaller than one entry produces a
/// valid, empty cache file.
pub fn encode_capped(cache: &EvalCache, max_bytes: Option<u64>) -> (String, usize) {
    let snapshot = cache.snapshot_stamped();
    let lines: Vec<String> = snapshot
        .iter()
        .map(|(point, gemm, last_used, entry)| encode_entry(point, gemm, *last_used, entry))
        .collect();
    let header = header();
    let full: u64 = (header.len() + 1) as u64
        + lines.iter().map(|l| (l.len() + 1) as u64).sum::<u64>();
    let keep: Vec<bool> = match max_bytes {
        Some(cap) if full > cap => {
            // Most-recently-used first; within one stamp, earlier
            // canonical positions survive longer.
            let mut order: Vec<usize> = (0..lines.len()).collect();
            order.sort_by_key(|&i| (std::cmp::Reverse(snapshot[i].2), i));
            let mut keep = vec![false; lines.len()];
            let mut size = (header.len() + 1) as u64;
            for i in order {
                let line_size = (lines[i].len() + 1) as u64;
                if size + line_size > cap {
                    // Strict LRU: nothing older than the first entry
                    // that does not fit survives either.
                    break;
                }
                size += line_size;
                keep[i] = true;
            }
            keep
        }
        // Under the cap (or uncapped): everything survives.
        Some(_) | None => vec![true; lines.len()],
    };
    let mut out = String::new();
    out.push_str(&header);
    out.push('\n');
    let mut evicted = 0usize;
    for (line, kept) in lines.iter().zip(&keep) {
        if *kept {
            out.push_str(line);
            out.push('\n');
        } else {
            evicted += 1;
        }
    }
    (out, evicted)
}

/// Write the cache to `path` atomically (unique temp file + rename),
/// creating parent directories. Returns the number of entries written.
///
/// Saving first folds any *compatible* entries already at `path` into
/// the in-memory cache, so the written file is the union of both —
/// shard processes pointing `--cache` at one file each contribute
/// their slice instead of overwriting each other's. The whole
/// read-union-write cycle runs under a sidecar lock file
/// (`<cache>.lock`, create-exclusive, bounded retry with a stale-lock
/// breaker — see [`SaveLock`]), which closes the historical
/// last-writer-wins window: two shards finishing at the same instant
/// serialize their saves, so the second one merges the first one's
/// entries rather than renaming over them.
pub fn save(cache: &EvalCache, path: &Path) -> Result<usize> {
    save_capped(cache, path, None).map(|o| o.entries)
}

/// How long an acquire waits for `<cache>.lock` before presuming its
/// holder died mid-save and breaking the lock (once). Generous: a real
/// save holds the lock for milliseconds.
const LOCK_DEADLINE: Duration = Duration::from_secs(5);

/// RAII guard serializing concurrent saves to one cache path via a
/// sidecar `<cache>.lock` file. std offers no portable byte-range
/// locking, but `O_CREAT|O_EXCL` (create-exclusive) is atomic on every
/// platform we target, including over NFS mounts modern enough to
/// matter — so the lock is a file whose *existence* is the lock.
///
/// Acquire retries with a growing sleep for [`LOCK_DEADLINE`]; if the
/// lock still exists after that (a holder that crashed between
/// creating it and its `Drop`), it is presumed stale and broken once —
/// a second full deadline expiring is an error, not a second break, so
/// two live processes can never steal the lock from each other
/// repeatedly. The holder's pid is written into the file to make a
/// stuck lock diagnosable. Dropping the guard removes the file.
struct SaveLock {
    path: PathBuf,
}

impl SaveLock {
    fn acquire(cache_path: &Path) -> Result<SaveLock> {
        let path = lock_path(cache_path);
        let mut start = Instant::now();
        let mut sleep = Duration::from_millis(5);
        let mut broke_stale = false;
        loop {
            match fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut file) => {
                    use std::io::Write;
                    // Best-effort diagnostics; the lock is the file's
                    // existence, not its content.
                    let _ = writeln!(file, "{}", std::process::id());
                    return Ok(SaveLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if start.elapsed() >= LOCK_DEADLINE {
                        if broke_stale {
                            bail!(
                                "cache lock {} still held after two {}s waits — \
                                 remove it manually if no saver is running",
                                path.display(),
                                LOCK_DEADLINE.as_secs()
                            );
                        }
                        broke_stale = true;
                        start = Instant::now();
                        let _ = fs::remove_file(&path);
                        continue;
                    }
                    std::thread::sleep(sleep);
                    sleep = (sleep * 2).min(Duration::from_millis(100));
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating cache lock {}", path.display()))
                }
            }
        }
    }
}

impl Drop for SaveLock {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

/// The sidecar lock path for a cache file: `<cache>.lock`.
fn lock_path(cache_path: &Path) -> PathBuf {
    let name = cache_path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("cache.bin");
    cache_path.with_file_name(format!("{name}.lock"))
}

/// Outcome of [`save_capped`]: how many entries were written and how
/// many the size cap evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SaveOutcome {
    pub entries: usize,
    pub evicted: usize,
}

impl SaveOutcome {
    /// One-line human-readable description for CLI status output.
    pub fn describe(&self) -> String {
        if self.evicted == 0 {
            format!("saved {} design points", self.entries)
        } else {
            format!(
                "saved {} design points ({} LRU-evicted by the size cap)",
                self.entries, self.evicted
            )
        }
    }
}

/// [`save`] under an optional `max_bytes` size cap (the ROADMAP's cache
/// eviction story): the on-disk union is trimmed least-recently-used
/// first until the file fits, so a shared cache file stops growing
/// without bound across runs while the entries current runs actually
/// touch stay warm. The in-memory cache is never trimmed — only the
/// written file is.
pub fn save_capped(
    cache: &EvalCache,
    path: &Path,
    max_bytes: Option<u64>,
) -> Result<SaveOutcome> {
    // The lock lives next to the cache file, so the parent dir must
    // exist before acquiring.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating cache dir {}", parent.display()))?;
        }
    }
    // Hold the sidecar lock across the whole read-union-write cycle:
    // a concurrent saver's entries land on disk either before our
    // load_into (merged into our union) or after our rename (merging
    // ours in turn) — never in between, where they would be lost.
    let _lock = SaveLock::acquire(path)?;
    // Loaded => existing entries merged into the union written below;
    // Missing/Discarded => nothing (valid) to merge. A real read error
    // must propagate: overwriting a file we could not read would
    // silently destroy previously persisted entries.
    load_into(cache, path)
        .with_context(|| format!("refusing to overwrite unreadable cache {}", path.display()))?;
    let (text, evicted) = encode_capped(cache, max_bytes);
    fsx::write_atomic_named(path, &text, "persist.write", "persist.rename")
        .with_context(|| format!("writing cache file {}", path.display()))?;
    Ok(SaveOutcome {
        entries: cache.len() - evicted,
        evicted,
    })
}

/// Post-mortem destination for a damaged cache file:
/// `<cache>.quarantine.<pid>` next to the original, so a salvaging
/// load leaves the evidence behind instead of silently rewriting it.
fn quarantine_path(cache_path: &Path) -> PathBuf {
    let name = cache_path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("cache.bin");
    cache_path.with_file_name(format!("{name}.quarantine.{}", std::process::id()))
}

/// Parse one v4 entry line: verify the trailing checksum, then decode
/// the body fields. Any failure condemns this line only — the caller
/// salvages around it.
fn parse_entry_line(line: &str) -> Result<(String, Gemm, u64, CacheEntry)> {
    let (body, sum_text) = match line.rsplit_once('\t') {
        Some(parts) => parts,
        None => bail!("no checksum column"),
    };
    let sum = match u64::from_str_radix(sum_text, 16) {
        Ok(s) if sum_text.len() == 16 => s,
        // A short/long or non-hex checksum field is corruption, spelled
        // exhaustively (lint R5).
        Ok(_) | Err(_) => bail!("bad checksum field {sum_text:?}"),
    };
    if fnv1a(body.as_bytes()) != sum {
        bail!("checksum mismatch");
    }
    let fields: Vec<&str> = body.split('\t').collect();
    if fields.len() != ENTRY_FIELDS - 1 {
        bail!("{} fields, want {ENTRY_FIELDS}", fields.len() + 1);
    }
    let dims = (
        parse_u64(fields[1]),
        parse_u64(fields[2]),
        parse_u64(fields[3]),
    );
    let gemm = match dims {
        (Ok(m), Ok(n), Ok(k)) if m > 0 && n > 0 && k > 0 => Gemm::new(m, n, k),
        // Any parse failure — or a zero dimension slipping past the
        // guard — is corruption, spelled exhaustively (lint R5).
        (Ok(_) | Err(_), _, _) => bail!("corrupt GEMM dims"),
    };
    let last_used = parse_u64(fields[4]).context("corrupt last-used stamp")?;
    let mapping = if fields[5] == NO_MAPPING {
        None
    } else {
        match Mapping::from_canonical(fields[5]) {
            // The mapping's embedded GEMM must agree with the entry key
            // it is stored under — a mismatch means the line was
            // spliced or hand-edited (with a recomputed checksum, or it
            // would already have failed above).
            Ok(m) if m.gemm == gemm => Some(Arc::new(m)),
            Ok(_) => bail!("mapping/GEMM mismatch"),
            Err(e) => bail!("corrupt mapping: {e:#}"),
        }
    };
    let metrics = metrics_from_fields(&fields[6..]).context("corrupt metrics")?;
    Ok((
        fields[0].to_string(),
        gemm,
        last_used,
        CacheEntry { mapping, metrics },
    ))
}

/// Load a persisted cache into `cache` (no hit/miss counter changes).
/// A missing file is a cold start; an incompatible header is discarded
/// in full; a compatible file with damaged lines is salvaged — every
/// checksum-verified line kept, corrupt lines dropped, the damaged
/// original quarantined — and only I/O failures on an existing file
/// error.
pub fn load_into(cache: &EvalCache, path: &Path) -> Result<CacheLoad> {
    let discard = |reason: String| Ok(CacheLoad::Discarded { reason });
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(CacheLoad::Missing),
        Err(e) => {
            return Err(e).with_context(|| format!("reading cache file {}", path.display()))
        }
    };
    let mut lines = text.lines();
    let head = match lines.next() {
        Some(h) => h,
        None => return discard("empty file".to_string()),
    };
    if head != header() {
        if !head.starts_with(MAGIC) {
            return discard("not a www-cim cache file".to_string());
        }
        return discard(format!(
            "incompatible header {head:?} (this binary writes {:?})",
            header()
        ));
    }
    // Salvage line by line: keep every entry whose checksum verifies,
    // drop the rest. Parsing completes before any preload so a
    // quarantine rename below never races a half-loaded cache.
    let mut parsed: Vec<(String, Gemm, u64, CacheEntry)> = Vec::new();
    let mut dropped = 0usize;
    for (i, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        match parse_entry_line(line) {
            Ok(entry) => parsed.push(entry),
            Err(e) => {
                eprintln!("[cache] dropping corrupt line {}: {e:#}", i + 2);
                dropped += 1;
            }
        }
    }
    let kept = parsed.len();
    for (point, gemm, last_used, entry) in parsed {
        cache.preload_stamped(&point, gemm, entry, last_used);
    }
    if dropped > 0 {
        // Move the damaged original aside (best-effort — the load
        // succeeded regardless): the next save writes a clean file and
        // the evidence survives for post-mortem.
        let quarantined = fs::rename(path, quarantine_path(path)).is_ok();
        return Ok(CacheLoad::Salvaged {
            kept,
            dropped,
            quarantined,
        });
    }
    Ok(CacheLoad::Loaded { entries: kept })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, CimSystem, MemLevel};
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn metrics(seed: f64) -> Metrics {
        Metrics {
            macs: 10,
            ops: 20,
            energy_pj: seed,
            breakdown: EnergyBreakdown {
                dram_pj: seed * 0.1,
                smem_pj: seed * 0.2,
                rf_pj: seed * 0.3,
                pe_buf_pj: 0.0,
                mac_pj: seed * 0.4,
                reduction_pj: seed / 3.0,
            },
            tops_per_watt: 20.0 / seed,
            compute_cycles: 100,
            dram_cycles: 90,
            smem_cycles: 80,
            total_cycles: 100,
            gflops: 0.2,
            utilization: 1.0 / 3.0,
            dram_bytes: 5,
            smem_bytes: 6,
        }
    }

    fn entry(seed: f64) -> CacheEntry {
        CacheEntry::metrics_only(metrics(seed))
    }

    fn mapped_entry(seed: f64, g: Gemm) -> CacheEntry {
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        CacheEntry {
            mapping: Some(Arc::new(PriorityMapper::new(&sys).map(&g))),
            metrics: metrics(seed),
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join("www_cim_persist_unit")
            .join(format!("{tag}.bin"))
    }

    #[test]
    fn metrics_fields_round_trip_bit_exact() {
        for seed in [1.0, 0.3, 1e-12, 7.25e9] {
            let m = metrics(seed);
            let fields = metrics_fields(&m);
            assert_eq!(fields.len(), METRIC_FIELDS);
            let refs: Vec<&str> = fields.iter().map(String::as_str).collect();
            assert_eq!(metrics_from_fields(&refs).unwrap(), m);
        }
    }

    #[test]
    fn save_load_round_trip() {
        let cache = EvalCache::new();
        cache.get_or_compute("pt-a", Gemm::new(8, 8, 8), || entry(1.0));
        let g = Gemm::new(16, 32, 64);
        cache.get_or_compute("pt-b", g, || mapped_entry(2.5, g));
        let path = tmp_path("roundtrip");
        let _ = fs::remove_file(&path);
        assert_eq!(save(&cache, &path).unwrap(), 2);

        let fresh = EvalCache::new();
        let load = load_into(&fresh, &path).unwrap();
        assert_eq!(load, CacheLoad::Loaded { entries: 2 });
        assert_eq!(fresh.len(), 2);
        assert_eq!(fresh.hits() + fresh.misses(), 0, "preload must not count");
        let e = fresh.get_or_compute("pt-b", g, || panic!("persisted entry must hit"));
        // The whole entry — mapping included — survives bit-for-bit.
        assert_eq!(e, mapped_entry(2.5, g));
        let no_map = fresh.get_or_compute("pt-a", Gemm::new(8, 8, 8), || {
            panic!("persisted entry must hit")
        });
        assert_eq!(no_map, entry(1.0));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stamps_round_trip_and_lru_cap_evicts_oldest_first() {
        // Three entries with strictly ordered recency: two stale
        // (preloaded with old stamps), one fresh (computed this run).
        let cache = EvalCache::new();
        let now = cache.run_stamp();
        cache.preload_stamped("pt-oldest", Gemm::new(8, 8, 8), entry(1.0), now - 2000);
        cache.preload_stamped("pt-old", Gemm::new(8, 8, 8), entry(2.0), now - 1000);
        cache.get_or_compute("pt-fresh", Gemm::new(8, 8, 8), || entry(3.0));

        // Uncapped: stamps survive the save → load round trip.
        let path = tmp_path("stamps");
        let _ = fs::remove_file(&path);
        assert_eq!(save(&cache, &path).unwrap(), 3);
        let reloaded = EvalCache::new();
        assert_eq!(
            load_into(&reloaded, &path).unwrap(),
            CacheLoad::Loaded { entries: 3 }
        );
        let stamps: Vec<(String, u64)> = reloaded
            .snapshot_stamped()
            .into_iter()
            .map(|(p, _, s, _)| (p, s))
            .collect();
        assert_eq!(
            stamps,
            vec![
                ("pt-fresh".to_string(), now),
                ("pt-old".to_string(), now - 1000),
                ("pt-oldest".to_string(), now - 2000),
            ]
        );

        // Capped: a budget with room for exactly two entries keeps the
        // two most recently used and evicts the oldest.
        let full_len = encode(&cache).len() as u64;
        let one_entry = encode_entry(
            "pt-oldest",
            &Gemm::new(8, 8, 8),
            now - 2000,
            &entry(1.0),
        )
        .len() as u64
            + 1;
        let capped_path = tmp_path("capped");
        let _ = fs::remove_file(&capped_path);
        let outcome = save_capped(&cache, &capped_path, Some(full_len - one_entry)).unwrap();
        assert_eq!(outcome, SaveOutcome { entries: 2, evicted: 1 });
        assert!(outcome.describe().contains("1 LRU-evicted"), "{}", outcome.describe());
        let trimmed = EvalCache::new();
        assert_eq!(
            load_into(&trimmed, &capped_path).unwrap(),
            CacheLoad::Loaded { entries: 2 }
        );
        let kept: Vec<String> = trimmed
            .snapshot_stamped()
            .into_iter()
            .map(|(p, _, _, _)| p)
            .collect();
        assert_eq!(kept, vec!["pt-fresh".to_string(), "pt-old".to_string()]);
        // The in-memory cache is never trimmed by a capped save.
        assert_eq!(cache.len(), 3);

        // A cap below one entry still writes a valid (empty) cache.
        let tiny_path = tmp_path("tiny-cap");
        let _ = fs::remove_file(&tiny_path);
        let outcome = save_capped(&cache, &tiny_path, Some(1)).unwrap();
        assert_eq!(outcome, SaveOutcome { entries: 0, evicted: 3 });
        let empty = EvalCache::new();
        assert_eq!(
            load_into(&empty, &tiny_path).unwrap(),
            CacheLoad::Loaded { entries: 0 }
        );
        for p in [path, capped_path, tiny_path] {
            let _ = fs::remove_file(&p);
        }
    }

    #[test]
    fn capped_save_merges_disk_union_before_trimming() {
        // Run 1 persists an entry; run 2 (simulated: a fresh cache with
        // a *newer* stamp for a different entry) saves with a cap that
        // fits only one entry — the union is formed first, then the
        // stale on-disk entry is the one evicted.
        let path = tmp_path("cap-union");
        let _ = fs::remove_file(&path);
        let run1 = EvalCache::new();
        let now = run1.run_stamp();
        run1.preload_stamped("pt-disk", Gemm::new(8, 8, 8), entry(1.0), now - 5000);
        save(&run1, &path).unwrap();

        let run2 = EvalCache::new();
        run2.get_or_compute("pt-live", Gemm::new(8, 8, 8), || entry(2.0));
        let line = encode_entry("pt-live", &Gemm::new(8, 8, 8), now, &entry(2.0));
        let cap = (header().len() + 1 + line.len() + 1) as u64;
        let outcome = save_capped(&run2, &path, Some(cap)).unwrap();
        assert_eq!(outcome, SaveOutcome { entries: 1, evicted: 1 });
        let reloaded = EvalCache::new();
        assert_eq!(
            load_into(&reloaded, &path).unwrap(),
            CacheLoad::Loaded { entries: 1 }
        );
        assert_eq!(reloaded.snapshot()[0].0, "pt-live");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn pr4_format_v3_cache_is_discarded_wholesale() {
        // A PR 4-era file: format=3 header, no per-entry checksum
        // column. The versioning contract discards it in full —
        // salvage only applies within the current format.
        let path = tmp_path("pr4-format");
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        let mut old = format!(
            "{MAGIC}\tformat=3\tcost-model={COST_MODEL_VERSION}\tmapper={MAPPER_VERSION}\n"
        );
        old.push_str("pt\t8\t8\t8\t12345\t-");
        for f in metrics_fields(&metrics(1.0)) {
            old.push('\t');
            old.push_str(&f);
        }
        old.push('\n');
        fs::write(&path, old).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("incompatible header"), "{reason}");
            }
            other => panic!("format-v3 cache must be discarded, got {other:?}"),
        }
        assert!(fresh.is_empty(), "no v3 entries may survive");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn pr3_format_v2_cache_is_discarded_wholesale() {
        // A PR 3-era file: format=2 header, no last-used column. The
        // versioning contract discards it in full.
        let path = tmp_path("pr3-format");
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        let mut old = format!(
            "{MAGIC}\tformat=2\tcost-model={COST_MODEL_VERSION}\tmapper={MAPPER_VERSION}\n"
        );
        old.push_str("pt\t8\t8\t8\t-");
        for f in metrics_fields(&metrics(1.0)) {
            old.push('\t');
            old.push_str(&f);
        }
        old.push('\n');
        fs::write(&path, old).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("incompatible header"), "{reason}");
            }
            other => panic!("format-v2 cache must be discarded, got {other:?}"),
        }
        assert!(fresh.is_empty(), "no v2 entries may survive");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn pr2_format_v1_cache_is_discarded_wholesale() {
        // A PR 2-era file: format=1 header and 22-field entries (no
        // mapping column). Per the versioning contract it is discarded
        // in full — zero entries may survive into the live cache.
        let path = tmp_path("pr2-format");
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        let mut old = format!("{MAGIC}\tformat=1\tcost-model={COST_MODEL_VERSION}\n");
        old.push_str("pt\t8\t8\t8");
        for f in metrics_fields(&metrics(1.0)) {
            old.push('\t');
            old.push_str(&f);
        }
        old.push('\n');
        fs::write(&path, old).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("incompatible header"), "{reason}");
            }
            other => panic!("format-v1 cache must be discarded, got {other:?}"),
        }
        assert!(fresh.is_empty(), "no v1 entries may survive");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stale_mapper_version_discards_the_file() {
        let cache = EvalCache::new();
        let g = Gemm::new(8, 8, 8);
        cache.get_or_compute("pt", g, || mapped_entry(1.0, g));
        let path = tmp_path("stale-mapper");
        save(&cache, &path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let stale = text.replacen(&format!("mapper={MAPPER_VERSION}"), "mapper=999999", 1);
        assert_ne!(text, stale, "header rewrite must take effect");
        fs::write(&path, stale).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("incompatible header"), "{reason}");
            }
            other => panic!("stale-mapper cache must be discarded, got {other:?}"),
        }
        assert!(fresh.is_empty(), "no entries may leak from a stale cache");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn spliced_line_fails_its_checksum_and_is_dropped() {
        // Splice the mapping of one entry under another entry's GEMM.
        // The edit invalidates the line's checksum, so the salvaging
        // load drops exactly that line.
        let cache = EvalCache::new();
        let g = Gemm::new(16, 32, 64);
        cache.get_or_compute("pt", g, || mapped_entry(1.0, g));
        let path = tmp_path("spliced");
        save(&cache, &path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let spliced = text.replacen("pt\t16\t32\t64\t", "pt\t16\t32\t65\t", 1);
        assert_ne!(text, spliced);
        fs::write(&path, spliced).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Salvaged {
                kept,
                dropped,
                quarantined,
            } => {
                assert_eq!((kept, dropped), (0, 1));
                assert!(quarantined, "damaged original must be moved aside");
            }
            other => panic!("spliced line must be dropped, got {other:?}"),
        }
        assert!(fresh.is_empty());
        assert!(!path.exists(), "quarantine must move the damaged file");
        assert!(quarantine_path(&path).exists());
        let _ = fs::remove_file(quarantine_path(&path));
    }

    #[test]
    fn spliced_line_with_recomputed_checksum_is_still_dropped() {
        // An adversarially hand-edited line — GEMM dims spliced *and*
        // the checksum recomputed to match — passes the integrity
        // check but still fails the semantic mapping/GEMM cross-check.
        let cache = EvalCache::new();
        let g = Gemm::new(16, 32, 64);
        cache.get_or_compute("pt", g, || mapped_entry(1.0, g));
        let path = tmp_path("spliced-resummed");
        save(&cache, &path).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        let (head, entry_line) = text.trim_end().split_once('\n').unwrap();
        let (body, _old_sum) = entry_line.rsplit_once('\t').unwrap();
        let spliced_body = body.replacen("pt\t16\t32\t64\t", "pt\t16\t32\t65\t", 1);
        assert_ne!(body, spliced_body);
        let resummed = format!(
            "{head}\n{spliced_body}\t{:016x}\n",
            fnv1a(spliced_body.as_bytes())
        );
        fs::write(&path, resummed).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Salvaged { kept, dropped, .. } => {
                assert_eq!((kept, dropped), (0, 1));
            }
            other => panic!("hand-edited line must be dropped, got {other:?}"),
        }
        assert!(fresh.is_empty());
        let _ = fs::remove_file(quarantine_path(&path));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_a_cold_start() {
        let fresh = EvalCache::new();
        let load = load_into(&fresh, &tmp_path("never-written")).unwrap();
        assert_eq!(load, CacheLoad::Missing);
        assert!(fresh.is_empty());
    }

    #[test]
    fn bumped_cost_model_version_discards_the_file() {
        let cache = EvalCache::new();
        cache.get_or_compute("pt", Gemm::new(8, 8, 8), || entry(1.0));
        let path = tmp_path("stale-model");
        save(&cache, &path).unwrap();
        // Simulate a cache written by a binary with a different cost
        // model: rewrite the header's version token.
        let text = fs::read_to_string(&path).unwrap();
        let stale = text.replacen(
            &format!("cost-model={COST_MODEL_VERSION}"),
            "cost-model=999999",
            1,
        );
        assert_ne!(text, stale, "header rewrite must take effect");
        fs::write(&path, stale).unwrap();

        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("incompatible header"), "{reason}");
            }
            other => panic!("stale cache must be discarded, got {other:?}"),
        }
        assert!(fresh.is_empty(), "no entries may leak from a stale cache");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn corrupt_tail_is_salvaged_around_not_discarded() {
        let cache = EvalCache::new();
        cache.get_or_compute("pt", Gemm::new(8, 8, 8), || entry(1.0));
        let path = tmp_path("corrupt");
        let _ = fs::remove_file(&path);
        save(&cache, &path).unwrap();
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("pt-broken\t1\t2\n"); // truncated entry
        fs::write(&path, &text).unwrap();

        let fresh = EvalCache::new();
        let load = load_into(&fresh, &path).unwrap();
        assert_eq!(
            load,
            CacheLoad::Salvaged {
                kept: 1,
                dropped: 1,
                quarantined: true
            }
        );
        assert!(load.describe().contains("salvaged 1 of 2"), "{}", load.describe());
        assert_eq!(fresh.len(), 1, "the intact entry must survive");
        let e = fresh.get_or_compute("pt", Gemm::new(8, 8, 8), || {
            panic!("salvaged entry must hit")
        });
        assert_eq!(e, entry(1.0));
        assert!(!path.exists(), "quarantine must move the damaged file");
        let _ = fs::remove_file(quarantine_path(&path));
    }

    #[test]
    fn salvaging_save_cycle_rewrites_a_clean_cache() {
        // End-to-end crash recovery: load a damaged file (salvage +
        // quarantine), then save — the new file is clean and loads as
        // Loaded, and the quarantined original is still on disk.
        let cache = EvalCache::new();
        cache.get_or_compute("pt", Gemm::new(8, 8, 8), || entry(1.0));
        let path = tmp_path("salvage-cycle");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(quarantine_path(&path));
        save(&cache, &path).unwrap();
        let mut text = fs::read_to_string(&path).unwrap();
        let torn_at = text.len() - 10; // tear inside the last line
        text.truncate(torn_at);
        fs::write(&path, &text).unwrap();

        let recovered = EvalCache::new();
        match load_into(&recovered, &path).unwrap() {
            CacheLoad::Salvaged { dropped, .. } => assert_eq!(dropped, 1),
            other => panic!("torn tail must salvage, got {other:?}"),
        }
        recovered.get_or_compute("pt-new", Gemm::new(4, 4, 4), || entry(2.0));
        save(&recovered, &path).unwrap();
        let reloaded = EvalCache::new();
        assert_eq!(
            load_into(&reloaded, &path).unwrap(),
            CacheLoad::Loaded { entries: 1 },
            "the re-saved cache must be clean"
        );
        assert!(quarantine_path(&path).exists(), "evidence must survive");
        let _ = fs::remove_file(quarantine_path(&path));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn non_cache_file_is_discarded_not_an_error() {
        let path = tmp_path("not-a-cache");
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, "{\"json\": true}\n").unwrap();
        let fresh = EvalCache::new();
        match load_into(&fresh, &path).unwrap() {
            CacheLoad::Discarded { reason } => {
                assert!(reason.contains("not a www-cim cache"), "{reason}")
            }
            other => panic!("foreign file must be discarded, got {other:?}"),
        }
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn racing_saves_union_instead_of_last_writer_wins() {
        // Two threads repeatedly save disjoint single-entry caches to
        // one path, released from a barrier so the read-union-write
        // cycles actually overlap. The sidecar lock must serialize
        // them: every entry either lands before the rival's load_into
        // (and is merged) or after its rename (and merges the rival's)
        // — the historical last-writer-wins race dropped the loser's.
        let path = tmp_path("racing");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(&lock_path(&path));
        const ROUNDS: u64 = 8;
        let barrier = std::sync::Barrier::new(2);
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let barrier = &barrier;
                let path = &path;
                s.spawn(move || {
                    for round in 0..ROUNDS {
                        let cache = EvalCache::new();
                        let key = format!("pt-{t}-{round}");
                        cache.get_or_compute(&key, Gemm::new(8, 8, 8), || {
                            entry((t * 100 + round) as f64 + 1.0)
                        });
                        barrier.wait();
                        save(&cache, path).expect("racing save must succeed");
                    }
                });
            }
        });
        let merged = EvalCache::new();
        match load_into(&merged, &path).unwrap() {
            CacheLoad::Loaded { entries } => assert_eq!(
                entries as u64,
                2 * ROUNDS,
                "every racing save's entry must survive the union"
            ),
            other => panic!("expected Loaded, got {other:?}"),
        }
        assert!(
            !lock_path(&path).exists(),
            "the lock must be released after every save"
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn stale_lock_is_broken_after_the_deadline() {
        // A lock whose holder crashed mid-save must not wedge saves
        // forever: after LOCK_DEADLINE the acquirer breaks it once.
        let path = tmp_path("stale-lock");
        let _ = fs::remove_file(&path);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(lock_path(&path), "999999\n").unwrap();
        let cache = EvalCache::new();
        cache.get_or_compute("pt", Gemm::new(8, 8, 8), || entry(1.0));
        let start = Instant::now();
        assert_eq!(save(&cache, &path).unwrap(), 1);
        assert!(
            start.elapsed() >= LOCK_DEADLINE,
            "the breaker must wait out the full deadline first"
        );
        assert!(!lock_path(&path).exists(), "broken lock must not linger");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn encode_is_deterministic_regardless_of_insertion_order() {
        let a = EvalCache::new();
        a.get_or_compute("x", Gemm::new(1, 2, 3), || entry(1.0));
        a.get_or_compute("y", Gemm::new(4, 5, 6), || entry(2.0));
        let b = EvalCache::new();
        b.get_or_compute("y", Gemm::new(4, 5, 6), || entry(2.0));
        b.get_or_compute("x", Gemm::new(1, 2, 3), || entry(1.0));
        assert_eq!(encode(&a), encode(&b));
    }
}
