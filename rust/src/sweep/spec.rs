//! Declarative sweep specifications: cartesian grids over workloads,
//! CiM systems, SM counts and mapper choices, expanded into a flat
//! evaluation job list.
//!
//! The grid axes mirror the paper's three questions — *What* (the
//! [`crate::cim::CimPrimitive`]), *Where* (the integration point,
//! via [`SystemSpec`]), *When* (the workload GEMMs) — plus the
//! framework extensions (SM count, mapper choice).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::arch::{CimSystem, SmemConfig};
use crate::cim::CimPrimitive;
use crate::coordinator::jobs::SystemSpec;
use crate::cost::Metrics;
use crate::mapping::loopnest::Dim;
use crate::mapping::{ExhaustiveMapper, HeuristicMapper, Mapping, Objective, PriorityMapper};
use crate::util::rng::Rng;
use crate::workload::{models, synthetic, Gemm};

/// Which mapping algorithm scores each grid point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MapperChoice {
    /// The paper's priority-based mapper (Algo 1) — the default.
    Priority,
    /// Priority mapper with weight duplication across idle primitives
    /// (§IV-B future work), at a configurable balance threshold (the
    /// paper's default is 4 — [`MapperChoice::duplication`]).
    PriorityDuplication { threshold: u64 },
    /// Priority mapper with a non-default multi-primitive balance
    /// threshold (the `ablation-threshold` axis; the paper fixes it at
    /// 4). `PriorityThreshold { threshold: 4 }` behaves like
    /// [`MapperChoice::Priority`] but is a distinct cache point — no
    /// behavioral aliasing is attempted.
    PriorityThreshold { threshold: u64 },
    /// Priority mapper with the DRAM-level loop order overridden to a
    /// fixed permutation (the `ablation-order` axis).
    PriorityFixedOrder { order: [Dim; 3] },
    /// Random heuristic search with a valid-sample budget (Fig 7's
    /// comparator); seeded per GEMM for determinism.
    Heuristic { budget: u64, seed: u64 },
    /// Exhaustive enumeration of the discretized map-space — the true
    /// optimum under `objective` (the `optimality` axis). Orders of
    /// magnitude slower than the priority mapper; keep the GEMMs modest.
    Exhaustive { objective: Objective },
}

impl MapperChoice {
    /// The weight-duplication mapper at the paper's default balance
    /// threshold ([`crate::mapping::priority::BALANCE_THRESHOLD`]).
    pub fn duplication() -> MapperChoice {
        MapperChoice::PriorityDuplication {
            threshold: crate::mapping::priority::BALANCE_THRESHOLD,
        }
    }

    /// Stable fingerprint fragment for cache keys. Prefixed with
    /// [`crate::mapping::MAPPER_VERSION`]: cached metrics depend on the
    /// mapper *implementation*, not just its name, and keys now outlive
    /// the process (`--cache`) — a changed algorithm must never hit an
    /// older implementation's persisted entries.
    pub fn fingerprint(&self) -> String {
        let v = crate::mapping::MAPPER_VERSION;
        match self {
            MapperChoice::Priority => format!("v{v}:priority"),
            MapperChoice::PriorityDuplication { threshold } => {
                format!("v{v}:priority+dup:t{threshold}")
            }
            MapperChoice::PriorityThreshold { threshold } => {
                format!("v{v}:priority:t{threshold}")
            }
            MapperChoice::PriorityFixedOrder { order } => format!(
                "v{v}:priority:order-{}{}{}",
                order[0].name(),
                order[1].name(),
                order[2].name()
            ),
            MapperChoice::Heuristic { budget, seed } => format!("v{v}:heuristic:{budget}:{seed}"),
            MapperChoice::Exhaustive { objective } => {
                format!("v{v}:exhaustive:{}", objective.name())
            }
        }
    }

    /// Parse a CLI mapper name: `priority`, `priority:t<threshold>`,
    /// `priority:order-<perm>` (a permutation of `mnk`, e.g.
    /// `priority:order-kmn`), `dup[:t<threshold>]`,
    /// `heuristic[:budget]`, `exhaustive[:energy|delay|edp]`.
    ///
    /// Every [`MapperChoice`] variant is reachable from this syntax and
    /// [`Self::cli_spec`] is its inverse — the property the scenario
    /// API relies on to serialize a mapper axis as one string.
    pub fn parse(s: &str, seed: u64) -> Result<MapperChoice> {
        let s = s.to_ascii_lowercase();
        if s == "priority" {
            return Ok(MapperChoice::Priority);
        }
        if let Some(t) = s.strip_prefix("priority:t") {
            return match t.parse() {
                Ok(threshold) if threshold >= 1 => {
                    Ok(MapperChoice::PriorityThreshold { threshold })
                }
                _ => bail!("--mapper priority:t<threshold>: bad threshold {t:?}"),
            };
        }
        if let Some(perm) = s.strip_prefix("priority:order-") {
            return Ok(MapperChoice::PriorityFixedOrder {
                order: parse_dim_order(perm)?,
            });
        }
        if s == "dup" || s == "duplication" || s == "priority+dup" {
            return Ok(MapperChoice::duplication());
        }
        if let Some(t) = s.strip_prefix("dup:t") {
            return match t.parse() {
                Ok(threshold) if threshold >= 1 => {
                    Ok(MapperChoice::PriorityDuplication { threshold })
                }
                _ => bail!("--mapper dup:t<threshold>: bad threshold {t:?}"),
            };
        }
        if let Some(rest) = s.strip_prefix("heuristic") {
            let budget = match rest.strip_prefix(':') {
                None if rest.is_empty() => 500,
                Some(b) => match b.parse() {
                    Ok(v) => v,
                    Err(_) => bail!("--mapper heuristic:<budget>: bad budget {b:?}"),
                },
                _ => bail!("--mapper: unknown mapper {s:?}"),
            };
            return Ok(MapperChoice::Heuristic { budget, seed });
        }
        if let Some(rest) = s.strip_prefix("exhaustive") {
            let objective = match rest.strip_prefix(':') {
                None if rest.is_empty() => Objective::Energy,
                Some(o) => match Objective::parse(o) {
                    Some(obj) => obj,
                    None => bail!("--mapper exhaustive:<objective>: bad objective {o:?}"),
                },
                _ => bail!("--mapper: unknown mapper {s:?}"),
            };
            return Ok(MapperChoice::Exhaustive { objective });
        }
        bail!(
            "--mapper: unknown mapper {s:?} (priority, priority:t<n>, \
             priority:order-<mnk perm>, dup[:t<n>], heuristic[:budget], \
             exhaustive[:energy|delay|edp])"
        )
    }

    /// The canonical CLI/scenario spelling of this mapper — the inverse
    /// of [`Self::parse`]: `parse(&mc.cli_spec(), seed) == mc` for every
    /// variant (the heuristic's seed travels separately, as the
    /// sweep/scenario seed).
    pub fn cli_spec(&self) -> String {
        match self {
            MapperChoice::Priority => "priority".to_string(),
            MapperChoice::PriorityDuplication { threshold } => format!("dup:t{threshold}"),
            MapperChoice::PriorityThreshold { threshold } => format!("priority:t{threshold}"),
            MapperChoice::PriorityFixedOrder { order } => format!(
                "priority:order-{}{}{}",
                order[0].name().to_ascii_lowercase(),
                order[1].name().to_ascii_lowercase(),
                order[2].name().to_ascii_lowercase()
            ),
            MapperChoice::Heuristic { budget, .. } => format!("heuristic:{budget}"),
            MapperChoice::Exhaustive { objective } => {
                format!("exhaustive:{}", objective.name())
            }
        }
    }

    /// Produce the mapping for one GEMM on one CiM system.
    pub fn map(&self, sys: &CimSystem, gemm: &Gemm) -> Mapping {
        match self {
            MapperChoice::Priority => PriorityMapper::new(sys).map(gemm),
            MapperChoice::PriorityDuplication { threshold } => {
                PriorityMapper::with_threshold(sys, *threshold)
                    .with_weight_duplication()
                    .map(gemm)
            }
            MapperChoice::PriorityThreshold { threshold } => {
                PriorityMapper::with_threshold(sys, *threshold).map(gemm)
            }
            MapperChoice::PriorityFixedOrder { order } => {
                PriorityMapper::new(sys).map(gemm).with_dram_order(*order)
            }
            MapperChoice::Heuristic { budget, seed } => {
                let mut h = HeuristicMapper::new(sys);
                h.valid_budget = *budget;
                let mut rng = Rng::new(seed ^ gemm.m ^ gemm.n ^ gemm.k);
                h.map(gemm, &mut rng).0
            }
            MapperChoice::Exhaustive { objective } => {
                ExhaustiveMapper::new(sys, *objective).map(gemm).mapping
            }
        }
    }
}

/// Parse a three-letter `mnk` permutation (e.g. `kmn`) into a DRAM-level
/// loop order — the `priority:order-<perm>` mapper axis.
fn parse_dim_order(perm: &str) -> Result<[Dim; 3]> {
    let dims: Vec<Dim> = perm
        .chars()
        .map(|c| match c {
            'm' => Ok(Dim::M),
            'n' => Ok(Dim::N),
            'k' => Ok(Dim::K),
            other => bail!("--mapper priority:order-<perm>: bad dimension {other:?}"),
        })
        .collect::<Result<Vec<Dim>>>()?;
    if dims.len() != 3 || !Dim::all().iter().all(|d| dims.contains(d)) {
        bail!(
            "--mapper priority:order-<perm>: {perm:?} must be a permutation of \
             m, n, k (e.g. kmn)"
        );
    }
    Ok([dims[0], dims[1], dims[2]])
}

/// One evaluation job: a GEMM of a workload on a system configuration.
#[derive(Debug, Clone)]
pub struct SweepJob {
    /// Workload the GEMM came from (reporting key).
    pub workload: String,
    pub gemm: Gemm,
    pub spec: SystemSpec,
    /// Streaming-multiprocessor count (1 = the paper's single SM;
    /// larger counts apply the multi-SM scaling model).
    pub sms: u64,
    pub mapper: MapperChoice,
}

/// Result of one evaluated job.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub workload: String,
    pub gemm: Gemm,
    /// Human-readable system label (`CimSystem::label()` convention).
    pub system: String,
    pub sms: u64,
    pub metrics: Metrics,
    /// The (single-SM) mapping that produced the metrics — `None` for
    /// baseline points. Served from the cache on hits (shared via
    /// `Arc`, so a hit never deep-copies the loop nest), so post-hoc
    /// cost analyses (NoC sensitivity, duplication factors) never
    /// re-run the mapper.
    pub mapping: Option<Arc<Mapping>>,
}

/// A declarative design-space sweep: the cartesian product of the
/// workload, system, and SM-count axes under one mapper choice.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Named GEMM lists (workload axis).
    pub workloads: Vec<(String, Vec<Gemm>)>,
    /// System axis (baseline and/or CiM integrations).
    pub systems: Vec<SystemSpec>,
    /// SM-count axis (default `[1]`).
    pub sm_counts: Vec<u64>,
    pub mapper: MapperChoice,
    /// Batch axis the workload entries were expanded at (default
    /// `[1]`). Bookkeeping only: batching reshapes the GEMMs, so the
    /// batched shapes (and `@b<n>`-suffixed names) already live in
    /// `workloads` — see [`parse_workloads_batched`].
    pub batches: Vec<u64>,
}

impl SweepSpec {
    pub fn new(name: &str) -> Self {
        SweepSpec {
            name: name.to_string(),
            workloads: Vec::new(),
            systems: Vec::new(),
            sm_counts: vec![1],
            mapper: MapperChoice::Priority,
            batches: vec![1],
        }
    }

    /// Add one named workload (a list of GEMMs).
    pub fn workload(mut self, name: &str, gemms: Vec<Gemm>) -> Self {
        self.workloads.push((name.to_string(), gemms));
        self
    }

    /// Replace the workload axis.
    pub fn workloads(mut self, workloads: Vec<(String, Vec<Gemm>)>) -> Self {
        self.workloads = workloads;
        self
    }

    /// Add one system to the system axis.
    pub fn system(mut self, spec: SystemSpec) -> Self {
        self.systems.push(spec);
        self
    }

    /// Replace the system axis.
    pub fn systems(mut self, specs: Vec<SystemSpec>) -> Self {
        self.systems = specs;
        self
    }

    /// Replace the SM-count axis.
    pub fn sm_counts(mut self, counts: Vec<u64>) -> Self {
        assert!(!counts.is_empty(), "sm_counts axis must be non-empty");
        self.sm_counts = counts;
        self
    }

    pub fn mapper(mut self, mapper: MapperChoice) -> Self {
        self.mapper = mapper;
        self
    }

    /// Record the batch axis. The workload axis must already reflect it
    /// (use [`parse_workloads_batched`] or the batched model
    /// constructors); this only keeps the axis visible for reporting.
    pub fn batches(mut self, batches: Vec<u64>) -> Self {
        assert!(!batches.is_empty(), "batch axis must be non-empty");
        self.batches = batches;
        self
    }

    /// Total number of grid points.
    pub fn n_points(&self) -> usize {
        let gemms: usize = self.workloads.iter().map(|(_, g)| g.len()).sum();
        gemms * self.systems.len() * self.sm_counts.len()
    }

    /// Expand the grid, GEMM-major: workload → GEMM → system → SM count
    /// (the `Grid::cross` convention used by the per-workload figures).
    pub fn jobs(&self) -> Vec<SweepJob> {
        let mut out = Vec::with_capacity(self.n_points());
        for (name, gemms) in &self.workloads {
            for gemm in gemms {
                for spec in &self.systems {
                    for &sms in &self.sm_counts {
                        out.push(SweepJob {
                            workload: name.clone(),
                            gemm: *gemm,
                            spec: spec.clone(),
                            sms,
                            mapper: self.mapper,
                        });
                    }
                }
            }
        }
        out
    }

    /// Expand the grid, system-major: system → workload → GEMM → SM
    /// count (the per-primitive figures' convention, e.g. Fig 9).
    pub fn jobs_system_major(&self) -> Vec<SweepJob> {
        let mut out = Vec::with_capacity(self.n_points());
        for spec in &self.systems {
            for (name, gemms) in &self.workloads {
                for gemm in gemms {
                    for &sms in &self.sm_counts {
                        out.push(SweepJob {
                            workload: name.clone(),
                            gemm: *gemm,
                            spec: spec.clone(),
                            sms,
                            mapper: self.mapper,
                        });
                    }
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// CLI axis parsing (`repro sweep` grid flags).
// ---------------------------------------------------------------------

/// Resolve a comma-separated workload list. Accepted names: the real
/// models (`bert`, `gptj`, `resnet50`, `dlrm`), the zoo extensions
/// (`vit`, `llama-decode`, `llama-prefill`), the groups `real` /
/// `all`, and `synthetic[:N]` (seeded synthetic dataset). Each
/// workload contributes its deduplicated layer shapes.
pub fn parse_workloads(list: &str, seed: u64) -> Result<Vec<(String, Vec<Gemm>)>> {
    parse_workloads_batched(list, seed, &[1])
}

/// [`parse_workloads`] expanded over a batch axis: the full workload
/// list at every batch size in `batches`, batch-major. Batching
/// reshapes the GEMMs themselves (see [`Gemm::batched`]), so no other
/// layer needs a batch concept — entry names stay plain at batch 1
/// (making `&[1]` exactly the unbatched parse, cache keys and
/// fingerprints included) and gain an `@b<n>` suffix for larger
/// batches so grid rows and fingerprints stay distinguishable.
pub fn parse_workloads_batched(
    list: &str,
    seed: u64,
    batches: &[u64],
) -> Result<Vec<(String, Vec<Gemm>)>> {
    if batches.is_empty() {
        bail!("--batch: empty batch list");
    }
    let mut out: Vec<(String, Vec<Gemm>)> = Vec::new();
    for &batch in batches {
        if batch == 0 {
            bail!("--batch: batch sizes must be positive");
        }
        workloads_at_batch(&mut out, list, seed, batch)?;
    }
    if out.is_empty() {
        bail!("--workloads: empty workload list");
    }
    Ok(out)
}

/// Append the resolved workload list at one batch size.
fn workloads_at_batch(
    out: &mut Vec<(String, Vec<Gemm>)>,
    list: &str,
    seed: u64,
    batch: u64,
) -> Result<()> {
    fn push_model(out: &mut Vec<(String, Vec<Gemm>)>, w: crate::workload::Workload) {
        let name = if w.batch() > 1 {
            format!("{}@b{}", w.name, w.batch())
        } else {
            w.name.clone()
        };
        let gemms: Vec<Gemm> = w.unique_with_counts().into_iter().map(|(g, _)| g).collect();
        out.push((name, gemms));
    }
    for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match name.to_ascii_lowercase().as_str() {
            "bert" | "bert-large" => push_model(out, models::bert_large_batched(batch)),
            "gptj" | "gpt-j" => push_model(out, models::gpt_j_batched(batch)),
            "resnet" | "resnet50" => push_model(out, models::resnet50_batched(batch)),
            "dlrm" => push_model(out, models::dlrm_batched(batch)),
            "vit" | "vit-base" => push_model(out, models::vit_base_batched(batch)),
            "llama-decode" => push_model(out, models::llama2_7b_decode_batched(batch)),
            "llama-prefill" => push_model(out, models::llama2_7b_prefill_batched(2048, batch)),
            "real" => {
                for w in models::real_dataset_batched(batch) {
                    push_model(out, w);
                }
            }
            "all" | "zoo" => {
                for w in models::extended_dataset_batched(batch) {
                    push_model(out, w);
                }
            }
            other => {
                if let Some(rest) = other.strip_prefix("synthetic") {
                    let n = match rest.strip_prefix(':') {
                        None if rest.is_empty() => 60,
                        Some(v) => match v.parse() {
                            Ok(n) => n,
                            Err(_) => bail!("--workloads synthetic:<N>: bad count {v:?}"),
                        },
                        _ => bail!("--workloads: unknown workload {other:?}"),
                    };
                    let wname = if batch > 1 {
                        format!("Synthetic@b{batch}")
                    } else {
                        "Synthetic".to_string()
                    };
                    out.push((wname, synthetic::dataset_batched(seed, n, batch)));
                } else {
                    bail!(
                        "--workloads: unknown workload {other:?} (bert, gptj, resnet50, dlrm, \
                         vit, llama-decode, llama-prefill, real, all, synthetic[:N])"
                    );
                }
            }
        }
    }
    Ok(())
}

/// Parse the batch axis: a comma-separated list of positive integers
/// (`--batch 1,4,16,64`).
pub fn parse_batches(list: &str) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for tok in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match tok.parse::<u64>() {
            Ok(n) if n > 0 => out.push(n),
            _ => bail!("--batch: bad batch size {tok:?} (positive integers)"),
        }
    }
    if out.is_empty() {
        bail!("--batch: empty batch list");
    }
    Ok(out)
}

/// Resolve the system axis from a primitive list (`d1,d2,a1,a2`, `all`,
/// and/or `baseline`) crossed with an integration-level list (`rf`,
/// `smem-a`, `smem-b`, `all`). `baseline` contributes one tensor-core
/// system regardless of levels.
pub fn parse_systems(prims: &str, levels: &str) -> Result<Vec<SystemSpec>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Level {
        Rf,
        SmemA,
        SmemB,
    }
    let mut level_list: Vec<Level> = Vec::new();
    for l in levels.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match l.to_ascii_lowercase().as_str() {
            "rf" => level_list.push(Level::Rf),
            "smem-a" | "smema" | "smem_a" => level_list.push(Level::SmemA),
            "smem-b" | "smemb" | "smem_b" | "smem" => level_list.push(Level::SmemB),
            "all" => {
                level_list.extend([Level::Rf, Level::SmemA, Level::SmemB]);
            }
            other => bail!("--levels: unknown level {other:?} (rf, smem-a, smem-b, all)"),
        }
    }
    if level_list.is_empty() {
        bail!("--levels: empty level list");
    }

    let mut specs: Vec<SystemSpec> = Vec::new();
    let mut prim_list: Vec<CimPrimitive> = Vec::new();
    for p in prims.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match p.to_ascii_lowercase().as_str() {
            "baseline" | "tcore" => {
                if !specs.contains(&SystemSpec::Baseline) {
                    specs.push(SystemSpec::Baseline);
                }
            }
            "all" => prim_list.extend(CimPrimitive::all()),
            other => match CimPrimitive::parse(other) {
                Some(prim) => prim_list.push(prim),
                None => bail!("--prims: unknown primitive {other:?} (d1, d2, a1, a2, all, baseline)"),
            },
        }
    }
    for prim in prim_list {
        for level in &level_list {
            specs.push(match level {
                Level::Rf => SystemSpec::CimAtRf(prim.clone()),
                Level::SmemA => SystemSpec::CimAtSmem(prim.clone(), SmemConfig::ConfigA),
                Level::SmemB => SystemSpec::CimAtSmem(prim.clone(), SmemConfig::ConfigB),
            });
        }
    }
    if specs.is_empty() {
        bail!("--prims: empty system axis");
    }
    Ok(specs)
}

/// Parse the SM-count axis: a comma-separated list of positive integers.
pub fn parse_sm_counts(list: &str) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for tok in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        match tok.parse::<u64>() {
            Ok(n) if n > 0 => out.push(n),
            _ => bail!("--sms: bad SM count {tok:?} (positive integers)"),
        }
    }
    if out.is_empty() {
        bail!("--sms: empty SM-count list");
    }
    Ok(out)
}

/// Default CLI axis values — shared between [`default_grid`] (what the
/// ≥500-point acceptance tests pin) and `repro sweep`'s flag defaults,
/// so the two cannot drift apart.
pub const DEFAULT_WORKLOADS: &str = "all";
pub const DEFAULT_PRIMS: &str = "baseline,all";
pub const DEFAULT_LEVELS: &str = "rf,smem-a,smem-b";

/// The default `repro sweep` grid: the full model zoo across the
/// baseline and every (primitive × integration point) — ≥500 points.
pub fn default_grid(seed: u64) -> Result<SweepSpec> {
    Ok(SweepSpec::new("sweep")
        .workloads(parse_workloads(DEFAULT_WORKLOADS, seed)?)
        .systems(parse_systems(DEFAULT_PRIMS, DEFAULT_LEVELS)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_the_cartesian_product() {
        let spec = SweepSpec::new("t")
            .workload("a", vec![Gemm::new(16, 16, 16), Gemm::new(32, 32, 32)])
            .workload("b", vec![Gemm::new(64, 64, 64)])
            .systems(vec![
                SystemSpec::Baseline,
                SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            ])
            .sm_counts(vec![1, 4]);
        assert_eq!(spec.n_points(), 3 * 2 * 2);
        assert_eq!(spec.jobs().len(), spec.n_points());
        assert_eq!(spec.jobs_system_major().len(), spec.n_points());
    }

    #[test]
    fn gemm_major_vs_system_major_ordering() {
        let spec = SweepSpec::new("t")
            .workload("a", vec![Gemm::new(16, 16, 16), Gemm::new(32, 32, 32)])
            .systems(vec![
                SystemSpec::Baseline,
                SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            ]);
        let gm = spec.jobs();
        assert_eq!(gm[0].gemm, gm[1].gemm, "gemm-major keeps the gemm fixed first");
        let sm = spec.jobs_system_major();
        assert_eq!(sm[0].spec, sm[1].spec, "system-major keeps the system fixed first");
    }

    #[test]
    fn mapper_fingerprints_distinct() {
        let fps = [
            MapperChoice::Priority.fingerprint(),
            MapperChoice::duplication().fingerprint(),
            MapperChoice::PriorityDuplication { threshold: 8 }.fingerprint(),
            MapperChoice::PriorityThreshold { threshold: 8 }.fingerprint(),
            MapperChoice::PriorityFixedOrder {
                order: [Dim::M, Dim::K, Dim::N],
            }
            .fingerprint(),
            MapperChoice::PriorityFixedOrder {
                order: [Dim::N, Dim::K, Dim::M],
            }
            .fingerprint(),
            MapperChoice::Heuristic { budget: 60, seed: 7 }.fingerprint(),
            MapperChoice::Heuristic { budget: 500, seed: 7 }.fingerprint(),
            MapperChoice::Exhaustive {
                objective: Objective::Energy,
            }
            .fingerprint(),
            MapperChoice::Exhaustive {
                objective: Objective::Edp,
            }
            .fingerprint(),
        ];
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j]);
            }
        }
    }

    #[test]
    fn mapper_parse() {
        assert_eq!(MapperChoice::parse("priority", 1).unwrap(), MapperChoice::Priority);
        assert_eq!(
            MapperChoice::parse("dup", 1).unwrap(),
            MapperChoice::duplication()
        );
        assert_eq!(
            MapperChoice::parse("dup:t9", 1).unwrap(),
            MapperChoice::PriorityDuplication { threshold: 9 }
        );
        assert_eq!(
            MapperChoice::parse("priority:t8", 1).unwrap(),
            MapperChoice::PriorityThreshold { threshold: 8 }
        );
        assert_eq!(
            MapperChoice::parse("priority:order-kmn", 1).unwrap(),
            MapperChoice::PriorityFixedOrder {
                order: [Dim::K, Dim::M, Dim::N]
            }
        );
        assert_eq!(
            MapperChoice::parse("heuristic:60", 9).unwrap(),
            MapperChoice::Heuristic { budget: 60, seed: 9 }
        );
        assert_eq!(
            MapperChoice::parse("exhaustive", 1).unwrap(),
            MapperChoice::Exhaustive {
                objective: Objective::Energy
            }
        );
        assert_eq!(
            MapperChoice::parse("exhaustive:edp", 1).unwrap(),
            MapperChoice::Exhaustive {
                objective: Objective::Edp
            }
        );
        assert!(MapperChoice::parse("magic", 1).is_err());
        assert!(MapperChoice::parse("priority:t0", 1).is_err());
        assert!(MapperChoice::parse("dup:t0", 1).is_err());
        assert!(MapperChoice::parse("exhaustive:speed", 1).is_err());
        // Malformed permutations: wrong length, repeats, foreign dims.
        for bad in ["priority:order-", "priority:order-mn", "priority:order-mmk",
                    "priority:order-mnkx", "priority:order-mnq"] {
            assert!(MapperChoice::parse(bad, 1).is_err(), "{bad:?} must not parse");
        }
    }

    /// Satellite bugfix property (ISSUE 4): every variant — including
    /// the previously CLI-unreachable duplication-threshold and
    /// fixed-order axes — round-trips `cli_spec → parse` exactly, and
    /// the parsed mapper fingerprints identically to the original (so
    /// a scenario's serialized mapper axis can never alias a different
    /// cache point than the in-memory mapper it came from).
    #[test]
    fn cli_spec_parse_fingerprint_round_trip() {
        let seed = 41;
        let mut choices = vec![
            MapperChoice::Priority,
            MapperChoice::duplication(),
            MapperChoice::Exhaustive { objective: Objective::Energy },
            MapperChoice::Exhaustive { objective: Objective::Delay },
            MapperChoice::Exhaustive { objective: Objective::Edp },
        ];
        for threshold in [1, 2, 4, 7, 64, 1000] {
            choices.push(MapperChoice::PriorityThreshold { threshold });
            choices.push(MapperChoice::PriorityDuplication { threshold });
        }
        for budget in [1, 60, 500, 10_000] {
            choices.push(MapperChoice::Heuristic { budget, seed });
        }
        for a in Dim::all() {
            for b in Dim::all() {
                for c in Dim::all() {
                    if a != b && b != c && a != c {
                        choices.push(MapperChoice::PriorityFixedOrder { order: [a, b, c] });
                    }
                }
            }
        }
        for mc in &choices {
            let spelled = mc.cli_spec();
            let parsed = MapperChoice::parse(&spelled, seed)
                .unwrap_or_else(|e| panic!("{spelled:?} must parse: {e:#}"));
            assert_eq!(parsed, *mc, "{spelled:?} must round-trip");
            assert_eq!(
                parsed.fingerprint(),
                mc.fingerprint(),
                "{spelled:?}: parse must land on the same cache point"
            );
        }
        // ...and distinct choices never collide through the round trip.
        for i in 0..choices.len() {
            for j in (i + 1)..choices.len() {
                assert_ne!(choices[i].fingerprint(), choices[j].fingerprint());
            }
        }
    }

    #[test]
    fn mapper_variants_produce_their_documented_mappings() {
        use crate::arch::{Architecture, CimSystem, MemLevel};
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        let g = Gemm::new(256, 512, 512);
        assert_eq!(
            MapperChoice::PriorityThreshold { threshold: 4 }.map(&sys, &g),
            MapperChoice::Priority.map(&sys, &g),
            "the default threshold is 4"
        );
        let order = [Dim::K, Dim::N, Dim::M];
        assert_eq!(
            MapperChoice::PriorityFixedOrder { order }.map(&sys, &g),
            PriorityMapper::new(&sys).map(&g).with_dram_order(order)
        );
        let exact = MapperChoice::Exhaustive {
            objective: Objective::Energy,
        }
        .map(&sys, &g);
        assert!(exact.nest.validate().is_ok());
    }

    #[test]
    fn workload_parsing() {
        let real = parse_workloads("real", 7).unwrap();
        assert_eq!(real.len(), 4);
        let one = parse_workloads("bert", 7).unwrap();
        assert_eq!(one[0].0, "BERT-Large");
        assert_eq!(one[0].1.len(), 5);
        let synth = parse_workloads("synthetic:25", 7).unwrap();
        assert_eq!(synth[0].1.len(), 25);
        assert!(parse_workloads("quantum", 7).is_err());
        assert!(parse_workloads("", 7).is_err());
    }

    #[test]
    fn system_parsing() {
        let specs = parse_systems("baseline,all", "rf,smem-b").unwrap();
        // 1 baseline + 4 prims x 2 levels
        assert_eq!(specs.len(), 9);
        assert_eq!(specs[0], SystemSpec::Baseline);
        let one = parse_systems("d1", "rf").unwrap();
        assert_eq!(one, vec![SystemSpec::CimAtRf(CimPrimitive::digital_6t())]);
        assert!(parse_systems("d1", "l5").is_err());
        assert!(parse_systems("d9", "rf").is_err());
    }

    #[test]
    fn sm_count_parsing() {
        assert_eq!(parse_sm_counts("1,2,4").unwrap(), vec![1, 2, 4]);
        assert!(parse_sm_counts("0").is_err());
        assert!(parse_sm_counts("x").is_err());
    }

    #[test]
    fn batch_parsing() {
        assert_eq!(parse_batches("1,4,16,64").unwrap(), vec![1, 4, 16, 64]);
        assert_eq!(parse_batches(" 8 ").unwrap(), vec![8]);
        assert!(parse_batches("0").is_err());
        assert!(parse_batches("x").is_err());
        assert!(parse_batches("").is_err());
    }

    #[test]
    fn batch_one_workload_parse_is_the_identity() {
        // The --batch 1 no-op guarantee at the parser level: same
        // names, same shapes, same order as the unbatched parse.
        for list in ["all", "real", "gptj,bert", "synthetic:12"] {
            let plain = parse_workloads(list, 7).unwrap();
            let batched = parse_workloads_batched(list, 7, &[1]).unwrap();
            assert_eq!(plain, batched, "{list:?}");
        }
    }

    #[test]
    fn batched_workload_parse_expands_batch_major() {
        let got = parse_workloads_batched("gptj,dlrm", 7, &[1, 16]).unwrap();
        let names: Vec<&str> = got.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["GPT-J", "DLRM", "GPT-J@b16", "DLRM@b16"]);
        // Batch-16 GPT-J carries the folded projection GEMM...
        assert!(got[2].1.contains(&Gemm::new(16, 4096, 4096)));
        // ...and the per-sequence attention GEMVs, deduplicated.
        assert!(got[2].1.contains(&Gemm::new(1, 2048, 4096)));
        assert_eq!(got[2].1.len(), got[0].1.len());
        assert!(parse_workloads_batched("gptj", 7, &[]).is_err());
        assert!(parse_workloads_batched("gptj", 7, &[0]).is_err());
    }

    #[test]
    fn default_grid_is_at_least_500_points() {
        let spec = default_grid(7).unwrap();
        assert!(
            spec.n_points() >= 500,
            "default grid has only {} points",
            spec.n_points()
        );
    }
}
