//! The parallel, memoized sweep executor.
//!
//! [`SweepEngine`] turns a job list (from [`super::spec::SweepSpec`] or
//! hand-built) into results by fanning evaluations over the in-tree
//! worker pool ([`crate::util::pool`]) with every point memoized in a
//! shared [`EvalCache`]. Evaluation of a point is a pure function of
//! (system fingerprint, SM count, mapper, GEMM), so results are
//! bit-identical across thread counts and across warm/cold caches —
//! properties the test suite asserts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::{Architecture, MultiSm};
use crate::coordinator::jobs::SystemSpec;
use crate::cost::{BaselineModel, CostModel};
use crate::util::pool;

use super::cache::{self, CacheEntry, EvalCache};
use super::spec::{MapperChoice, SweepJob, SweepResult, SweepSpec};

/// Parallel grid evaluator with a shared memoization cache.
#[derive(Debug, Clone)]
pub struct SweepEngine {
    arch: Architecture,
    /// Precomputed [`cache::arch_fingerprint`] — prefixes every key so
    /// engines over different architectures can share one cache.
    arch_fp: String,
    threads: usize,
    cache: Arc<EvalCache>,
}

impl SweepEngine {
    /// Engine with a fresh cache and the default thread count.
    pub fn new(arch: Architecture) -> Self {
        Self::with_cache(arch, Arc::new(EvalCache::new()))
    }

    /// Engine sharing an existing cache (e.g. across experiments of one
    /// `repro experiment all` run).
    pub fn with_cache(arch: Architecture, cache: Arc<EvalCache>) -> Self {
        let arch_fp = cache::arch_fingerprint(&arch);
        SweepEngine {
            arch,
            arch_fp,
            threads: pool::default_threads(),
            cache,
        }
    }

    /// Set the worker-thread count (builder style).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn thread_count(&self) -> usize {
        self.threads
    }

    pub fn arch(&self) -> &Architecture {
        &self.arch
    }

    pub fn cache(&self) -> &EvalCache {
        &self.cache
    }

    pub fn shared_cache(&self) -> Arc<EvalCache> {
        Arc::clone(&self.cache)
    }

    /// Precomputed per-(system spec, mapper) evaluation context: the
    /// full cache key and the human-readable label. Building these is
    /// pure string formatting, so a sweep computes them once per
    /// distinct spec instead of once per job — on a warm cache the
    /// per-job work drops to one borrowed-key map probe.
    fn point_meta(&self, spec: &SystemSpec, mapper: MapperChoice) -> PointMeta {
        let system_fp = cache::spec_fingerprint(spec);
        // The mapper cannot influence the baseline, so baseline points
        // share one cache entry across mapper choices.
        let mapper_fp = if matches!(spec, SystemSpec::Baseline) {
            cache::BASELINE_MAPPER_FP.to_string()
        } else {
            mapper.fingerprint()
        };
        PointMeta {
            key: cache::point_key(&self.arch_fp, &system_fp, &mapper_fp),
            label: cache::spec_label(spec, &self.arch),
        }
    }

    /// Evaluate one job, memoized. The cache holds the single-SM
    /// metrics; multi-SM points are a pure post-transform
    /// ([`MultiSm::scale`]) applied on read, so every value of an
    /// SM-count axis shares one evaluation.
    pub fn evaluate(&self, job: &SweepJob) -> SweepResult {
        let meta = self.point_meta(&job.spec, job.mapper);
        self.evaluate_with_meta(job, &meta)
    }

    fn evaluate_with_meta(&self, job: &SweepJob, meta: &PointMeta) -> SweepResult {
        let entry = self
            .cache
            .get_or_compute(&meta.key, job.gemm, || self.evaluate_uncached(job));
        let metrics = if job.sms <= 1 {
            entry.metrics
        } else {
            MultiSm::new(job.sms).scale(&entry.metrics)
        };
        SweepResult {
            workload: job.workload.clone(),
            gemm: job.gemm,
            system: meta.label.clone(),
            sms: job.sms,
            metrics,
            mapping: entry.mapping,
        }
    }

    /// The raw (cache-miss) evaluation: instantiate the system, map the
    /// GEMM, run the cost model (single-SM). The mapping rides into the
    /// cache next to the metrics; every mapper invocation is counted on
    /// the shared cache so warm runs can prove they never re-map.
    fn evaluate_uncached(&self, job: &SweepJob) -> CacheEntry {
        match job.spec.system(&self.arch) {
            None => CacheEntry::metrics_only(BaselineModel::new(&self.arch).evaluate(&job.gemm)),
            Some(sys) => {
                self.cache.note_mapper_call();
                let mapping = job.mapper.map(&sys, &job.gemm);
                let metrics = CostModel::new(&sys).evaluate(&job.gemm, &mapping);
                CacheEntry {
                    mapping: Some(Arc::new(mapping)),
                    metrics,
                }
            }
        }
    }

    /// Evaluate a batch in parallel, preserving job order. The (cache
    /// key, label) pair is computed once per distinct (spec, mapper) in
    /// the batch and shared across its jobs (grids repeat each system
    /// for every GEMM × SM count).
    pub fn run(&self, jobs: &[SweepJob]) -> Vec<SweepResult> {
        let mut distinct: Vec<(&SystemSpec, MapperChoice, Arc<PointMeta>)> = Vec::new();
        let mut pairs: Vec<(&SweepJob, Arc<PointMeta>)> = Vec::with_capacity(jobs.len());
        for job in jobs {
            let meta = match distinct
                .iter()
                .find(|(s, m, _)| **s == job.spec && *m == job.mapper)
            {
                Some((_, _, meta)) => Arc::clone(meta),
                None => {
                    let meta = Arc::new(self.point_meta(&job.spec, job.mapper));
                    distinct.push((&job.spec, job.mapper, Arc::clone(&meta)));
                    meta
                }
            };
            pairs.push((job, meta));
        }
        pool::map_parallel(&pairs, self.threads, |(job, meta)| {
            self.evaluate_with_meta(job, meta)
        })
    }

    /// Run an explicit job list with timing and cache accounting —
    /// the engine behind [`Self::run_spec`] and the `--shard` slices.
    pub fn run_jobs_named(&self, name: &str, jobs: &[SweepJob]) -> SweepRun {
        let (h0, m0) = (self.cache.hits(), self.cache.misses());
        let t0 = Instant::now();
        let results = self.run(jobs);
        SweepRun {
            spec_name: name.to_string(),
            results,
            threads: self.threads,
            cache_hits: self.cache.hits() - h0,
            cache_misses: self.cache.misses() - m0,
            elapsed: t0.elapsed(),
        }
    }

    /// Expand and run a full [`SweepSpec`], with timing and cache
    /// accounting for the run.
    pub fn run_spec(&self, spec: &SweepSpec) -> SweepRun {
        self.run_jobs_named(&spec.name, &spec.jobs())
    }
}

/// Precomputed (cache key, display label) for one (spec, mapper) pair.
#[derive(Debug)]
struct PointMeta {
    key: String,
    label: String,
}

/// One executed sweep: ordered results plus run-level accounting.
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub spec_name: String,
    pub results: Vec<SweepResult>,
    pub threads: usize,
    /// Cache hits during this run (duplicates within the grid plus
    /// overlap with previously-run sweeps sharing the cache).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub elapsed: Duration,
}

impl SweepRun {
    pub fn n_points(&self) -> usize {
        self.results.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimPrimitive;
    use crate::sweep::spec::MapperChoice;
    use crate::workload::Gemm;

    fn small_spec() -> SweepSpec {
        SweepSpec::new("unit")
            .workload(
                "w",
                vec![Gemm::new(64, 64, 64), Gemm::new(512, 1024, 1024)],
            )
            .systems(vec![
                SystemSpec::Baseline,
                SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            ])
    }

    #[test]
    fn run_preserves_order_and_counts() {
        let engine = SweepEngine::new(Architecture::default_sm());
        let spec = small_spec();
        let run = engine.run_spec(&spec);
        assert_eq!(run.n_points(), spec.n_points());
        assert_eq!(run.results[0].system, "Tensor-core");
        assert!(run.results[1].system.contains("Digital-6T@RF"));
        assert_eq!(run.cache_misses, 4);
        assert_eq!(run.cache_hits, 0);
    }

    #[test]
    fn rerun_is_fully_cached_and_identical() {
        let engine = SweepEngine::new(Architecture::default_sm()).threads(1);
        let spec = small_spec();
        let cold = engine.run_spec(&spec);
        let warm = engine.run_spec(&spec);
        assert_eq!(warm.cache_misses, 0);
        assert_eq!(warm.cache_hits, cold.cache_misses);
        for (a, b) in cold.results.iter().zip(&warm.results) {
            assert_eq!(a.metrics, b.metrics);
            assert_eq!(a.system, b.system);
        }
    }

    #[test]
    fn engine_matches_direct_evaluation() {
        use crate::arch::{CimSystem, MemLevel};
        use crate::mapping::PriorityMapper;
        let arch = Architecture::default_sm();
        let engine = SweepEngine::new(arch.clone());
        let g = Gemm::new(512, 1024, 1024);
        let job = SweepJob {
            workload: "w".into(),
            gemm: g,
            spec: SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            sms: 1,
            mapper: MapperChoice::Priority,
        };
        let via_engine = engine.evaluate(&job).metrics;
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        let direct = CostModel::new(&sys).evaluate(&g, &PriorityMapper::new(&sys).map(&g));
        assert_eq!(via_engine, direct);
    }

    #[test]
    fn sms_axis_applies_multi_sm_scaling() {
        let arch = Architecture::default_sm();
        let engine = SweepEngine::new(arch);
        let mk = |sms| SweepJob {
            workload: "w".into(),
            gemm: Gemm::new(2048, 4096, 4096),
            spec: SystemSpec::CimAtRf(CimPrimitive::digital_6t()),
            sms,
            mapper: MapperChoice::Priority,
        };
        let one = engine.evaluate(&mk(1)).metrics;
        let four = engine.evaluate(&mk(4)).metrics;
        assert_eq!(MultiSm::new(4).scale(&one), four);
        assert!(four.gflops > one.gflops);
        // Every SM-count axis value shares the single-SM cache entry.
        assert_eq!(engine.cache().misses(), 1);
        assert_eq!(engine.cache().hits(), 1);
    }

    #[test]
    fn results_carry_mappings_and_mapper_calls_are_counted() {
        use crate::mapping::PriorityMapper;
        let arch = Architecture::default_sm();
        let engine = SweepEngine::new(arch.clone()).threads(1);
        let g = Gemm::new(512, 1024, 1024);
        let mk = |spec| SweepJob {
            workload: "w".into(),
            gemm: g,
            spec,
            sms: 1,
            mapper: MapperChoice::Priority,
        };
        let jobs = [
            mk(SystemSpec::CimAtRf(CimPrimitive::digital_6t())),
            mk(SystemSpec::Baseline),
        ];
        let results = engine.run(&jobs);
        // CiM results carry the exact mapping the mapper produced;
        // baseline results carry none.
        let sys = crate::arch::CimSystem::at_level(
            &arch,
            CimPrimitive::digital_6t(),
            crate::arch::MemLevel::RegisterFile,
        );
        assert_eq!(
            results[0].mapping.as_deref(),
            Some(&PriorityMapper::new(&sys).map(&g))
        );
        assert!(results[1].mapping.is_none());
        assert_eq!(engine.cache().mapper_calls(), 1, "one CiM miss = one map");
        // A warm rerun serves the mapping from the cache: no re-mapping.
        let warm = engine.run(&jobs);
        assert_eq!(engine.cache().mapper_calls(), 1);
        assert_eq!(warm[0].mapping, results[0].mapping);
    }

    #[test]
    fn baseline_cache_entry_shared_across_mappers() {
        let engine = SweepEngine::new(Architecture::default_sm()).threads(1);
        let mk = |mapper| SweepJob {
            workload: "w".into(),
            gemm: Gemm::new(64, 64, 64),
            spec: SystemSpec::Baseline,
            sms: 1,
            mapper,
        };
        engine.evaluate(&mk(MapperChoice::Priority));
        engine.evaluate(&mk(MapperChoice::duplication()));
        assert_eq!(engine.cache().misses(), 1);
        assert_eq!(engine.cache().hits(), 1);
    }
}
