//! Convolution-to-GEMM extraction via im2col (paper §III-A) and the
//! ResNet-50 layer generator used to derive the Table VI dataset.
//!
//! im2col maps Conv2D(Ci→Co, Kh×Kw, stride s, pad p) on an `Hi×Wi`
//! input to GEMM(M, N, K) with `M = Ho·Wo`, `N = Co`, `K = Kh·Kw·Ci`
//! (Table I row 1).

use super::gemm::Gemm;

/// A 2-D convolution layer (square kernels/strides as in ResNet).
#[derive(Debug, Clone, Copy)]
pub struct Conv2d {
    pub h_in: u64,
    pub w_in: u64,
    pub c_in: u64,
    pub c_out: u64,
    pub kernel: u64,
    pub stride: u64,
    pub pad: u64,
}

impl Conv2d {
    pub fn output_hw(&self) -> (u64, u64) {
        let ho = (self.h_in + 2 * self.pad - self.kernel) / self.stride + 1;
        let wo = (self.w_in + 2 * self.pad - self.kernel) / self.stride + 1;
        (ho, wo)
    }

    /// im2col transformation (Table I).
    pub fn to_gemm(&self) -> Gemm {
        let (ho, wo) = self.output_hw();
        Gemm::new(ho * wo, self.c_out, self.kernel * self.kernel * self.c_in)
    }
}

/// ResNet-50 for 224×224 ImageNet inference at batch 1: the stem conv,
/// 16 bottleneck blocks in stages of [3, 4, 6, 3], and the classifier.
///
/// Matches the paper's Appendix B listing, which excludes the
/// stride-matching *downsample* (projection shortcut) convolutions;
/// pass `include_downsample` to also generate those.
pub fn resnet50_gemms(include_downsample: bool) -> Vec<Gemm> {
    let mut out = Vec::new();

    // Stem: 7x7/2, 3->64, 224x224 -> 112x112.
    let stem = Conv2d {
        h_in: 224,
        w_in: 224,
        c_in: 3,
        c_out: 64,
        kernel: 7,
        stride: 2,
        pad: 3,
    };
    out.push(stem.to_gemm());
    // 3x3/2 max-pool: 112x112 -> 56x56 (no GEMM).

    // (input hw, mid channels, out channels, blocks, first-block stride)
    let stages: [(u64, u64, u64, u64, u64); 4] = [
        (56, 64, 256, 3, 1),
        (56, 128, 512, 4, 2),
        (28, 256, 1024, 6, 2),
        (14, 512, 2048, 3, 2),
    ];

    let mut c_in = 64u64;
    for (hw_in, mid, c_out, blocks, first_stride) in stages {
        let mut hw = hw_in;
        for b in 0..blocks {
            let stride = if b == 0 { first_stride } else { 1 };
            // 1x1 reduce (operates at the incoming resolution).
            out.push(
                Conv2d {
                    h_in: hw,
                    w_in: hw,
                    c_in,
                    c_out: mid,
                    kernel: 1,
                    stride: 1,
                    pad: 0,
                }
                .to_gemm(),
            );
            // 3x3 (carries the stride).
            let hw_out = hw / stride;
            out.push(
                Conv2d {
                    h_in: hw,
                    w_in: hw,
                    c_in: mid,
                    c_out: mid,
                    kernel: 3,
                    stride,
                    pad: 1,
                }
                .to_gemm(),
            );
            // 1x1 expand.
            out.push(
                Conv2d {
                    h_in: hw_out,
                    w_in: hw_out,
                    c_in: mid,
                    c_out,
                    kernel: 1,
                    stride: 1,
                    pad: 0,
                }
                .to_gemm(),
            );
            if b == 0 && include_downsample {
                out.push(
                    Conv2d {
                        h_in: hw,
                        w_in: hw,
                        c_in,
                        c_out,
                        kernel: 1,
                        stride,
                        pad: 0,
                    }
                    .to_gemm(),
                );
            }
            hw = hw_out;
            c_in = c_out;
        }
    }

    // Global average pool -> FC 2048 -> 1000 (a GEMV at batch 1).
    out.push(Gemm::new(1, 1000, 2048));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn im2col_first_layer() {
        // Table VI row: ResNet50 (12544, 64, 147).
        let stem = Conv2d {
            h_in: 224,
            w_in: 224,
            c_in: 3,
            c_out: 64,
            kernel: 7,
            stride: 2,
            pad: 3,
        };
        assert_eq!(stem.output_hw(), (112, 112));
        assert_eq!(stem.to_gemm(), Gemm::new(12544, 64, 147));
    }

    #[test]
    fn im2col_3x3_same_padding() {
        let c = Conv2d {
            h_in: 56,
            w_in: 56,
            c_in: 64,
            c_out: 64,
            kernel: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(c.to_gemm(), Gemm::new(3136, 64, 576));
    }

    #[test]
    fn layer_count_without_downsample() {
        // stem + 16 blocks x 3 convs + fc = 50 GEMMs ("all the 50
        // layers of ResNet", Appendix B).
        assert_eq!(resnet50_gemms(false).len(), 50);
        // + 4 projection shortcuts
        assert_eq!(resnet50_gemms(true).len(), 54);
    }

    #[test]
    fn generated_unique_shapes_match_table_vi_unique_shapes() {
        let generated: BTreeSet<(u64, u64, u64)> = resnet50_gemms(false)
            .iter()
            .map(|g| (g.m, g.n, g.k))
            .collect();
        let table: BTreeSet<(u64, u64, u64)> = super::super::models::resnet50()
            .gemms()
            .iter()
            .map(|g| (g.m, g.n, g.k))
            .collect();
        for shape in &table {
            assert!(generated.contains(shape), "table shape {shape:?} not generated");
        }
        for shape in &generated {
            assert!(table.contains(shape), "generated {shape:?} missing from table");
        }
    }

    #[test]
    fn resolutions_shrink_monotonically() {
        let gemms = resnet50_gemms(false);
        // M (= Ho*Wo) never grows as we go deeper, until the FC layer.
        let ms: Vec<u64> = gemms.iter().map(|g| g.m).collect();
        for w in ms.windows(2).take(ms.len() - 2) {
            assert!(w[1] <= w[0], "M grew mid-network: {w:?}");
        }
    }
}
