//! Transformer attention/FC GEMM shape derivations (paper Table I).
//!
//! Assuming single batch and fused attention-score computation:
//!
//! | layer            | M        | N       | K        |
//! |------------------|----------|---------|----------|
//! | Q/K/V projection | embed    | seq     | embed    |
//! | logits (QKᵀ)     | seq      | seq     | embed    |
//! | attention (QKᵀV) | embed    | seq     | seq      |
//! | FC layer         | out-dim  | batch   | in-dim   |
//!
//! The table's (M, N) convention for projections is output-row = embed;
//! reported model datasets (Table VI) list the equivalent transposed
//! form with M = seq — both describe the same multiplication, and
//! [`TransformerConfig::encoder_gemms`] emits the Table VI orientation
//! so the derivations cross-check against the hardcoded dataset.

use super::gemm::Gemm;

/// Transformer encoder/decoder layer dimensions.
#[derive(Debug, Clone, Copy)]
pub struct TransformerConfig {
    /// Sequence length processed per forward pass (1 in decode phase).
    pub seq: u64,
    /// Embedding (hidden) size.
    pub embed: u64,
    /// Feed-forward inner size (typically 4×embed).
    pub ff: u64,
}

impl TransformerConfig {
    /// BERT-Large: embed 1024, ff 4096, evaluated at seq = 512 (§V-C).
    pub fn bert_large(seq: u64) -> Self {
        TransformerConfig {
            seq,
            embed: 1024,
            ff: 4096,
        }
    }

    /// GPT-J 6B: embed 4096, ff 16384; decode phase processes 1 token.
    pub fn gpt_j_decode() -> Self {
        TransformerConfig {
            seq: 1,
            embed: 4096,
            ff: 16384,
        }
    }

    /// Q/K/V/output projection: activations `seq×embed` times weights
    /// `embed×embed`.
    pub fn projection(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.embed)
    }

    /// Attention logits QKᵀ: `seq×embed` times `embed×seq`.
    pub fn logits(&self) -> Gemm {
        Gemm::new(self.seq, self.seq, self.embed)
    }

    /// Attention output QKᵀV: `seq×seq` times `seq×embed`.
    pub fn attention_v(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.seq)
    }

    /// First FC of the MLP block: expand embed -> ff.
    pub fn ffn_expand(&self) -> Gemm {
        Gemm::new(self.seq, self.ff, self.embed)
    }

    /// Second FC of the MLP block: contract ff -> embed.
    pub fn ffn_contract(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.ff)
    }

    /// The unique GEMMs of one encoder layer, Table VI orientation.
    pub fn encoder_gemms(&self) -> Vec<Gemm> {
        vec![
            self.projection(),
            self.logits(),
            self.attention_v(),
            self.ffn_expand(),
            self.ffn_contract(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_large_matches_table_vi() {
        let cfg = TransformerConfig::bert_large(512);
        let shapes = cfg.encoder_gemms();
        let expect = [
            Gemm::new(512, 1024, 1024),
            Gemm::new(512, 512, 1024),
            Gemm::new(512, 1024, 512),
            Gemm::new(512, 4096, 1024),
            Gemm::new(512, 1024, 4096),
        ];
        assert_eq!(shapes, expect);
    }

    #[test]
    fn gpt_j_decode_is_gemv() {
        let cfg = TransformerConfig::gpt_j_decode();
        assert_eq!(cfg.projection(), Gemm::new(1, 4096, 4096));
        assert_eq!(cfg.ffn_expand(), Gemm::new(1, 16384, 4096));
        assert!(cfg.projection().is_gemv());
    }

    #[test]
    fn logits_reduce_over_embed() {
        let cfg = TransformerConfig::bert_large(128);
        assert_eq!(cfg.logits().k, cfg.embed);
        assert_eq!(cfg.attention_v().k, cfg.seq);
    }
}
