//! Transformer attention/FC GEMM shape derivations (paper Table I).
//!
//! Assuming single batch and fused attention-score computation:
//!
//! | layer            | M        | N       | K        |
//! |------------------|----------|---------|----------|
//! | Q/K/V projection | embed    | seq     | embed    |
//! | logits (QKᵀ)     | seq      | seq     | embed    |
//! | attention (QKᵀV) | embed    | seq     | seq      |
//! | FC layer         | out-dim  | batch   | in-dim   |
//!
//! The table's (M, N) convention for projections is output-row = embed;
//! reported model datasets (Table VI) list the equivalent transposed
//! form with M = seq — both describe the same multiplication, and
//! [`TransformerConfig::encoder_gemms`] emits the Table VI orientation
//! so the derivations cross-check against the hardcoded dataset.

use super::gemm::Gemm;

/// Transformer encoder/decoder layer dimensions.
#[derive(Debug, Clone, Copy)]
pub struct TransformerConfig {
    /// Sequence length processed per forward pass (1 in decode phase).
    pub seq: u64,
    /// Embedding (hidden) size.
    pub embed: u64,
    /// Feed-forward inner size (typically 4×embed).
    pub ff: u64,
}

impl TransformerConfig {
    /// BERT-Large: embed 1024, ff 4096, evaluated at seq = 512 (§V-C).
    pub fn bert_large(seq: u64) -> Self {
        TransformerConfig {
            seq,
            embed: 1024,
            ff: 4096,
        }
    }

    /// GPT-J 6B: embed 4096, ff 16384; decode phase processes 1 token.
    pub fn gpt_j_decode() -> Self {
        TransformerConfig {
            seq: 1,
            embed: 4096,
            ff: 16384,
        }
    }

    /// Q/K/V/output projection: activations `seq×embed` times weights
    /// `embed×embed`.
    pub fn projection(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.embed)
    }

    /// Attention logits QKᵀ: `seq×embed` times `embed×seq`.
    pub fn logits(&self) -> Gemm {
        Gemm::new(self.seq, self.seq, self.embed)
    }

    /// Attention output QKᵀV: `seq×seq` times `seq×embed`.
    pub fn attention_v(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.seq)
    }

    /// First FC of the MLP block: expand embed -> ff.
    pub fn ffn_expand(&self) -> Gemm {
        Gemm::new(self.seq, self.ff, self.embed)
    }

    /// Second FC of the MLP block: contract ff -> embed.
    pub fn ffn_contract(&self) -> Gemm {
        Gemm::new(self.seq, self.embed, self.ff)
    }

    /// The unique GEMMs of one encoder layer, Table VI orientation.
    pub fn encoder_gemms(&self) -> Vec<Gemm> {
        vec![
            self.projection(),
            self.logits(),
            self.attention_v(),
            self.ffn_expand(),
            self.ffn_contract(),
        ]
    }

    /// [`Self::encoder_gemms`] evaluated at batch `b`. Weight-bearing
    /// layers (projections, FFN) share their weights across the batch
    /// and fold it into M; the attention GEMMs (QKᵀ, QKᵀV) carry no
    /// weights and score each sequence against its own K/V, so they
    /// repeat per sequence with their shape unchanged. `b = 1` is the
    /// identity.
    pub fn encoder_gemms_batched(&self, b: u64) -> Vec<Gemm> {
        assert!(b > 0, "batch must be positive");
        let mut out = vec![self.projection().batched(b)];
        for _ in 0..b {
            out.push(self.logits());
        }
        for _ in 0..b {
            out.push(self.attention_v());
        }
        out.push(self.ffn_expand().batched(b));
        out.push(self.ffn_contract().batched(b));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_large_matches_table_vi() {
        let cfg = TransformerConfig::bert_large(512);
        let shapes = cfg.encoder_gemms();
        let expect = [
            Gemm::new(512, 1024, 1024),
            Gemm::new(512, 512, 1024),
            Gemm::new(512, 1024, 512),
            Gemm::new(512, 4096, 1024),
            Gemm::new(512, 1024, 4096),
        ];
        assert_eq!(shapes, expect);
    }

    #[test]
    fn gpt_j_decode_is_gemv() {
        let cfg = TransformerConfig::gpt_j_decode();
        assert_eq!(cfg.projection(), Gemm::new(1, 4096, 4096));
        assert_eq!(cfg.ffn_expand(), Gemm::new(1, 16384, 4096));
        assert!(cfg.projection().is_gemv());
    }

    #[test]
    fn logits_reduce_over_embed() {
        let cfg = TransformerConfig::bert_large(128);
        assert_eq!(cfg.logits().k, cfg.embed);
        assert_eq!(cfg.attention_v().k, cfg.seq);
    }

    #[test]
    fn batched_encoder_folds_weights_and_replicates_attention() {
        let cfg = TransformerConfig::bert_large(512);
        // batch 1 is exactly encoder_gemms().
        assert_eq!(cfg.encoder_gemms_batched(1), cfg.encoder_gemms());
        let b = 4;
        let gemms = cfg.encoder_gemms_batched(b);
        // 3 folded weight layers + 2·b replicated attention GEMMs.
        assert_eq!(gemms.len(), 3 + 2 * b as usize);
        assert_eq!(gemms[0], cfg.projection().batched(b));
        assert!(gemms[1..=b as usize].iter().all(|&g| g == cfg.logits()));
        // Total MACs scale exactly linearly with batch.
        let macs_1: u64 = cfg.encoder_gemms().iter().map(|g| g.macs()).sum();
        let macs_b: u64 = gemms.iter().map(|g| g.macs()).sum();
        assert_eq!(macs_b, b * macs_1);
    }
}
