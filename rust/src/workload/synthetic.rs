//! Synthetic GEMM dataset (paper §V-C): 1000 shapes with M, N, K
//! varying from 16 to 8192, used for the What-question sweeps (Fig 9).
//!
//! Dimensions are sampled as powers of two over [16, 8192] (log-uniform
//! over exponents 4..=13) so small and large shapes are equally
//! represented and the CiM capacity sweet spots (K = 256, N = 16·c, ...)
//! are exercised exactly, matching the paper's gridded scatter plots.

use super::gemm::Gemm;
use crate::util::rng::Rng;

/// Default dataset size (§V-C).
pub const DATASET_SIZE: usize = 1000;

/// Dimension bounds (§V-C).
pub const DIM_MIN: u64 = 16;
pub const DIM_MAX: u64 = 8192;

/// Sample one power-of-two dimension in [16, 8192].
fn sample_dim(rng: &mut Rng) -> u64 {
    1u64 << rng.gen_range(4, 14)
}

/// Generate the synthetic dataset. Deterministic for a given seed.
pub fn dataset(seed: u64, size: usize) -> Vec<Gemm> {
    let mut rng = Rng::new(seed);
    (0..size)
        .map(|_| Gemm::new(sample_dim(&mut rng), sample_dim(&mut rng), sample_dim(&mut rng)))
        .collect()
}

/// [`dataset`] evaluated at batch `b`: every shape stacks its batch
/// along M (shared weights), so `dataset_batched(s, n, 1)` is exactly
/// `dataset(s, n)` and total MACs scale linearly with `b`.
pub fn dataset_batched(seed: u64, size: usize, batch: u64) -> Vec<Gemm> {
    assert!(batch > 0, "batch must be positive");
    dataset(seed, size).iter().map(|g| g.batched(batch)).collect()
}

/// Default seed for the paper-configuration dataset.
pub const DEFAULT_SEED: u64 = 0x57_57_57; // "WWW"

/// The paper's configuration: 1000 points, default seed.
pub fn default_dataset() -> Vec<Gemm> {
    dataset(DEFAULT_SEED, DATASET_SIZE)
}

/// Square GEMM(X, X, X) series used by the appendix (Fig 13):
/// X ∈ {64, 128, ..., 8192}.
pub fn square_series() -> Vec<Gemm> {
    (6..=13).map(|e| 1u64 << e).map(|x| Gemm::new(x, x, x)).collect()
}

/// Sweep helper for Fig 10: vary one dimension over the power-of-two
/// grid while the others stay fixed.
pub fn sweep_dim<F: Fn(u64) -> Gemm>(make: F) -> Vec<Gemm> {
    (4..=13).map(|e| make(1u64 << e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_size_and_bounds() {
        let ds = default_dataset();
        assert_eq!(ds.len(), DATASET_SIZE);
        for g in &ds {
            for d in [g.m, g.n, g.k] {
                assert!((DIM_MIN..=DIM_MAX).contains(&d));
                assert!(d.is_power_of_two());
            }
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(dataset(7, 100), dataset(7, 100));
        assert_ne!(dataset(7, 100), dataset(8, 100));
    }

    #[test]
    fn batched_dataset_scales_m_only() {
        assert_eq!(dataset_batched(7, 100, 1), dataset(7, 100));
        let base = dataset(7, 100);
        let b4 = dataset_batched(7, 100, 4);
        assert_eq!(b4.len(), base.len());
        for (g1, g4) in base.iter().zip(&b4) {
            assert_eq!(g4.m, 4 * g1.m);
            assert_eq!((g4.n, g4.k), (g1.n, g1.k));
        }
    }

    #[test]
    fn covers_small_and_large() {
        let ds = default_dataset();
        assert!(ds.iter().any(|g| g.m == DIM_MIN || g.n == DIM_MIN || g.k == DIM_MIN));
        assert!(ds.iter().any(|g| g.m == DIM_MAX || g.n == DIM_MAX || g.k == DIM_MAX));
    }

    #[test]
    fn square_series_shape() {
        let s = square_series();
        assert_eq!(s.first().unwrap().m, 64);
        assert_eq!(s.last().unwrap().m, 8192);
        assert!(s.iter().all(|g| g.m == g.n && g.n == g.k));
    }

    #[test]
    fn sweep_grid() {
        let s = sweep_dim(|x| Gemm::new(x, 32, 32));
        assert_eq!(s.len(), 10);
        assert_eq!(s[0].m, 16);
        assert_eq!(s[9].m, 8192);
    }
}
