//! Workload substrate: the GEMM shapes of ML inference (paper §III-A,
//! Table I, Table VI) plus the synthetic sweep dataset (§V-C).

pub mod attention;
pub mod gemm;
pub mod models;
pub mod resnet;
pub mod synthetic;

/// Version of the workload substrate's GEMM shapes and constructors.
/// The batched constructors feed every sweep fingerprint and cache key
/// (workload name + `MxNxK` appear in both), so a semantic change here
/// silently invalidates persisted caches and golden CSVs — bump this
/// constant whenever shapes, names, or batching semantics change
/// (guarded by `repro lint` R3 via `lint/guards.toml`).
pub const WORKLOAD_VERSION: u32 = 1;

pub use gemm::Gemm;
pub use models::{Workload, WorkloadKind};
