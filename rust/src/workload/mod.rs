//! Workload substrate: the GEMM shapes of ML inference (paper §III-A,
//! Table I, Table VI) plus the synthetic sweep dataset (§V-C).

pub mod attention;
pub mod gemm;
pub mod models;
pub mod resnet;
pub mod synthetic;

pub use gemm::Gemm;
pub use models::{Workload, WorkloadKind};
