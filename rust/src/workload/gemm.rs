//! GEMM(M, N, K) — the unit of work throughout the paper.
//!
//! Input matrix `M×K` times weight matrix `K×N` gives output `M×N`
//! (§III-A legacy naming). All matrices are INT-8 (1 byte/element).

use crate::arch::BYTES_PER_ELEM;

/// A general matrix-matrix multiplication shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Gemm {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl Gemm {
    pub fn new(m: u64, n: u64, k: u64) -> Self {
        assert!(m > 0 && n > 0 && k > 0, "GEMM dims must be positive");
        Gemm { m, n, k }
    }

    /// Arithmetic operations: `2·M·N·K` (multiply + accumulate).
    pub fn ops(&self) -> u64 {
        2 * self.m * self.n * self.k
    }

    /// MAC operations: `M·N·K`.
    pub fn macs(&self) -> u64 {
        self.m * self.n * self.k
    }

    /// Input matrix (A, `M×K`) size in elements.
    pub fn input_elems(&self) -> u64 {
        self.m * self.k
    }

    /// Weight matrix (B, `K×N`) size in elements.
    pub fn weight_elems(&self) -> u64 {
        self.k * self.n
    }

    /// Output matrix (Z, `M×N`) size in elements.
    pub fn output_elems(&self) -> u64 {
        self.m * self.n
    }

    /// Total footprint of all three matrices in bytes.
    pub fn total_bytes(&self) -> u64 {
        (self.input_elems() + self.weight_elems() + self.output_elems()) * BYTES_PER_ELEM
    }

    /// Algorithmic reuse (arithmetic intensity), eq. 1:
    /// `2MNK / (BP·(MN + NK + MK))` — each matrix fetched exactly once.
    pub fn algorithmic_reuse(&self) -> f64 {
        self.ops() as f64 / self.total_bytes() as f64
    }

    /// Matrix-vector multiplication (`M = 1`): the degenerate case that
    /// defeats CiM weight reuse (§VI-C).
    pub fn is_gemv(&self) -> bool {
        self.m == 1
    }

    /// The same layer evaluated at batch `b`: the weight matrix is
    /// shared across the batch, so the `b` input vectors/matrices stack
    /// along M — a batch-`b` decode GEMV becomes an `M = b` GEMM.
    pub fn batched(&self, b: u64) -> Gemm {
        Gemm::new(self.m * b, self.n, self.k)
    }

    /// "Irregular" shape per §VI-B: one dimension much smaller than the
    /// others (ratio ≥ `threshold`).
    pub fn is_irregular(&self, threshold: f64) -> bool {
        let dims = [self.m as f64, self.n as f64, self.k as f64];
        let max = dims.iter().cloned().fold(f64::MIN, f64::max);
        let min = dims.iter().cloned().fold(f64::MAX, f64::min);
        max / min >= threshold
    }
}

impl std::fmt::Display for Gemm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GEMM({}, {}, {})", self.m, self.n, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_and_macs() {
        let g = Gemm::new(512, 1024, 1024);
        assert_eq!(g.macs(), 536_870_912); // Table VI row 1
        assert_eq!(g.ops(), 2 * 536_870_912);
    }

    #[test]
    fn algorithmic_reuse_matches_table_vi() {
        // Table VI: BERT-Large (512,1024,1024) -> reuse 512.
        let g = Gemm::new(512, 1024, 1024);
        assert!((g.algorithmic_reuse() - 512.0).abs() < 0.5);
        // (512,512,1024) -> 409.6
        let g = Gemm::new(512, 512, 1024);
        assert!((g.algorithmic_reuse() - 409.6).abs() < 0.1);
        // GPT-J decode GEMV (1,4096,4096) -> 1.999
        let g = Gemm::new(1, 4096, 4096);
        assert!((g.algorithmic_reuse() - 1.999).abs() < 0.01);
        // ResNet50 first layer (12544,64,147) -> 88.86
        let g = Gemm::new(12544, 64, 147);
        assert!((g.algorithmic_reuse() - 88.860).abs() < 0.01);
    }

    #[test]
    fn gemv_detection() {
        assert!(Gemm::new(1, 256, 512).is_gemv());
        assert!(!Gemm::new(2, 256, 512).is_gemv());
    }

    #[test]
    fn batched_stacks_along_m() {
        let g = Gemm::new(1, 4096, 4096);
        assert_eq!(g.batched(16), Gemm::new(16, 4096, 4096));
        assert!(!g.batched(2).is_gemv());
        // batch 1 is the identity.
        assert_eq!(g.batched(1), g);
        // MACs scale linearly with batch; the weight footprint does not.
        assert_eq!(g.batched(8).macs(), 8 * g.macs());
        assert_eq!(g.batched(8).weight_elems(), g.weight_elems());
    }

    #[test]
    fn irregularity() {
        assert!(Gemm::new(1, 4096, 4096).is_irregular(4.0));
        assert!(!Gemm::new(512, 1024, 1024).is_irregular(4.0));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_dim_rejected() {
        Gemm::new(0, 1, 1);
    }

    #[test]
    fn display() {
        assert_eq!(Gemm::new(1, 2, 3).to_string(), "GEMM(1, 2, 3)");
    }
}
