//! The real-model GEMM dataset (paper §V-C, Appendix B Table VI):
//! ResNet-50 (ImageNet), BERT-Large (seq 512), DLRM, and the GPT-J
//! decoding phase, all at batch 1.

use super::gemm::Gemm;

/// Workload family, used for grouping in the per-workload figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    Cnn,
    TransformerEncoder,
    TransformerDecoder,
    Recommendation,
    Synthetic,
}

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Cnn => "CNN",
            WorkloadKind::TransformerEncoder => "Transformer-Encoder",
            WorkloadKind::TransformerDecoder => "Transformer-Decoder",
            WorkloadKind::Recommendation => "Recommendation",
            WorkloadKind::Synthetic => "Synthetic",
        }
    }
}

/// A named ML workload: an ordered list of GEMM layers.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub kind: WorkloadKind,
    layers: Vec<Gemm>,
    /// Batch size the layer list was derived at. The layers already
    /// reflect it (weight-bearing GEMMs fold it into M, per-sequence
    /// attention GEMMs repeat), so this is bookkeeping for display and
    /// per-request normalization, not a multiplier to re-apply.
    batch: u64,
}

impl Workload {
    pub fn new(name: &str, kind: WorkloadKind, layers: Vec<Gemm>) -> Self {
        Workload::new_batched(name, kind, layers, 1)
    }

    pub fn new_batched(name: &str, kind: WorkloadKind, layers: Vec<Gemm>, batch: u64) -> Self {
        assert!(!layers.is_empty(), "workload needs at least one layer");
        assert!(batch > 0, "batch must be positive");
        Workload {
            name: name.to_string(),
            kind,
            layers,
            batch,
        }
    }

    /// Batch size this workload's layer list was derived at.
    pub fn batch(&self) -> u64 {
        self.batch
    }

    /// All layers in network order (duplicates kept — repeated blocks
    /// matter for whole-network totals and Fig 2's frequency shading).
    pub fn gemms(&self) -> &[Gemm] {
        &self.layers
    }

    /// Deduplicated shapes with occurrence counts (Fig 2 shading).
    pub fn unique_with_counts(&self) -> Vec<(Gemm, usize)> {
        let mut out: Vec<(Gemm, usize)> = Vec::new();
        for &g in &self.layers {
            match out.iter_mut().find(|(u, _)| *u == g) {
                Some((_, c)) => *c += 1,
                None => out.push((g, 1)),
            }
        }
        out
    }

    /// Total MACs of a full forward pass.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|g| g.macs()).sum()
    }
}

/// BERT-Large encoder layer at sequence length 512 (Table VI).
pub fn bert_large() -> Workload {
    Workload::new(
        "BERT-Large",
        WorkloadKind::TransformerEncoder,
        vec![
            Gemm::new(512, 1024, 1024),
            Gemm::new(512, 512, 1024),
            Gemm::new(512, 1024, 512),
            Gemm::new(512, 4096, 1024),
            Gemm::new(512, 1024, 4096),
        ],
    )
}

/// GPT-J 6B decoding phase (Table VI): token-at-a-time GEMVs plus the
/// large context feed-forward GEMM.
pub fn gpt_j() -> Workload {
    Workload::new(
        "GPT-J",
        WorkloadKind::TransformerDecoder,
        vec![
            Gemm::new(1, 4096, 4096),
            Gemm::new(2048, 4096, 4096),
            Gemm::new(1, 2048, 4096),
            Gemm::new(1, 4096, 2048),
            Gemm::new(1, 16384, 4096),
        ],
    )
}

/// DLRM MLP layers (Table VI).
pub fn dlrm() -> Workload {
    Workload::new(
        "DLRM",
        WorkloadKind::Recommendation,
        vec![Gemm::new(1, 256, 512), Gemm::new(1, 64, 256)],
    )
}

/// ResNet-50 with ImageNet at batch 1 — the Table VI listing verbatim
/// (duplicate rows are repeated blocks; the table's one "40" is the
/// obvious 49 typo). Cross-checked against the im2col generator in
/// [`super::resnet`].
pub fn resnet50() -> Workload {
    let rows: [(u64, u64, u64); 53] = [
        (12544, 64, 147),
        (3136, 64, 64),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 64, 256),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 64, 256),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 128, 256),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 256, 512),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 512, 1024),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (1, 1000, 2048),
    ];
    Workload::new(
        "ResNet50",
        WorkloadKind::Cnn,
        rows.iter().map(|&(m, n, k)| Gemm::new(m, n, k)).collect(),
    )
}

/// The full real dataset of §V-C, in the order the paper reports it.
pub fn real_dataset() -> Vec<Workload> {
    vec![bert_large(), gpt_j(), resnet50(), dlrm()]
}

// ---------------------------------------------------------------------
// Zoo extensions beyond the paper's four models (framework feature):
// derived with the same Table I rules, batch 1.
// ---------------------------------------------------------------------

/// ViT-Base/16 on 224×224: seq 197 (196 patches + CLS), embed 768,
/// ff 3072 — an encoder whose shapes sit between BERT and ResNet.
pub fn vit_base() -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq: 197,
        embed: 768,
        ff: 3072,
    };
    Workload::new("ViT-Base", WorkloadKind::TransformerEncoder, cfg.encoder_gemms())
}

/// Llama-2-7B decode phase (token-at-a-time): embed 4096, ff 11008
/// (gate/up/down projections) — GEMV-dominated like GPT-J decode.
pub fn llama2_7b_decode() -> Workload {
    Workload::new(
        "Llama2-7B-decode",
        WorkloadKind::TransformerDecoder,
        vec![
            Gemm::new(1, 4096, 4096),  // q/k/v/o projections
            Gemm::new(1, 11008, 4096), // gate + up
            Gemm::new(1, 4096, 11008), // down
        ],
    )
}

/// Llama-2-7B prefill at a given prompt length: the same layers with
/// M = seq — the regular-shape regime where CiM shines.
pub fn llama2_7b_prefill(seq: u64) -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq,
        embed: 4096,
        ff: 11008,
    };
    Workload::new(
        "Llama2-7B-prefill",
        WorkloadKind::TransformerDecoder,
        cfg.encoder_gemms(),
    )
}

/// Everything: the paper's dataset plus the zoo extensions.
pub fn extended_dataset() -> Vec<Workload> {
    let mut v = real_dataset();
    v.push(vit_base());
    v.push(llama2_7b_decode());
    v.push(llama2_7b_prefill(2048));
    v
}

// ---------------------------------------------------------------------
// Batched variants (serving regime). Weight-bearing layers share their
// weights across the batch and fold it into M — batch-`b` decode GEMVs
// become M = b GEMMs, the escape hatch from the §VI-C regime where CiM
// loses. Attention GEMMs carry no weights and score each sequence
// against its own K/V, so they repeat with their shape unchanged. Every
// `*_batched(1)` is layer-for-layer identical to its base constructor.
// ---------------------------------------------------------------------

/// [`bert_large`] at batch `b` (encoder layer, seq 512).
pub fn bert_large_batched(batch: u64) -> Workload {
    let cfg = super::attention::TransformerConfig::bert_large(512);
    Workload::new_batched(
        "BERT-Large",
        WorkloadKind::TransformerEncoder,
        cfg.encoder_gemms_batched(batch),
        batch,
    )
}

/// [`gpt_j`] decode at batch `b`: the token-at-a-time projection and
/// FFN GEMVs stack along M (shared weights); the two KV-cache attention
/// GEMMs repeat per sequence, each against its own 2048-token cache.
pub fn gpt_j_batched(batch: u64) -> Workload {
    assert!(batch > 0, "batch must be positive");
    let mut layers = vec![
        Gemm::new(1, 4096, 4096).batched(batch),
        Gemm::new(2048, 4096, 4096).batched(batch),
    ];
    for _ in 0..batch {
        layers.push(Gemm::new(1, 2048, 4096));
    }
    for _ in 0..batch {
        layers.push(Gemm::new(1, 4096, 2048));
    }
    layers.push(Gemm::new(1, 16384, 4096).batched(batch));
    Workload::new_batched("GPT-J", WorkloadKind::TransformerDecoder, layers, batch)
}

/// [`dlrm`] at batch `b`: MLP weights are shared, both GEMVs fold.
pub fn dlrm_batched(batch: u64) -> Workload {
    Workload::new_batched(
        "DLRM",
        WorkloadKind::Recommendation,
        dlrm().gemms().iter().map(|g| g.batched(batch)).collect(),
        batch,
    )
}

/// [`resnet50`] at batch `b`: every im2col GEMM stacks its per-image
/// output pixels along M (filters are the shared weights).
pub fn resnet50_batched(batch: u64) -> Workload {
    Workload::new_batched(
        "ResNet50",
        WorkloadKind::Cnn,
        resnet50().gemms().iter().map(|g| g.batched(batch)).collect(),
        batch,
    )
}

/// [`vit_base`] at batch `b`.
pub fn vit_base_batched(batch: u64) -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq: 197,
        embed: 768,
        ff: 3072,
    };
    Workload::new_batched(
        "ViT-Base",
        WorkloadKind::TransformerEncoder,
        cfg.encoder_gemms_batched(batch),
        batch,
    )
}

/// [`llama2_7b_decode`] at batch `b`: all three are weight projections,
/// all fold.
pub fn llama2_7b_decode_batched(batch: u64) -> Workload {
    Workload::new_batched(
        "Llama2-7B-decode",
        WorkloadKind::TransformerDecoder,
        llama2_7b_decode().gemms().iter().map(|g| g.batched(batch)).collect(),
        batch,
    )
}

/// [`real_dataset`] at batch `b`, same order.
pub fn real_dataset_batched(batch: u64) -> Vec<Workload> {
    vec![
        bert_large_batched(batch),
        gpt_j_batched(batch),
        resnet50_batched(batch),
        dlrm_batched(batch),
    ]
}

/// [`extended_dataset`] at batch `b`, same order.
pub fn extended_dataset_batched(batch: u64) -> Vec<Workload> {
    let mut v = real_dataset_batched(batch);
    v.push(vit_base_batched(batch));
    v.push(llama2_7b_decode_batched(batch));
    v.push(llama2_7b_prefill_batched(2048, batch));
    v
}

/// [`llama2_7b_prefill`] at batch `b`.
pub fn llama2_7b_prefill_batched(seq: u64, batch: u64) -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq,
        embed: 4096,
        ff: 11008,
    };
    Workload::new_batched(
        "Llama2-7B-prefill",
        WorkloadKind::TransformerDecoder,
        cfg.encoder_gemms_batched(batch),
        batch,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_vi_macs_spotchecks() {
        // #MACs column of Table VI.
        assert_eq!(Gemm::new(512, 1024, 1024).macs(), 536_870_912);
        assert_eq!(Gemm::new(2048, 4096, 4096).macs(), 34_359_738_368);
        assert_eq!(Gemm::new(1, 256, 512).macs(), 131_072);
        assert_eq!(Gemm::new(12544, 64, 147).macs(), 118_013_952);
        assert_eq!(Gemm::new(1, 1000, 2048).macs(), 2_048_000);
    }

    #[test]
    fn dataset_composition() {
        let ds = real_dataset();
        assert_eq!(ds.len(), 4);
        let names: Vec<&str> = ds.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names, vec!["BERT-Large", "GPT-J", "ResNet50", "DLRM"]);
    }

    #[test]
    fn gemv_layers_present_in_gptj_and_dlrm() {
        assert!(gpt_j().gemms().iter().filter(|g| g.is_gemv()).count() >= 4);
        assert!(dlrm().gemms().iter().all(|g| g.is_gemv()));
    }

    #[test]
    fn unique_with_counts_resnet() {
        let r = resnet50();
        let uniq = r.unique_with_counts();
        assert!(uniq.len() < r.gemms().len(), "resnet has repeated blocks");
        let total: usize = uniq.iter().map(|(_, c)| c).sum();
        assert_eq!(total, r.gemms().len());
        // (196,256,2304) occurs 6 times (one per stage-3 block).
        let (_, c) = uniq
            .iter()
            .find(|(g, _)| *g == Gemm::new(196, 256, 2304))
            .unwrap();
        assert_eq!(*c, 6);
    }

    #[test]
    fn bert_shapes_are_regular_resnet_tail_is_gemv() {
        assert!(bert_large().gemms().iter().all(|g| !g.is_gemv()));
        assert!(resnet50().gemms().last().unwrap().is_gemv());
    }

    #[test]
    fn total_macs_positive() {
        for w in real_dataset() {
            assert!(w.total_macs() > 0);
        }
    }

    #[test]
    fn batched_at_one_is_the_identity() {
        // Every batched constructor at b = 1 reproduces its base
        // constructor layer-for-layer (the --batch 1 no-op guarantee).
        let pairs: Vec<(Workload, Workload)> = vec![
            (bert_large(), bert_large_batched(1)),
            (gpt_j(), gpt_j_batched(1)),
            (dlrm(), dlrm_batched(1)),
            (resnet50(), resnet50_batched(1)),
            (vit_base(), vit_base_batched(1)),
            (llama2_7b_decode(), llama2_7b_decode_batched(1)),
            (llama2_7b_prefill(2048), llama2_7b_prefill_batched(2048, 1)),
        ];
        for (base, batched) in pairs {
            assert_eq!(base.gemms(), batched.gemms(), "{}", base.name);
            assert_eq!(base.name, batched.name);
            assert_eq!(base.kind, batched.kind);
            assert_eq!(batched.batch(), 1);
        }
    }

    #[test]
    fn batched_macs_scale_linearly() {
        // Batch b does b requests' worth of work — no more, no less.
        for b in [2u64, 8, 16] {
            assert_eq!(gpt_j_batched(b).total_macs(), b * gpt_j().total_macs());
            assert_eq!(bert_large_batched(b).total_macs(), b * bert_large().total_macs());
            assert_eq!(resnet50_batched(b).total_macs(), b * resnet50().total_macs());
            assert_eq!(dlrm_batched(b).total_macs(), b * dlrm().total_macs());
        }
    }

    #[test]
    fn batching_escapes_the_gemv_regime() {
        // GPT-J decode at batch 1 is GEMV-dominated; at batch 16 every
        // weight-bearing layer is a real GEMM (§VI-C escape). The
        // replicated per-sequence attention GEMMs stay GEMV but dedup
        // into two shapes with counts.
        assert!(gpt_j().gemms().iter().filter(|g| g.is_gemv()).count() >= 4);
        let b16 = gpt_j_batched(16);
        let uniq = b16.unique_with_counts();
        assert_eq!(uniq.len(), gpt_j().unique_with_counts().len());
        assert!(uniq.iter().filter(|(g, _)| g.is_gemv()).all(|&(_, c)| c == 16));
        assert!(b16.gemms().contains(&Gemm::new(16, 4096, 4096)));
        assert_eq!(b16.batch(), 16);
        // DLRM folds entirely: no GEMV left at batch > 1.
        assert!(dlrm_batched(4).gemms().iter().all(|g| !g.is_gemv()));
    }

    #[test]
    fn zoo_extensions_well_formed() {
        let ext = extended_dataset();
        assert_eq!(ext.len(), 7);
        // ViT-Base attention logits: (197, 197, 768).
        assert!(vit_base().gemms().contains(&Gemm::new(197, 197, 768)));
        // Llama decode is all GEMVs; prefill is all regular.
        assert!(llama2_7b_decode().gemms().iter().all(|g| g.is_gemv()));
        assert!(llama2_7b_prefill(2048).gemms().iter().all(|g| !g.is_gemv()));
    }
}
