//! The real-model GEMM dataset (paper §V-C, Appendix B Table VI):
//! ResNet-50 (ImageNet), BERT-Large (seq 512), DLRM, and the GPT-J
//! decoding phase, all at batch 1.

use super::gemm::Gemm;

/// Workload family, used for grouping in the per-workload figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    Cnn,
    TransformerEncoder,
    TransformerDecoder,
    Recommendation,
    Synthetic,
}

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Cnn => "CNN",
            WorkloadKind::TransformerEncoder => "Transformer-Encoder",
            WorkloadKind::TransformerDecoder => "Transformer-Decoder",
            WorkloadKind::Recommendation => "Recommendation",
            WorkloadKind::Synthetic => "Synthetic",
        }
    }
}

/// A named ML workload: an ordered list of GEMM layers.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub kind: WorkloadKind,
    layers: Vec<Gemm>,
}

impl Workload {
    pub fn new(name: &str, kind: WorkloadKind, layers: Vec<Gemm>) -> Self {
        assert!(!layers.is_empty(), "workload needs at least one layer");
        Workload {
            name: name.to_string(),
            kind,
            layers,
        }
    }

    /// All layers in network order (duplicates kept — repeated blocks
    /// matter for whole-network totals and Fig 2's frequency shading).
    pub fn gemms(&self) -> &[Gemm] {
        &self.layers
    }

    /// Deduplicated shapes with occurrence counts (Fig 2 shading).
    pub fn unique_with_counts(&self) -> Vec<(Gemm, usize)> {
        let mut out: Vec<(Gemm, usize)> = Vec::new();
        for &g in &self.layers {
            match out.iter_mut().find(|(u, _)| *u == g) {
                Some((_, c)) => *c += 1,
                None => out.push((g, 1)),
            }
        }
        out
    }

    /// Total MACs of a full forward pass.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|g| g.macs()).sum()
    }
}

/// BERT-Large encoder layer at sequence length 512 (Table VI).
pub fn bert_large() -> Workload {
    Workload::new(
        "BERT-Large",
        WorkloadKind::TransformerEncoder,
        vec![
            Gemm::new(512, 1024, 1024),
            Gemm::new(512, 512, 1024),
            Gemm::new(512, 1024, 512),
            Gemm::new(512, 4096, 1024),
            Gemm::new(512, 1024, 4096),
        ],
    )
}

/// GPT-J 6B decoding phase (Table VI): token-at-a-time GEMVs plus the
/// large context feed-forward GEMM.
pub fn gpt_j() -> Workload {
    Workload::new(
        "GPT-J",
        WorkloadKind::TransformerDecoder,
        vec![
            Gemm::new(1, 4096, 4096),
            Gemm::new(2048, 4096, 4096),
            Gemm::new(1, 2048, 4096),
            Gemm::new(1, 4096, 2048),
            Gemm::new(1, 16384, 4096),
        ],
    )
}

/// DLRM MLP layers (Table VI).
pub fn dlrm() -> Workload {
    Workload::new(
        "DLRM",
        WorkloadKind::Recommendation,
        vec![Gemm::new(1, 256, 512), Gemm::new(1, 64, 256)],
    )
}

/// ResNet-50 with ImageNet at batch 1 — the Table VI listing verbatim
/// (duplicate rows are repeated blocks; the table's one "40" is the
/// obvious 49 typo). Cross-checked against the im2col generator in
/// [`super::resnet`].
pub fn resnet50() -> Workload {
    let rows: [(u64, u64, u64); 53] = [
        (12544, 64, 147),
        (3136, 64, 64),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 64, 256),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 64, 256),
        (3136, 64, 576),
        (3136, 256, 64),
        (3136, 128, 256),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 128, 1152),
        (784, 512, 128),
        (784, 128, 512),
        (784, 256, 512),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 256, 2304),
        (196, 1024, 256),
        (196, 256, 1024),
        (196, 512, 1024),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (49, 512, 4608),
        (49, 2048, 512),
        (49, 512, 2048),
        (1, 1000, 2048),
    ];
    Workload::new(
        "ResNet50",
        WorkloadKind::Cnn,
        rows.iter().map(|&(m, n, k)| Gemm::new(m, n, k)).collect(),
    )
}

/// The full real dataset of §V-C, in the order the paper reports it.
pub fn real_dataset() -> Vec<Workload> {
    vec![bert_large(), gpt_j(), resnet50(), dlrm()]
}

// ---------------------------------------------------------------------
// Zoo extensions beyond the paper's four models (framework feature):
// derived with the same Table I rules, batch 1.
// ---------------------------------------------------------------------

/// ViT-Base/16 on 224×224: seq 197 (196 patches + CLS), embed 768,
/// ff 3072 — an encoder whose shapes sit between BERT and ResNet.
pub fn vit_base() -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq: 197,
        embed: 768,
        ff: 3072,
    };
    Workload::new("ViT-Base", WorkloadKind::TransformerEncoder, cfg.encoder_gemms())
}

/// Llama-2-7B decode phase (token-at-a-time): embed 4096, ff 11008
/// (gate/up/down projections) — GEMV-dominated like GPT-J decode.
pub fn llama2_7b_decode() -> Workload {
    Workload::new(
        "Llama2-7B-decode",
        WorkloadKind::TransformerDecoder,
        vec![
            Gemm::new(1, 4096, 4096),  // q/k/v/o projections
            Gemm::new(1, 11008, 4096), // gate + up
            Gemm::new(1, 4096, 11008), // down
        ],
    )
}

/// Llama-2-7B prefill at a given prompt length: the same layers with
/// M = seq — the regular-shape regime where CiM shines.
pub fn llama2_7b_prefill(seq: u64) -> Workload {
    let cfg = super::attention::TransformerConfig {
        seq,
        embed: 4096,
        ff: 11008,
    };
    Workload::new(
        "Llama2-7B-prefill",
        WorkloadKind::TransformerDecoder,
        cfg.encoder_gemms(),
    )
}

/// Everything: the paper's dataset plus the zoo extensions.
pub fn extended_dataset() -> Vec<Workload> {
    let mut v = real_dataset();
    v.push(vit_base());
    v.push(llama2_7b_decode());
    v.push(llama2_7b_prefill(2048));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_vi_macs_spotchecks() {
        // #MACs column of Table VI.
        assert_eq!(Gemm::new(512, 1024, 1024).macs(), 536_870_912);
        assert_eq!(Gemm::new(2048, 4096, 4096).macs(), 34_359_738_368);
        assert_eq!(Gemm::new(1, 256, 512).macs(), 131_072);
        assert_eq!(Gemm::new(12544, 64, 147).macs(), 118_013_952);
        assert_eq!(Gemm::new(1, 1000, 2048).macs(), 2_048_000);
    }

    #[test]
    fn dataset_composition() {
        let ds = real_dataset();
        assert_eq!(ds.len(), 4);
        let names: Vec<&str> = ds.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names, vec!["BERT-Large", "GPT-J", "ResNet50", "DLRM"]);
    }

    #[test]
    fn gemv_layers_present_in_gptj_and_dlrm() {
        assert!(gpt_j().gemms().iter().filter(|g| g.is_gemv()).count() >= 4);
        assert!(dlrm().gemms().iter().all(|g| g.is_gemv()));
    }

    #[test]
    fn unique_with_counts_resnet() {
        let r = resnet50();
        let uniq = r.unique_with_counts();
        assert!(uniq.len() < r.gemms().len(), "resnet has repeated blocks");
        let total: usize = uniq.iter().map(|(_, c)| c).sum();
        assert_eq!(total, r.gemms().len());
        // (196,256,2304) occurs 6 times (one per stage-3 block).
        let (_, c) = uniq
            .iter()
            .find(|(g, _)| *g == Gemm::new(196, 256, 2304))
            .unwrap();
        assert_eq!(*c, 6);
    }

    #[test]
    fn bert_shapes_are_regular_resnet_tail_is_gemv() {
        assert!(bert_large().gemms().iter().all(|g| !g.is_gemv()));
        assert!(resnet50().gemms().last().unwrap().is_gemv());
    }

    #[test]
    fn total_macs_positive() {
        for w in real_dataset() {
            assert!(w.total_macs() > 0);
        }
    }

    #[test]
    fn zoo_extensions_well_formed() {
        let ext = extended_dataset();
        assert_eq!(ext.len(), 7);
        // ViT-Base attention logits: (197, 197, 768).
        assert!(vit_base().gemms().contains(&Gemm::new(197, 197, 768)));
        // Llama decode is all GEMVs; prefill is all regular.
        assert!(llama2_7b_decode().gemms().iter().all(|g| g.is_gemv()));
        assert!(llama2_7b_prefill(2048).gemms().iter().all(|g| !g.is_gemv()));
    }
}
