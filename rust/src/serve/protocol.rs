//! The newline-delimited JSON wire protocol.
//!
//! One request per line, one *or more* response lines per request:
//!
//! * `{"op":"ping"}` → `{"ok":true,"op":"ping","protocol":2,"done":true}`
//! * `{"op":"eval","scenario":{...}}` → a header line
//!   (`{"ok":true,"op":"eval",...,"points":N}`), then one
//!   `{"row":"<csv line>"}` per CSV line (header row included), then a
//!   final `{"done":true,"ok":true,"stats":{...}}`. Joining the `row`
//!   strings with `\n` (plus a trailing `\n`) reproduces the `repro
//!   run` CSV byte-for-byte.
//! * `{"op":"stats"}` / `{"op":"flush"}` / `{"op":"shutdown"}` →
//!   a single line carrying `"done":true`. The `stats` line reports,
//!   besides uptime/cache/metrics, a `"salvage"` object (`kept` /
//!   `dropped` counts from the startup cache load) and a `"faults"`
//!   object (per-point hit/fire counters when `REPRO_FAULTS` is
//!   armed, `{}` otherwise) — protocol v2.
//!
//! Every response line carries `"ok"`; the last line of a response
//! carries `"done":true`. Errors are a single
//! `{"ok":false,"error":"...","done":true}` line; an overloaded daemon
//! answers the *connection* with `{"ok":false,"busy":true,...}` before
//! closing it. Responses are [`Json::encode_compact`] — exactly one
//! line each, deterministic key order.

use anyhow::{anyhow, bail, Result};

use crate::scenario::Scenario;
use crate::util::json::{escape, Json};

/// Wire-protocol version, reported by `ping` and `stats`. Bump on any
/// change to request/response shapes (guarded by `repro lint` R3).
/// v2: the `stats` response gained the `salvage` and `faults` objects.
pub const SERVE_PROTOCOL_VERSION: u32 = 2;

/// A decoded client request.
#[derive(Debug)]
pub enum Request {
    /// Evaluate a sweep scenario and stream its rows back.
    Eval(Box<Scenario>),
    /// Liveness + protocol probe.
    Ping,
    /// Global cache/metrics snapshot.
    Stats,
    /// Persist the cache now (under the save lock).
    Flush,
    /// Drain and exit after in-flight requests finish.
    Shutdown,
}

impl Request {
    /// Op name as it appears on the wire (and in metrics).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Eval(_) => "eval",
            Request::Ping => "ping",
            Request::Stats => "stats",
            Request::Flush => "flush",
            Request::Shutdown => "shutdown",
        }
    }

    /// Decode one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let v = Json::parse(line)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request needs a string \"op\" field"))?;
        match op {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "flush" => Ok(Request::Flush),
            "shutdown" => Ok(Request::Shutdown),
            "eval" => {
                let sc = v
                    .get("scenario")
                    .ok_or_else(|| anyhow!("eval requests need a \"scenario\" object"))?;
                // Scenario::from_json parses text; round-tripping the
                // already-parsed object through the compact encoder
                // keeps one strict scenario decoder in the tree.
                let sc = Scenario::from_json(&sc.encode_compact())?;
                Ok(Request::Eval(Box::new(sc)))
            }
            other => bail!(
                "unknown op {other:?} (expected eval, ping, stats, flush or shutdown)"
            ),
        }
    }
}

/// `{"ok":false,"error":"...","done":true}` — the single-line error
/// response.
pub fn error_line(message: &str) -> String {
    format!("{{\"ok\":false,\"error\":\"{}\",\"done\":true}}", escape(message))
}

/// The explicit overload response, written straight from the acceptor
/// when the bounded queue rejects a connection.
pub fn busy_line() -> String {
    format!(
        "{{\"ok\":false,\"busy\":true,\"error\":\"server busy: accept queue full\",\
         \"protocol\":{SERVE_PROTOCOL_VERSION},\"done\":true}}"
    )
}

/// One streamed CSV line (without its trailing newline).
pub fn row_line(row: &str) -> String {
    format!("{{\"row\":\"{}\"}}", escape(row))
}

/// Build the single-line response for simple ops: merges `"ok":true`,
/// the op name, the protocol version, any op-specific fields, and the
/// `"done":true` terminator.
pub fn done_line(op: &str, fields: Vec<(String, Json)>) -> String {
    let mut obj = vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str(op.to_string())),
        (
            "protocol".to_string(),
            Json::Num(f64::from(SERVE_PROTOCOL_VERSION)),
        ),
    ];
    obj.extend(fields);
    obj.push(("done".to_string(), Json::Bool(true)));
    Json::Obj(obj).encode_compact()
}

/// The eval response header (precedes the row stream).
pub fn eval_header(name: &str, points: usize) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("eval".to_string())),
        (
            "protocol".to_string(),
            Json::Num(f64::from(SERVE_PROTOCOL_VERSION)),
        ),
        ("name".to_string(), Json::Str(name.to_string())),
        ("points".to_string(), Json::Num(points as f64)),
    ])
    .encode_compact()
}

/// The eval response terminator with per-request stats.
pub fn eval_done(stats: Vec<(String, Json)>) -> String {
    Json::Obj(vec![
        ("done".to_string(), Json::Bool(true)),
        ("ok".to_string(), Json::Bool(true)),
        ("stats".to_string(), Json::Obj(stats)),
    ])
    .encode_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_ops_parse() {
        for (line, op) in [
            ("{\"op\":\"ping\"}", "ping"),
            ("{\"op\":\"stats\"}", "stats"),
            ("{\"op\":\"flush\"}", "flush"),
            ("{\"op\":\"shutdown\"}", "shutdown"),
        ] {
            assert_eq!(Request::parse(line).unwrap().op(), op);
        }
    }

    #[test]
    fn eval_parses_an_inline_scenario() {
        let sc = Scenario::builder("wire")
            .workloads("synthetic:2")
            .prims("d1")
            .levels("rf")
            .seed(3)
            .build()
            .unwrap();
        let line = format!("{{\"op\":\"eval\",\"scenario\":{}}}", sc.to_json());
        match Request::parse(&line).unwrap() {
            Request::Eval(parsed) => assert_eq!(parsed.name, "wire"),
            other => panic!("expected eval, got {}", other.op()),
        }
    }

    #[test]
    fn bad_requests_are_rejected_with_context() {
        let err = Request::parse("{\"op\":\"frobnicate\"}").unwrap_err();
        assert!(format!("{err:#}").contains("unknown op"), "{err:#}");
        let err = Request::parse("{\"op\":\"eval\"}").unwrap_err();
        assert!(format!("{err:#}").contains("scenario"), "{err:#}");
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{\"noop\":true}").is_err());
    }

    #[test]
    fn response_lines_are_single_line_json() {
        for line in [
            error_line("boom \"quoted\""),
            busy_line(),
            row_line("a,b,c"),
            done_line("ping", vec![]),
            eval_header("quick", 12),
            eval_done(vec![("hits".to_string(), Json::Num(3.0))]),
        ] {
            assert!(!line.contains('\n'), "multi-line response: {line}");
            Json::parse(&line).expect("response must be valid JSON");
        }
        assert!(busy_line().contains("\"busy\":true"));
        assert!(done_line("ping", vec![]).contains("\"done\":true"));
    }
}
