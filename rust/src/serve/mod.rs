//! `repro serve` — a persistent warm-cache evaluation daemon.
//!
//! Every other entry point (`run`, `sweep`, `experiment`,
//! `orchestrate`) is a cold-start batch process that pays process
//! spawn plus cache load/save per invocation. This module keeps one
//! shared [`crate::sweep::EvalCache`] warm in a long-lived process and
//! answers scenario evaluations over a newline-delimited JSON protocol
//! on `std::net::TcpListener` — interactive design-space queries on
//! top of the paper's analytical model, with zero new dependencies.
//!
//! Layout:
//!
//! * [`protocol`] — wire format: request decoding, response encoding,
//!   and [`protocol::SERVE_PROTOCOL_VERSION`] (R3-guarded).
//! * [`handler`] — op implementations over the shared [`handler::ServerState`].
//! * [`listener`] — accept loop, bounded queue, worker pool, drain.
//! * [`metrics`] — per-op counters and log2-µs latency histograms.
//! * [`drain`] — SIGTERM/SIGINT → drain-flag bridge (no `libc` crate).
//! * [`client`] — the blocking client behind `repro query`, with a
//!   deterministic retry policy (exponential backoff, no jitter)
//!   distinguishing retryable outcomes (busy, connect-refused, torn
//!   response) from fatal protocol errors.
//!
//! Determinism invariant (pinned by `tests/integration_serve.rs` and
//! the CI e2e step): the row stream of an `eval` response is
//! byte-identical to the CSV the same scenario writes via `repro run`.
//! Cache warmth, worker count, request interleaving and coalescing
//! must not be observable in the payload — only in the stats.

pub mod client;
pub mod drain;
pub mod handler;
pub mod listener;
pub mod metrics;
pub mod protocol;

pub use client::{
    eval_with_retry, simple_with_retry, Client, EvalResponse, RetryPolicy,
};
pub use listener::{Server, ServeOptions};
pub use protocol::SERVE_PROTOCOL_VERSION;
