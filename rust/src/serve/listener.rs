//! The TCP listener, accept loop and worker pool.
//!
//! One acceptor thread (the caller of [`Server::run`]) and `workers`
//! persistent worker threads joined by a [`BoundedQueue`] of accepted
//! connections. The queue is the backpressure boundary: when it is
//! full the acceptor answers the connection with the explicit busy
//! line and closes it — the daemon never buffers without bound.
//!
//! Connections are keep-alive: a worker serves requests off one socket
//! until the client closes it (or the daemon drains), so a scripted
//! client pays connection setup once. Reads use a short timeout so
//! idle workers notice the drain flag promptly.
//!
//! Drain (SIGTERM, `shutdown` op, or [`Server::request_drain`]): the
//! acceptor stops accepting, closes the queue (queued connections
//! still get served), joins every worker — which finish their
//! in-flight request and then close their connection at the next read
//! boundary — and finally persists the cache under the save lock.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::sweep::{persist, EvalCache};
use crate::util::faults::{self, FaultAction};
use crate::util::json::Json;
use crate::util::pool::{self, BoundedQueue};

use super::drain;
use super::handler::{self, ServerState};
use super::protocol::{self, Request};

/// How the daemon is configured (CLI flags map 1:1 onto this).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:7878` (`:0` picks a free port).
    pub addr: String,
    /// Worker threads serving connections.
    pub workers: usize,
    /// Accepted-connection queue capacity; overflow answers busy.
    pub queue_depth: usize,
    /// Cache file to warm from at startup and flush to on drain/`flush`.
    pub cache_path: Option<PathBuf>,
    /// LRU size cap applied when persisting.
    pub cache_max_bytes: Option<u64>,
    /// Honor process-wide SIGTERM/SIGINT (CLI: yes; in-process tests:
    /// no — the flag is global and sticky, which would couple tests).
    pub watch_signals: bool,
    /// Suppress status lines (in-process servers in tests/bench).
    pub quiet: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        let workers = pool::default_threads();
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            workers,
            queue_depth: workers * 2,
            cache_path: None,
            cache_max_bytes: None,
            watch_signals: false,
            quiet: false,
        }
    }
}

/// A bound (but not yet running) daemon.
#[derive(Debug)]
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    opts: ServeOptions,
}

/// Accept-loop poll interval; also bounds drain-detection latency.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Per-read socket timeout; bounds how long an idle worker takes to
/// notice the drain flag.
const READ_POLL: Duration = Duration::from_millis(250);

impl Server {
    /// Bind the listener and warm the cache from `cache_path` (if any).
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let cache = Arc::new(EvalCache::new());
        let mut salvage = (0u64, 0u64);
        if let Some(path) = &opts.cache_path {
            let load = persist::load_into(&cache, path)?;
            if let persist::CacheLoad::Salvaged { kept, dropped, .. } = &load {
                salvage = (*kept as u64, *dropped as u64);
            }
            if !opts.quiet {
                println!("[serve] cache: {} ({})", load.describe(), path.display());
            }
        }
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        // Non-blocking accept so the loop can poll the drain flag.
        listener.set_nonblocking(true)?;
        let state = Arc::new(
            ServerState::new(cache, opts.cache_path.clone(), opts.cache_max_bytes)
                .with_salvage(salvage.0, salvage.1),
        );
        Ok(Server { listener, state, opts })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared handle onto the daemon's state (tests assert on it).
    pub fn state(&self) -> Arc<ServerState> {
        Arc::clone(&self.state)
    }

    /// Programmatic drain trigger equivalent to the `shutdown` op —
    /// the in-process way to stop a [`Server::run`] loop.
    pub fn request_drain(&self) {
        self.state
            .draining
            .store(true, std::sync::atomic::Ordering::Relaxed);
    }

    fn drain_requested(&self) -> bool {
        self.state.draining()
            || (self.opts.watch_signals && drain::termination_requested())
    }

    /// Serve until drained, then flush the cache and return. This is
    /// the daemon's whole life; it owns the calling thread.
    pub fn run(self) -> Result<()> {
        if self.opts.watch_signals {
            drain::install();
        }
        let queue: BoundedQueue<TcpStream> = BoundedQueue::new(self.opts.queue_depth);
        let addr = self.local_addr()?;
        if !self.opts.quiet {
            println!(
                "[serve] listening on {addr} (protocol v{}, {} worker(s), queue {})",
                protocol::SERVE_PROTOCOL_VERSION,
                self.opts.workers,
                self.opts.queue_depth
            );
        }

        std::thread::scope(|scope| {
            for _ in 0..self.opts.workers {
                scope.spawn(|| worker_loop(&self.state, &queue));
            }

            // Accept loop: runs on the caller's thread until drained.
            loop {
                if self.drain_requested() {
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        // Chaos hook: force this accept down the busy
                        // path as if the queue were full, so client
                        // retry handling is testable deterministically.
                        if faults::check("serve.accept") == FaultAction::Fail {
                            reject_busy(&self.state, stream);
                        } else {
                            match queue.try_push(stream) {
                                Ok(()) => self.state.metrics.record_connection(),
                                Err(stream) => reject_busy(&self.state, stream),
                            }
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => {
                        // Transient accept failures (e.g. EMFILE) must
                        // not kill the daemon; back off and retry.
                        eprintln!("[serve] accept error: {e}");
                        std::thread::sleep(ACCEPT_POLL);
                    }
                }
            }

            // Drain: make the flag visible to workers parked on idle
            // connections, stop feeding the queue, serve what is
            // already queued, and wait for every in-flight request.
            self.state
                .draining
                .store(true, std::sync::atomic::Ordering::Relaxed);
            if !self.opts.quiet {
                println!("[serve] draining: finishing in-flight requests");
            }
            queue.close();
        });

        // Every worker has exited; flush under the save lock.
        let flushed = self.state.flush_cache()?;
        if !self.opts.quiet {
            match flushed {
                Some(outcome) => {
                    println!("[serve] final flush: {}", outcome.describe())
                }
                None => println!("[serve] no cache path configured; nothing to flush"),
            }
            println!("[serve] drained; bye");
        }
        Ok(())
    }
}

/// Answer a connection the queue rejected with the explicit busy line.
fn reject_busy(state: &ServerState, mut stream: TcpStream) {
    state.metrics.record_busy();
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let _ = stream.write_all(protocol::busy_line().as_bytes());
    let _ = stream.write_all(b"\n");
    // Dropping the stream closes it.
}

fn worker_loop(state: &ServerState, queue: &BoundedQueue<TcpStream>) {
    while let Some(stream) = queue.pop() {
        serve_connection(state, stream);
    }
}

/// Serve one keep-alive connection until the client closes it, an IO
/// error occurs, or the daemon drains (checked between requests).
fn serve_connection(state: &ServerState, mut stream: TcpStream) {
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        // Serve every complete line already buffered.
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&line);
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if !respond(state, &mut stream, line) {
                return;
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // client closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // Idle poll: close only when draining and no request
                // is partially buffered.
                if state.draining() && buf.is_empty() {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Decode, dispatch and answer one request line. Returns `false` when
/// the connection should close (write failure).
fn respond(state: &ServerState, stream: &mut TcpStream, line: &str) -> bool {
    let started = Instant::now();
    let (op, lines, ok) = match Request::parse(line) {
        Ok(request) => {
            let (lines, _shutdown) = handler::handle(state, &request);
            let ok = lines
                .first()
                .and_then(|l| Json::parse(l).ok())
                .and_then(|v| v.get("ok").and_then(Json::as_bool))
                .unwrap_or(false);
            (request.op(), lines, ok)
        }
        Err(e) => {
            state.metrics.record_bad_request();
            ("", vec![protocol::error_line(&format!("{e:#}"))], false)
        }
    };
    let mut payload = String::new();
    for l in &lines {
        payload.push_str(l);
        payload.push('\n');
    }
    let written = stream.write_all(payload.as_bytes()).is_ok() && stream.flush().is_ok();
    if !op.is_empty() {
        state.metrics.record(op, started.elapsed(), ok && written);
    }
    written
}
