//! Termination signaling for the daemon.
//!
//! The drain contract: on SIGTERM (or a `shutdown` op) the listener
//! stops accepting, queued and in-flight requests run to completion,
//! the cache is flushed under the persistence lock, and the process
//! exits 0. The signal handler itself only flips an [`AtomicBool`] —
//! everything async-signal-unsafe happens on the accept loop, which
//! polls [`termination_requested`] between accepts.
//!
//! No `libc` crate offline, so the handler is registered through the
//! raw C `signal(2)` symbol. Non-unix builds skip registration and rely
//! on [`request_termination`] (which tests use on every platform).

use std::sync::atomic::{AtomicBool, Ordering};

/// Set once by the signal handler (or [`request_termination`]); never
/// cleared — a drained daemon does not come back.
static TERM_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    /// `signal(2)` from the platform libc. `usize` stands in for the
    /// handler function pointer / `SIG_ERR` sentinel.
    fn signal(signum: i32, handler: usize) -> usize;
}

#[cfg(unix)]
extern "C" fn on_term(_signum: i32) {
    // Async-signal-safe: a relaxed store is a single atomic write.
    TERM_FLAG.store(true, Ordering::Relaxed);
}

/// Install the SIGTERM/SIGINT → drain-flag handlers. Idempotent;
/// a registration failure is ignored (the daemon still drains via the
/// `shutdown` op).
pub fn install() {
    #[cfg(unix)]
    {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        // SAFETY: `on_term` only performs an atomic store, which is
        // async-signal-safe; the handler address stays valid for the
        // life of the process.
        unsafe {
            signal(SIGTERM, on_term as usize);
            signal(SIGINT, on_term as usize);
        }
    }
}

/// Has a drain been requested (signal or [`request_termination`])?
pub fn termination_requested() -> bool {
    TERM_FLAG.load(Ordering::Relaxed)
}

/// Programmatic drain trigger — the `shutdown` op and the tests use
/// this instead of delivering a real signal.
pub fn request_termination() {
    TERM_FLAG.store(true, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_termination_flips_the_flag() {
        // Note: the flag is process-global and sticky, so this test is
        // meaningful only for the transition; other tests that consult
        // it must tolerate either state.
        install();
        request_termination();
        assert!(termination_requested());
    }
}
