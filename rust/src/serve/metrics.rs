//! Per-op counters and latency histograms for the daemon.
//!
//! Lock-free atomics on the request path; the `stats` op snapshots
//! everything into deterministic JSON (keys in fixed order, buckets
//! always present) so dashboards and tests can diff responses.
//!
//! Latency uses log2 microsecond buckets: bucket `i` counts requests
//! with `latency_us` in `[2^i, 2^(i+1))` (bucket 0 additionally takes
//! sub-microsecond requests, the last bucket is open-ended). Fixed
//! 20 buckets cover 1 µs .. ~0.5 s, plenty for an analytical model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Histogram bucket count: log2 µs buckets 0..19, last open-ended.
pub const LATENCY_BUCKETS: usize = 20;

/// The ops tracked, in the order they appear in every stats snapshot.
pub const TRACKED_OPS: &[&str] = &["eval", "ping", "stats", "flush", "shutdown"];

/// Counters + latency histogram for one op.
#[derive(Debug, Default)]
struct OpMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    total_us: AtomicU64,
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl OpMetrics {
    fn record(&self, latency: Duration, ok: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        self.total_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (63 - us.max(1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> Json {
        let requests = self.requests.load(Ordering::Relaxed);
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .map(|b| Json::Num(b.load(Ordering::Relaxed) as f64))
            .collect();
        Json::Obj(vec![
            ("requests".into(), Json::Num(requests as f64)),
            (
                "errors".into(),
                Json::Num(self.errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "total_us".into(),
                Json::Num(self.total_us.load(Ordering::Relaxed) as f64),
            ),
            ("latency_log2us".into(), Json::Arr(buckets)),
        ])
    }
}

/// All daemon metrics: per-op plus listener-level counters that have
/// no op to attribute to (busy rejections, undecodable requests).
#[derive(Debug, Default)]
pub struct ServeMetrics {
    ops: [OpMetrics; TRACKED_OPS.len()],
    busy: AtomicU64,
    bad_requests: AtomicU64,
    connections: AtomicU64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one handled request. Unknown op names count as
    /// bad requests (they were answered with an error line).
    pub fn record(&self, op: &str, latency: Duration, ok: bool) {
        match TRACKED_OPS.iter().position(|&t| t == op) {
            Some(i) => self.ops[i].record(latency, ok),
            None => {
                self.bad_requests.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// A connection was rejected with the explicit busy response.
    pub fn record_busy(&self) {
        self.busy.fetch_add(1, Ordering::Relaxed);
    }

    /// A line arrived that did not decode to a request.
    pub fn record_bad_request(&self) {
        self.bad_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection was accepted and handed to a worker.
    pub fn record_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn busy_count(&self) -> u64 {
        self.busy.load(Ordering::Relaxed)
    }

    /// Deterministic JSON snapshot, embedded in the `stats` response.
    pub fn snapshot(&self) -> Json {
        let ops: Vec<(String, Json)> = TRACKED_OPS
            .iter()
            .zip(self.ops.iter())
            .map(|(name, m)| ((*name).to_string(), m.snapshot()))
            .collect();
        Json::Obj(vec![
            (
                "connections".into(),
                Json::Num(self.connections.load(Ordering::Relaxed) as f64),
            ),
            ("busy".into(), Json::Num(self.busy.load(Ordering::Relaxed) as f64)),
            (
                "bad_requests".into(),
                Json::Num(self.bad_requests.load(Ordering::Relaxed) as f64),
            ),
            ("ops".into(), Json::Obj(ops)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_lands_in_the_log2_bucket() {
        let m = ServeMetrics::new();
        m.record("eval", Duration::from_micros(0), true); // bucket 0
        m.record("eval", Duration::from_micros(1), true); // bucket 0
        m.record("eval", Duration::from_micros(3), true); // bucket 1
        m.record("eval", Duration::from_micros(1500), false); // bucket 10
        m.record("eval", Duration::from_secs(3600), true); // clamped to last
        let snap = m.snapshot();
        let eval = snap.get("ops").unwrap().get("eval").unwrap();
        assert_eq!(eval.get("requests").unwrap().as_u64(), Some(5));
        assert_eq!(eval.get("errors").unwrap().as_u64(), Some(1));
        let buckets = eval.get("latency_log2us").unwrap().as_array().unwrap();
        assert_eq!(buckets.len(), LATENCY_BUCKETS);
        assert_eq!(buckets[0].as_u64(), Some(2));
        assert_eq!(buckets[1].as_u64(), Some(1));
        assert_eq!(buckets[10].as_u64(), Some(1));
        assert_eq!(buckets[LATENCY_BUCKETS - 1].as_u64(), Some(1));
    }

    #[test]
    fn unknown_ops_count_as_bad_requests() {
        let m = ServeMetrics::new();
        m.record("frobnicate", Duration::from_micros(1), false);
        m.record_busy();
        let snap = m.snapshot();
        assert_eq!(snap.get("bad_requests").unwrap().as_u64(), Some(1));
        assert_eq!(snap.get("busy").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn snapshot_lists_every_tracked_op_even_when_idle() {
        let snap = ServeMetrics::new().snapshot();
        let ops = snap.get("ops").unwrap();
        for op in TRACKED_OPS {
            assert_eq!(
                ops.get(op).and_then(|o| o.get("requests")).and_then(Json::as_u64),
                Some(0),
                "op {op} missing from idle snapshot"
            );
        }
    }
}
