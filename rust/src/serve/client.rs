//! Blocking client for the serve protocol — the library behind
//! `repro query`, the integration tests and `examples/serve_client.rs`.
//!
//! One [`Client`] is one keep-alive connection: issue as many requests
//! as you like, in order. Each call sends one request line and reads
//! response lines until the `"done":true` terminator.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Result};

use crate::scenario::Scenario;
use crate::util::json::Json;

/// A decoded `eval` response.
#[derive(Debug, Clone)]
pub struct EvalResponse {
    /// Output base name the daemon derived from the scenario.
    pub name: String,
    /// The reconstructed CSV document — byte-identical to what
    /// `repro run` writes for the same scenario.
    pub csv: String,
    /// The per-request stats object from the terminator line
    /// (`points`, `hits`, `misses`, `mapper_calls`, `elapsed_us`).
    pub stats: Json,
}

/// One keep-alive connection to a serve daemon.
#[derive(Debug)]
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7878`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to serve daemon at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    /// Send one request line, collect response lines through the
    /// `"done":true` terminator (inclusive). A busy/error response is
    /// a single terminator line, so this never hangs on rejection.
    fn exchange(&mut self, request: &str) -> Result<Vec<Json>> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut lines = Vec::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                bail!("daemon closed the connection mid-response");
            }
            let v = Json::parse(line.trim())
                .with_context(|| format!("undecodable response line: {}", line.trim()))?;
            let done = v.get("done").and_then(Json::as_bool) == Some(true);
            lines.push(v);
            if done {
                return Ok(lines);
            }
        }
    }

    /// A simple op (`ping`/`stats`/`flush`/`shutdown`): one response
    /// line. Errors (including busy) surface as `Err`.
    fn simple(&mut self, op: &str) -> Result<Json> {
        let lines = self.exchange(&format!("{{\"op\":\"{op}\"}}"))?;
        let v = lines
            .into_iter()
            .next_back()
            .ok_or_else(|| anyhow!("empty response"))?;
        check_ok(&v)?;
        Ok(v)
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.simple("ping")
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.simple("stats")
    }

    pub fn flush(&mut self) -> Result<Json> {
        self.simple("flush")
    }

    /// Ask the daemon to drain and exit (it finishes in-flight
    /// requests, flushes the cache, then terminates).
    pub fn shutdown(&mut self) -> Result<Json> {
        self.simple("shutdown")
    }

    /// Evaluate a sweep scenario on the daemon's warm cache.
    pub fn eval(&mut self, sc: &Scenario) -> Result<EvalResponse> {
        // `Scenario::to_json` pretty-prints; the wire format is one
        // line per request, so re-encode compactly.
        let compact = Json::parse(&sc.to_json())
            .context("re-encoding the scenario for the wire")?
            .encode_compact();
        let request = format!("{{\"op\":\"eval\",\"scenario\":{compact}}}");
        let lines = self.exchange(&request)?;
        let header = lines
            .first()
            .ok_or_else(|| anyhow!("empty eval response"))?;
        check_ok(header)?;
        let name = header
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("eval header missing \"name\""))?
            .to_string();
        let mut csv = String::new();
        for v in &lines {
            if let Some(row) = v.get("row").and_then(Json::as_str) {
                csv.push_str(row);
                csv.push('\n');
            }
        }
        let last = lines
            .last()
            .ok_or_else(|| anyhow!("eval response missing terminator"))?;
        check_ok(last)?;
        let stats = last
            .get("stats")
            .cloned()
            .ok_or_else(|| anyhow!("eval terminator missing \"stats\""))?;
        Ok(EvalResponse { name, csv, stats })
    }
}

/// Turn `{"ok":false,...}` responses into typed errors.
fn check_ok(v: &Json) -> Result<()> {
    if v.get("ok").and_then(Json::as_bool) == Some(false) {
        let msg = v
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("daemon reported an unspecified error");
        bail!("{msg}");
    }
    Ok(())
}
