//! Blocking client for the serve protocol — the library behind
//! `repro query`, the integration tests and `examples/serve_client.rs`.
//!
//! One [`Client`] is one keep-alive connection: issue as many requests
//! as you like, in order. Each call sends one request line and reads
//! response lines until the `"done":true` terminator.
//!
//! ## Retryable vs fatal
//!
//! Every failure an attempt can hit is classified once, here:
//! *retryable* outcomes are transient daemon/transport states — an
//! explicit `busy:true` rejection, a refused/timed-out connection, a
//! connection closed mid-response before the `done` terminator, a
//! request-deadline expiry — while *fatal* outcomes are protocol-level
//! errors that would fail identically on any retry (an `ok:false`
//! response without `busy`, an undecodable response line, a malformed
//! response shape). [`eval_with_retry`] / [`simple_with_retry`] drive
//! a fresh connection per attempt under a [`RetryPolicy`]:
//! exponential backoff, **no jitter** — retry schedules are as
//! deterministic as every other output of this tree.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::scenario::Scenario;
use crate::util::json::Json;

/// A decoded `eval` response.
#[derive(Debug, Clone)]
pub struct EvalResponse {
    /// Output base name the daemon derived from the scenario.
    pub name: String,
    /// The reconstructed CSV document — byte-identical to what
    /// `repro run` writes for the same scenario.
    pub csv: String,
    /// The per-request stats object from the terminator line
    /// (`points`, `hits`, `misses`, `mapper_calls`, `elapsed_us`).
    pub stats: Json,
}

/// Deterministic client-side retry policy (`repro query --retries
/// --backoff-ms --deadline-ms`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Extra attempts after the first (0 = fail fast, the default).
    pub retries: u32,
    /// Base backoff; attempt `k` sleeps `backoff_ms << k`. No jitter:
    /// the schedule is reproducible.
    pub backoff_ms: u64,
    /// Per-attempt deadline covering connect and every read/write
    /// (0 = no deadline).
    pub deadline_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            retries: 0,
            backoff_ms: 50,
            deadline_ms: 0,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based): exponential,
    /// saturating, jitter-free.
    pub fn backoff(&self, attempt: u32) -> Duration {
        Duration::from_millis(self.backoff_ms.saturating_mul(1u64 << attempt.min(16)))
    }
}

/// One attempt's failure, classified for the retry loop.
#[derive(Debug)]
enum AttemptError {
    /// Transient: a later attempt may succeed (busy daemon, refused
    /// connection, torn response, deadline expiry).
    Retryable(anyhow::Error),
    /// Protocol-level: every retry would fail identically.
    Fatal(anyhow::Error),
}

impl AttemptError {
    fn into_error(self) -> anyhow::Error {
        match self {
            AttemptError::Retryable(e) | AttemptError::Fatal(e) => e,
        }
    }
}

/// One keep-alive connection to a serve daemon.
#[derive(Debug)]
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7878`) with no deadline.
    pub fn connect(addr: &str) -> Result<Client> {
        connect_within(addr, 0).map_err(AttemptError::into_error)
    }

    /// Send one request line, collect response lines through the
    /// `"done":true` terminator (inclusive). A busy/error response is
    /// a single terminator line, so this never hangs on rejection.
    fn try_exchange(&mut self, request: &str) -> Result<Vec<Json>, AttemptError> {
        let sent = self
            .writer
            .write_all(request.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"))
            .and_then(|()| self.writer.flush());
        if let Err(e) = sent {
            // A send failure means the daemon went away (or the
            // deadline expired) — transient either way.
            return Err(AttemptError::Retryable(anyhow!("sending request: {e}")));
        }
        let mut lines = Vec::new();
        loop {
            let mut line = String::new();
            let n = match self.reader.read_line(&mut line) {
                Ok(n) => n,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut =>
                {
                    return Err(AttemptError::Retryable(anyhow!(
                        "request deadline exceeded waiting for a response line"
                    )));
                }
                Err(e) => {
                    return Err(AttemptError::Retryable(anyhow!(
                        "reading response: {e}"
                    )));
                }
            };
            if n == 0 {
                // EOF before the terminator: the daemon died or
                // dropped us mid-response — the response is torn, a
                // fresh attempt gets a whole one.
                return Err(AttemptError::Retryable(anyhow!(
                    "daemon closed the connection mid-response"
                )));
            }
            let v = match Json::parse(line.trim()) {
                Ok(v) => v,
                Err(e) => {
                    return Err(AttemptError::Fatal(anyhow!(
                        "undecodable response line {:?}: {e:#}",
                        line.trim()
                    )));
                }
            };
            let done = v.get("done").and_then(Json::as_bool) == Some(true);
            lines.push(v);
            if done {
                return Ok(lines);
            }
        }
    }

    /// A simple op (`ping`/`stats`/`flush`/`shutdown`): one response
    /// line. Errors (including busy) surface as `Err`.
    fn try_simple(&mut self, op: &str) -> Result<Json, AttemptError> {
        let lines = self.try_exchange(&format!("{{\"op\":\"{op}\"}}"))?;
        let v = lines
            .into_iter()
            .next_back()
            .ok_or_else(|| AttemptError::Fatal(anyhow!("empty response")))?;
        classify_ok(&v)?;
        Ok(v)
    }

    fn try_eval(&mut self, sc: &Scenario) -> Result<EvalResponse, AttemptError> {
        // `Scenario::to_json` pretty-prints; the wire format is one
        // line per request, so re-encode compactly.
        let compact = match Json::parse(&sc.to_json()) {
            Ok(v) => v.encode_compact(),
            Err(e) => {
                return Err(AttemptError::Fatal(
                    e.context("re-encoding the scenario for the wire"),
                ));
            }
        };
        let request = format!("{{\"op\":\"eval\",\"scenario\":{compact}}}");
        let lines = self.try_exchange(&request)?;
        let header = lines
            .first()
            .ok_or_else(|| AttemptError::Fatal(anyhow!("empty eval response")))?;
        classify_ok(header)?;
        let name = header
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| AttemptError::Fatal(anyhow!("eval header missing \"name\"")))?
            .to_string();
        let mut csv = String::new();
        for v in &lines {
            if let Some(row) = v.get("row").and_then(Json::as_str) {
                csv.push_str(row);
                csv.push('\n');
            }
        }
        let last = lines
            .last()
            .ok_or_else(|| AttemptError::Fatal(anyhow!("eval response missing terminator")))?;
        classify_ok(last)?;
        let stats = last
            .get("stats")
            .cloned()
            .ok_or_else(|| AttemptError::Fatal(anyhow!("eval terminator missing \"stats\"")))?;
        Ok(EvalResponse { name, csv, stats })
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.try_simple("ping").map_err(AttemptError::into_error)
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.try_simple("stats").map_err(AttemptError::into_error)
    }

    pub fn flush(&mut self) -> Result<Json> {
        self.try_simple("flush").map_err(AttemptError::into_error)
    }

    /// Ask the daemon to drain and exit (it finishes in-flight
    /// requests, flushes the cache, then terminates).
    pub fn shutdown(&mut self) -> Result<Json> {
        self.try_simple("shutdown").map_err(AttemptError::into_error)
    }

    /// Evaluate a sweep scenario on the daemon's warm cache.
    pub fn eval(&mut self, sc: &Scenario) -> Result<EvalResponse> {
        self.try_eval(sc).map_err(AttemptError::into_error)
    }
}

/// Connect with an optional per-attempt deadline applied to the
/// connect itself and, via socket timeouts, to every later read and
/// write on the connection. Connection failures are retryable — the
/// daemon may simply not be up yet.
fn connect_within(addr: &str, deadline_ms: u64) -> Result<Client, AttemptError> {
    let stream = if deadline_ms == 0 {
        TcpStream::connect(addr)
            .with_context(|| format!("connecting to serve daemon at {addr}"))
            .map_err(AttemptError::Retryable)?
    } else {
        use std::net::ToSocketAddrs;
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving serve daemon address {addr}"))
            .map_err(AttemptError::Fatal)?
            .next()
            .ok_or_else(|| {
                AttemptError::Fatal(anyhow!("no socket address behind {addr}"))
            })?;
        TcpStream::connect_timeout(&sock, Duration::from_millis(deadline_ms))
            .with_context(|| {
                format!("connecting to serve daemon at {addr} within {deadline_ms} ms")
            })
            .map_err(AttemptError::Retryable)?
    };
    if deadline_ms > 0 {
        let timeout = Some(Duration::from_millis(deadline_ms));
        let _ = stream.set_read_timeout(timeout);
        let _ = stream.set_write_timeout(timeout);
    }
    let _ = stream.set_nodelay(true);
    let reader = BufReader::new(
        stream
            .try_clone()
            .context("cloning the daemon connection")
            .map_err(AttemptError::Retryable)?,
    );
    Ok(Client { reader, writer: stream })
}

/// Classify `{"ok":false,...}` responses: an explicit `busy:true` is
/// the daemon shedding load (retryable); anything else is a protocol
/// error a retry would only repeat (fatal).
fn classify_ok(v: &Json) -> Result<(), AttemptError> {
    if v.get("ok").and_then(Json::as_bool) == Some(false) {
        let msg = v
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("daemon reported an unspecified error");
        if v.get("busy").and_then(Json::as_bool) == Some(true) {
            return Err(AttemptError::Retryable(anyhow!("daemon busy: {msg}")));
        }
        return Err(AttemptError::Fatal(anyhow!("{msg}")));
    }
    Ok(())
}

/// Run one attempt function against a fresh connection per attempt,
/// under `policy`. Retryable failures sleep the deterministic backoff
/// and try again; fatal failures and exhausted budgets return the
/// underlying error.
fn retry_loop<T>(
    addr: &str,
    policy: &RetryPolicy,
    mut attempt: impl FnMut(&mut Client) -> Result<T, AttemptError>,
) -> Result<T> {
    let mut tries = 0u32;
    loop {
        let outcome = match connect_within(addr, policy.deadline_ms) {
            Ok(mut client) => attempt(&mut client),
            Err(e) => Err(e),
        };
        match outcome {
            Ok(v) => return Ok(v),
            Err(AttemptError::Fatal(e)) => return Err(e),
            Err(AttemptError::Retryable(e)) => {
                if tries >= policy.retries {
                    return Err(
                        e.context(format!("giving up after {} attempt(s)", tries + 1))
                    );
                }
                let backoff = policy.backoff(tries);
                eprintln!(
                    "[query] attempt {}/{} failed ({e:#}); retrying in {} ms",
                    tries + 1,
                    policy.retries + 1,
                    backoff.as_millis()
                );
                std::thread::sleep(backoff);
                tries += 1;
            }
        }
    }
}

/// [`Client::eval`] under a retry policy, one fresh connection per
/// attempt (the previous connection may be dead or timed out).
pub fn eval_with_retry(
    addr: &str,
    sc: &Scenario,
    policy: &RetryPolicy,
) -> Result<EvalResponse> {
    retry_loop(addr, policy, |client| client.try_eval(sc))
}

/// A simple op under a retry policy (see [`eval_with_retry`]).
pub fn simple_with_retry(addr: &str, op: &str, policy: &RetryPolicy) -> Result<Json> {
    retry_loop(addr, policy, |client| client.try_simple(op))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_is_exponential_and_deterministic() {
        let policy = RetryPolicy {
            retries: 5,
            backoff_ms: 50,
            deadline_ms: 0,
        };
        let schedule: Vec<u128> =
            (0..4).map(|k| policy.backoff(k).as_millis()).collect();
        assert_eq!(schedule, vec![50, 100, 200, 400]);
        // Identical inputs, identical schedule — no jitter.
        assert_eq!(policy.backoff(3), policy.backoff(3));
        // Huge attempt numbers saturate instead of overflowing.
        let far = RetryPolicy {
            retries: 0,
            backoff_ms: u64::MAX,
            deadline_ms: 0,
        };
        assert_eq!(far.backoff(40).as_millis(), u64::MAX as u128);
    }

    #[test]
    fn default_policy_fails_fast() {
        let policy = RetryPolicy::default();
        assert_eq!(policy.retries, 0);
        assert_eq!(policy.deadline_ms, 0);
        assert_eq!(policy.backoff(0).as_millis(), 50);
    }

    #[test]
    fn busy_is_retryable_other_errors_are_fatal() {
        let busy = Json::parse(
            "{\"ok\":false,\"busy\":true,\"error\":\"server busy\",\"done\":true}",
        )
        .unwrap();
        match classify_ok(&busy) {
            Err(AttemptError::Retryable(e)) => {
                assert!(format!("{e:#}").contains("busy"), "{e:#}")
            }
            other => panic!("busy must be retryable, got {other:?}"),
        }
        let fatal =
            Json::parse("{\"ok\":false,\"error\":\"unknown op\",\"done\":true}").unwrap();
        match classify_ok(&fatal) {
            Err(AttemptError::Fatal(e)) => {
                assert!(format!("{e:#}").contains("unknown op"), "{e:#}")
            }
            other => panic!("protocol errors must be fatal, got {other:?}"),
        }
        assert!(classify_ok(&Json::parse("{\"ok\":true}").unwrap()).is_ok());
    }
}
