//! Request handlers: one shared [`ServerState`] behind every worker,
//! one function per op. Handlers are pure with respect to the
//! connection — they return response *lines* (already
//! compact-encoded); the listener owns sockets, framing and flushing.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::scenario::exec;
use crate::sweep::{persist, EvalCache};
use crate::util::json::Json;

use super::metrics::ServeMetrics;
use super::protocol::{self, Request};

/// Everything the workers share: the warm cache, its persistence
/// policy, metrics, and the drain flag.
#[derive(Debug)]
pub struct ServerState {
    pub cache: Arc<EvalCache>,
    pub cache_path: Option<PathBuf>,
    pub cache_max_bytes: Option<u64>,
    pub metrics: ServeMetrics,
    /// Flipped by `shutdown` (and by the listener on SIGTERM); workers
    /// finish in-flight requests, then the listener flushes and exits.
    pub draining: AtomicBool,
    pub started: Instant,
    /// Entries kept / lines dropped by a salvaging startup cache load
    /// (both 0 after a clean load), reported by `stats` so chaos tests
    /// can assert the daemon recovered instead of discarding.
    pub salvaged_kept: u64,
    pub salvaged_dropped: u64,
}

impl ServerState {
    pub fn new(
        cache: Arc<EvalCache>,
        cache_path: Option<PathBuf>,
        cache_max_bytes: Option<u64>,
    ) -> Self {
        ServerState {
            cache,
            cache_path,
            cache_max_bytes,
            metrics: ServeMetrics::new(),
            draining: AtomicBool::new(false),
            started: Instant::now(),
            salvaged_kept: 0,
            salvaged_dropped: 0,
        }
    }

    /// Record the outcome of a salvaging startup cache load.
    pub fn with_salvage(mut self, kept: u64, dropped: u64) -> Self {
        self.salvaged_kept = kept;
        self.salvaged_dropped = dropped;
        self
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Persist the cache under the save-lock sidecar. No-op (`None`)
    /// without a configured cache path.
    pub fn flush_cache(&self) -> anyhow::Result<Option<persist::SaveOutcome>> {
        match &self.cache_path {
            None => Ok(None),
            Some(path) => {
                let outcome =
                    persist::save_capped(&self.cache, path, self.cache_max_bytes)?;
                Ok(Some(outcome))
            }
        }
    }
}

/// Handle one decoded request. Returns the response lines (in order)
/// and whether the daemon should begin draining afterwards.
pub fn handle(state: &ServerState, request: &Request) -> (Vec<String>, bool) {
    match request {
        Request::Ping => (vec![protocol::done_line("ping", vec![])], false),
        Request::Stats => (vec![stats_line(state)], false),
        Request::Flush => (vec![flush_line(state)], false),
        Request::Shutdown => {
            state.draining.store(true, Ordering::Relaxed);
            (
                vec![protocol::done_line(
                    "shutdown",
                    vec![("draining".to_string(), Json::Bool(true))],
                )],
                true,
            )
        }
        Request::Eval(sc) => eval_lines(state, sc),
    }
}

fn eval_lines(state: &ServerState, sc: &crate::scenario::Scenario) -> (Vec<String>, bool) {
    let eval = match exec::eval_sweep(sc, Arc::clone(&state.cache)) {
        Ok(eval) => eval,
        Err(e) => return (vec![protocol::error_line(&format!("{e:#}"))], false),
    };
    let mut lines = Vec::with_capacity(eval.csv.lines().count() + 2);
    lines.push(protocol::eval_header(&eval.name, eval.points));
    for row in eval.csv.lines() {
        lines.push(protocol::row_line(row));
    }
    lines.push(protocol::eval_done(vec![
        ("points".to_string(), Json::Num(eval.points as f64)),
        ("hits".to_string(), Json::Num(eval.hits as f64)),
        ("misses".to_string(), Json::Num(eval.misses as f64)),
        (
            "mapper_calls".to_string(),
            Json::Num(eval.mapper_calls as f64),
        ),
        (
            "elapsed_us".to_string(),
            Json::Num(eval.elapsed.as_micros() as f64),
        ),
    ]));
    (lines, false)
}

/// The armed fault points as `{point: {"hits":h,"fired":f}}` — an
/// empty object when `REPRO_FAULTS` is off. The snapshot is sorted by
/// point name, so the encoding is deterministic.
fn faults_json() -> Json {
    Json::Obj(
        crate::util::faults::snapshot()
            .into_iter()
            .map(|c| {
                (
                    c.point,
                    Json::Obj(vec![
                        ("hits".to_string(), Json::Num(c.hits as f64)),
                        ("fired".to_string(), Json::Num(c.fired as f64)),
                    ]),
                )
            })
            .collect(),
    )
}

/// The `stats` response: protocol + uptime + exact global cache
/// counters + salvage/fault counters + per-op metrics. Global counters
/// (not per-request deltas) are what tests assert on — they are exact
/// under concurrency.
fn stats_line(state: &ServerState) -> String {
    let cache = Json::Obj(vec![
        ("entries".to_string(), Json::Num(state.cache.len() as f64)),
        ("hits".to_string(), Json::Num(state.cache.hits() as f64)),
        ("misses".to_string(), Json::Num(state.cache.misses() as f64)),
        (
            "mapper_calls".to_string(),
            Json::Num(state.cache.mapper_calls() as f64),
        ),
        (
            "coalesced".to_string(),
            Json::Num(state.cache.coalesced() as f64),
        ),
    ]);
    protocol::done_line(
        "stats",
        vec![
            (
                "uptime_us".to_string(),
                Json::Num(state.started.elapsed().as_micros() as f64),
            ),
            ("draining".to_string(), Json::Bool(state.draining())),
            ("cache".to_string(), cache),
            (
                "salvage".to_string(),
                Json::Obj(vec![
                    (
                        "kept".to_string(),
                        Json::Num(state.salvaged_kept as f64),
                    ),
                    (
                        "dropped".to_string(),
                        Json::Num(state.salvaged_dropped as f64),
                    ),
                ]),
            ),
            ("faults".to_string(), faults_json()),
            ("metrics".to_string(), state.metrics.snapshot()),
        ],
    )
}

fn flush_line(state: &ServerState) -> String {
    match state.flush_cache() {
        Err(e) => protocol::error_line(&format!("flush failed: {e:#}")),
        Ok(None) => protocol::done_line(
            "flush",
            vec![("persisted".to_string(), Json::Bool(false))],
        ),
        Ok(Some(outcome)) => protocol::done_line(
            "flush",
            vec![
                ("persisted".to_string(), Json::Bool(true)),
                ("entries".to_string(), Json::Num(outcome.entries as f64)),
                ("evicted".to_string(), Json::Num(outcome.evicted as f64)),
            ],
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn state() -> ServerState {
        ServerState::new(Arc::new(EvalCache::new()), None, None)
    }

    fn quick_scenario(name: &str) -> Scenario {
        Scenario::builder(name)
            .workloads("synthetic:3")
            .prims("baseline,d1")
            .levels("rf")
            .seed(5)
            .threads(2)
            .build()
            .unwrap()
    }

    #[test]
    fn eval_rows_reconstruct_the_repro_run_csv() {
        let st = state();
        let sc = quick_scenario("hq");
        let (lines, shutdown) = handle(&st, &Request::Eval(Box::new(sc.clone())));
        assert!(!shutdown);
        let rows: Vec<String> = lines
            .iter()
            .filter_map(|l| {
                Json::parse(l).ok().and_then(|v| {
                    v.get("row").and_then(Json::as_str).map(|s| s.to_string())
                })
            })
            .collect();
        let reconstructed = rows.join("\n") + "\n";
        let direct = exec::eval_sweep(&sc, Arc::new(EvalCache::new())).unwrap().csv;
        assert_eq!(reconstructed, direct, "streamed rows must rebuild the CSV");
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(last.get("done").and_then(Json::as_bool), Some(true));
        assert_eq!(
            last.get("stats")
                .and_then(|s| s.get("misses"))
                .and_then(Json::as_u64),
            Some(6),
            "3 GEMMs x 2 systems, cold cache"
        );
    }

    #[test]
    fn second_eval_is_all_hits() {
        let st = state();
        let sc = quick_scenario("warm");
        let _ = handle(&st, &Request::Eval(Box::new(sc.clone())));
        let (lines, _) = handle(&st, &Request::Eval(Box::new(sc)));
        let last = Json::parse(lines.last().unwrap()).unwrap();
        let stats = last.get("stats").unwrap();
        assert_eq!(stats.get("misses").and_then(Json::as_u64), Some(0));
        assert_eq!(stats.get("mapper_calls").and_then(Json::as_u64), Some(0));
        assert_eq!(stats.get("hits").and_then(Json::as_u64), Some(6));
    }

    #[test]
    fn experiment_scenarios_are_refused_not_panicked() {
        let st = state();
        let sc = Scenario::builder("fig2").experiment("fig2").build().unwrap();
        let (lines, shutdown) = handle(&st, &Request::Eval(Box::new(sc)));
        assert!(!shutdown);
        assert_eq!(lines.len(), 1);
        let v = Json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert!(v.get("error").and_then(Json::as_str).unwrap().contains("sweep"));
    }

    #[test]
    fn shutdown_flips_the_drain_flag() {
        let st = state();
        assert!(!st.draining());
        let (lines, shutdown) = handle(&st, &Request::Shutdown);
        assert!(shutdown);
        assert!(st.draining());
        assert!(lines[0].contains("\"draining\":true"));
    }

    #[test]
    fn stats_reports_exact_global_cache_counters() {
        let st = state();
        let sc = quick_scenario("st");
        let _ = handle(&st, &Request::Eval(Box::new(sc.clone())));
        let _ = handle(&st, &Request::Eval(Box::new(sc)));
        let (lines, _) = handle(&st, &Request::Stats);
        let v = Json::parse(&lines[0]).unwrap();
        let cache = v.get("cache").unwrap();
        assert_eq!(cache.get("entries").and_then(Json::as_u64), Some(6));
        assert_eq!(cache.get("misses").and_then(Json::as_u64), Some(6));
        assert_eq!(cache.get("hits").and_then(Json::as_u64), Some(6));
    }

    #[test]
    fn stats_reports_salvage_and_fault_counters() {
        let st = state();
        let (lines, _) = handle(&st, &Request::Stats);
        let v = Json::parse(&lines[0]).unwrap();
        let salvage = v.get("salvage").expect("stats must carry salvage");
        assert_eq!(salvage.get("kept").and_then(Json::as_u64), Some(0));
        assert_eq!(salvage.get("dropped").and_then(Json::as_u64), Some(0));
        // Unarmed (the unit-test process never sets REPRO_FAULTS), the
        // faults object is present but empty.
        assert!(lines[0].contains("\"faults\":{}"), "{}", lines[0]);

        let st = state().with_salvage(41, 1);
        let (lines, _) = handle(&st, &Request::Stats);
        let v = Json::parse(&lines[0]).unwrap();
        let salvage = v.get("salvage").unwrap();
        assert_eq!(salvage.get("kept").and_then(Json::as_u64), Some(41));
        assert_eq!(salvage.get("dropped").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn flush_without_a_cache_path_reports_not_persisted() {
        let st = state();
        let (lines, _) = handle(&st, &Request::Flush);
        assert!(lines[0].contains("\"persisted\":false"), "{}", lines[0]);
    }

    #[test]
    fn flush_with_a_path_writes_a_loadable_cache_file() {
        let dir = std::env::temp_dir().join("www_cim_serve_handler_flush");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cache.bin");
        let st = ServerState::new(Arc::new(EvalCache::new()), Some(path.clone()), None);
        let _ = handle(&st, &Request::Eval(Box::new(quick_scenario("fl"))));
        let (lines, _) = handle(&st, &Request::Flush);
        assert!(lines[0].contains("\"persisted\":true"), "{}", lines[0]);
        assert!(path.exists());
        let fresh = EvalCache::new();
        persist::load_into(&fresh, &path).unwrap();
        assert_eq!(fresh.len(), 6, "flushed file must reload");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
