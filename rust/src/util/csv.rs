//! Minimal CSV output (serde/csv crates unavailable offline).
//!
//! Every experiment regenerator mirrors its printed table into
//! `results/<id>.csv` with this writer so figures can be re-plotted.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A row whose cell count does not match the document header.
///
/// Surfaced as a typed error (convertible into `anyhow::Error`) instead
/// of a panic: a malformed experiment row should fail that experiment's
/// `Result`, not abort a whole `repro experiment all` run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowArityError {
    pub expected: usize,
    pub got: usize,
}

impl std::fmt::Display for RowArityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "csv row arity mismatch: row has {} cells, header has {}",
            self.got, self.expected
        )
    }
}

impl std::error::Error for RowArityError {}

/// In-memory CSV document with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Csv {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; errors (rather than panics) when the cell count
    /// does not match the header arity.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> Result<&mut Self, RowArityError> {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        if cells.len() != self.header.len() {
            return Err(RowArityError {
                expected: self.header.len(),
                got: cells.len(),
            });
        }
        self.rows.push(cells);
        Ok(self)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// RFC-4180-style encoding: quote fields containing `,`, `"` or
    /// newlines; double embedded quotes.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        out.push_str(&encode_row(&self.header));
        for r in &self.rows {
            out.push_str(&encode_row(r));
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.encode().as_bytes())
    }
}

fn encode_row(cells: &[String]) -> String {
    let mut line = cells
        .iter()
        .map(|c| encode_field(c))
        .collect::<Vec<_>>()
        .join(",");
    line.push('\n');
    line
}

fn encode_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Parse a simple CSV document (no embedded newlines) — used by tests
/// and the artifact-manifest reader.
pub fn parse(text: &str) -> Vec<Vec<String>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse_line)
        .collect()
}

fn parse_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_basic() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["1", "2"]).unwrap();
        assert_eq!(c.encode(), "a,b\n1,2\n");
    }

    #[test]
    fn quoting() {
        assert_eq!(encode_field("plain"), "plain");
        assert_eq!(encode_field("a,b"), "\"a,b\"");
        assert_eq!(encode_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn roundtrip() {
        let mut c = Csv::new(vec!["x", "y"]);
        c.row(vec!["with,comma", "with \"quote\""]).unwrap();
        let parsed = parse(&c.encode());
        assert_eq!(parsed[0], vec!["x", "y"]);
        assert_eq!(parsed[1], vec!["with,comma", "with \"quote\""]);
    }

    #[test]
    fn roundtrip_quoted_fields_exhaustive() {
        // Encode/parse round trip over the quoting corner cases: plain,
        // embedded comma, embedded quotes, doubled quotes, both at once,
        // leading/trailing spaces, empty fields.
        let rows: Vec<Vec<String>> = vec![
            vec!["plain".into(), "".into(), " padded ".into()],
            vec!["a,b,c".into(), "say \"hi\"".into(), "\"\"".into()],
            vec!["mix,ed \"q,uote\"".into(), ",".into(), "\"".into()],
        ];
        let mut c = Csv::new(vec!["c1", "c2", "c3"]);
        for r in &rows {
            c.row(r.clone()).unwrap();
        }
        let parsed = parse(&c.encode());
        assert_eq!(parsed[0], vec!["c1", "c2", "c3"]);
        for (want, got) in rows.iter().zip(&parsed[1..]) {
            assert_eq!(want, got);
        }
    }

    #[test]
    fn write_creates_dirs() {
        let dir = std::env::temp_dir().join("www_cim_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/out.csv");
        let mut c = Csv::new(vec!["a"]);
        c.row(vec!["1"]).unwrap();
        c.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn arity_mismatch_is_a_typed_error() {
        let mut c = Csv::new(vec!["a", "b"]);
        let err = c.row(vec!["1"]).unwrap_err();
        assert_eq!(err, RowArityError { expected: 2, got: 1 });
        assert!(err.to_string().contains("arity mismatch"));
        // the malformed row is not recorded
        assert_eq!(c.n_rows(), 0);
        // and a good row still goes through afterwards
        c.row(vec!["1", "2"]).unwrap();
        assert_eq!(c.n_rows(), 1);
    }

    #[test]
    fn arity_error_converts_into_anyhow() {
        fn emit() -> anyhow::Result<()> {
            let mut c = Csv::new(vec!["a", "b"]);
            c.row(vec!["only-one"])?;
            Ok(())
        }
        let err = emit().unwrap_err();
        assert!(format!("{err:#}").contains("arity mismatch"));
    }
}
