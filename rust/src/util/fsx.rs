//! Atomic artifact writes: unique temp file + rename.
//!
//! Every persistent artifact (cache files, orchestrator manifests,
//! merged sweep JSON, query CSVs) must hit disk atomically so a crash
//! mid-write can never leave a half-written file that poisons a later
//! load or `--resume`. This factors out the idiom `sweep::persist`
//! established — write `<name>.<pid>.tmp` in the destination
//! directory, then `rename` into place — and threads it through the
//! [`super::faults`] layer so chaos tests can tear or fail the write
//! deterministically. Lint rule R8 rejects bare `fs::write` in the
//! persistent-artifact scope and points here.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::faults::{self, FaultAction};

/// Temp-file sibling for `path`: `<file name>.<pid>.tmp` in the same
/// directory, so the final `rename` never crosses a filesystem.
fn tmp_path(path: &Path) -> PathBuf {
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
    let name = match name {
        Some(n) => n,
        None => "artifact".to_string(),
    };
    path.with_file_name(format!("{name}.{}.tmp", std::process::id()))
}

/// Write `contents` to `path` atomically under the generic
/// `fsx.write` / `fsx.rename` fault points.
pub fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    write_atomic_named(path, contents, "fsx.write", "fsx.rename")
}

/// Write `contents` to `path` atomically, declaring caller-chosen
/// fault points (so e.g. the sweep cache arms `persist.write` /
/// `persist.rename` independently of other artifacts). Creates parent
/// directories. A `Fail` on the rename point leaves the temp file
/// behind — exactly the debris a crash between write and rename
/// would leave.
pub fn write_atomic_named(
    path: &Path,
    contents: &str,
    write_point: &str,
    rename_point: &str,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating directory {}", parent.display()))?;
        }
    }
    let tmp = tmp_path(path);
    let payload = match faults::check(write_point) {
        FaultAction::Fail => {
            bail!("injected fault: {write_point} failing write of {}", path.display())
        }
        FaultAction::Torn => &contents.as_bytes()[..contents.len() / 2],
        FaultAction::None => contents.as_bytes(),
    };
    fs::write(&tmp, payload).with_context(|| format!("writing {}", tmp.display()))?;
    if faults::check(rename_point) == FaultAction::Fail {
        bail!(
            "injected fault: {rename_point} failing rename of {} into place",
            path.display()
        );
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "www-cim-fsx-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_atomic_round_trips() {
        let dir = tmp_dir("round-trip");
        let path = dir.join("artifact.json");
        write_atomic(&path, "{\"ok\":true}\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "{\"ok\":true}\n");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_atomic_creates_parent_directories() {
        let dir = tmp_dir("parents");
        let path = dir.join("deep/nested/out.csv");
        write_atomic(&path, "a,b\n1,2\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "a,b\n1,2\n");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_atomic_leaves_no_temp_debris() {
        let dir = tmp_dir("no-debris");
        let path = dir.join("artifact.txt");
        write_atomic(&path, "payload").unwrap();
        let mut names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names, vec!["artifact.txt".to_string()]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn overwrites_are_atomic_replacements() {
        let dir = tmp_dir("overwrite");
        let path = dir.join("artifact.txt");
        write_atomic(&path, "first").unwrap();
        write_atomic(&path, "second").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "second");
        let _ = fs::remove_dir_all(&dir);
    }
}
