//! Utility substrates built in-tree because the offline toolchain only
//! carries the `xla` dependency closure (see DESIGN.md §Substitutions).
//!
//! * [`rng`] — SplitMix64 deterministic PRNG (rand replacement).
//! * [`stats`] — summary statistics used by the experiment harnesses.
//! * [`table`] — ASCII table rendering for paper-style output.
//! * [`csv`] — CSV writers for `results/`.
//! * [`json`] — minimal JSON reader (serde_json replacement) for the
//!   shard-merge tool.
//! * [`check`] — mini property-testing harness (proptest replacement).
//! * [`faults`] — deterministic fault injection (`REPRO_FAULTS`) for
//!   chaos tests; a no-op branch when unarmed.
//! * [`fsx`] — atomic artifact writes (unique temp + rename), wired
//!   through the fault points.
//! * [`hash`] — stable FNV-1a hashing for cross-process fingerprints.
//! * [`cli`] — subcommand/flag parser (clap replacement).
//! * [`pool`] — scoped worker pool (tokio/rayon replacement).
//! * [`bench`] — timing harness used by `cargo bench` targets
//!   (criterion replacement).

pub mod bench;
pub mod check;
pub mod cli;
pub mod csv;
pub mod faults;
pub mod fsx;
pub mod hash;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;
