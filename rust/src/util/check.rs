//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Provides the subset this repo needs: seeded random generation of
//! structured inputs, a configurable number of cases, and clear failure
//! reporting including the seed to reproduce. Greedy scalar shrinking is
//! applied to `Vec<u64>`-encoded inputs (each failing component is
//! bisected toward its minimum while the property still fails).
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use www_cim::util::check::{check, Config};
//! check(Config::default().cases(64), "add commutes", |rng| {
//!     let (a, b) = (rng.gen_range(0, 1000), rng.gen_range(0, 1000));
//!     if a + b != b + a { return Err(format!("{a}+{b}")); }
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // WWW_CHECK_CASES / WWW_SEED allow widening runs without code edits.
        let cases = std::env::var("WWW_CHECK_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        let seed = std::env::var("WWW_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC1A0_5EED);
        Config { cases, seed }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` against `cfg.cases` seeded RNG streams; panic with the
/// case index + seed + message on the first failure.
pub fn check<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            // lint: allow(R4): aborting with the failing seed is this property harness's contract
            panic!(
                "property '{name}' failed at case {case}/{} (WWW_SEED={} reproduces): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Run a property over explicitly-encoded `Vec<u64>` inputs, with greedy
/// per-component shrinking on failure. `gen` draws an input; `prop`
/// returns `Err` on failure.
pub fn check_shrink<G, F>(cfg: Config, name: &str, mut gen: G, mut prop: F)
where
    G: FnMut(&mut Rng) -> Vec<u64>,
    F: FnMut(&[u64]) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let (shrunk, msg) = shrink(&input, &mut prop, first_msg);
            // lint: allow(R4): aborting with the shrunk counterexample is this property harness's contract
            panic!(
                "property '{name}' failed at case {case} (WWW_SEED={} reproduces)\n  \
                 original input: {input:?}\n  shrunk input:   {shrunk:?}\n  error: {msg}",
                cfg.seed
            );
        }
    }
}

/// Greedily bisect each component toward 0 while the property keeps
/// failing; returns the minimized input and its failure message.
fn shrink<F>(input: &[u64], prop: &mut F, mut msg: String) -> (Vec<u64>, String)
where
    F: FnMut(&[u64]) -> Result<(), String>,
{
    let mut cur = input.to_vec();
    let mut progress = true;
    while progress {
        progress = false;
        for i in 0..cur.len() {
            let mut lo = 0u64;
            let mut hi = cur[i];
            // find the smallest value of component i that still fails
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                let mut cand = cur.clone();
                cand[i] = mid;
                match prop(&cand) {
                    Err(e) => {
                        hi = mid;
                        msg = e;
                    }
                    Ok(()) => lo = mid + 1,
                }
            }
            if hi < cur[i] {
                cur[i] = hi;
                progress = true;
            }
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(32), "u64 add is monotone", |rng| {
            let a = rng.gen_range(0, 1 << 20);
            let b = rng.gen_range(0, 1 << 20);
            if a + b >= a {
                Ok(())
            } else {
                Err("overflow".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        check(Config::default().cases(4), "always fails", |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_minimizes() {
        // Property: all components < 10. Failure is minimized to exactly 10.
        let input = vec![500u64, 3, 77];
        let mut prop = |xs: &[u64]| {
            if xs.iter().all(|&x| x < 10) {
                Ok(())
            } else {
                Err(format!("{xs:?} has component >= 10"))
            }
        };
        let (shrunk, _) = shrink(&input, &mut prop, "seed".into());
        assert_eq!(shrunk, vec![0, 0, 10]); // earlier components zeroed first, last pinned at the bound
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn check_shrink_reports_minimized() {
        check_shrink(
            Config::default().cases(8),
            "component bound",
            |rng| vec![rng.gen_range(0, 1000), rng.gen_range(0, 1000)],
            |xs| {
                if xs[0] < 900 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }
}
