//! ASCII table rendering — the experiment regenerators print the paper's
//! tables/series in this format (and mirror them to CSV via [`crate::util::csv`]).

/// A simple left/right-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column auto-sizing. Numeric-looking cells are
    /// right-aligned, text cells left-aligned.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let numeric: Vec<bool> = (0..ncols)
            .map(|i| {
                !self.rows.is_empty()
                    && self.rows.iter().all(|r| looks_numeric(&r[i]))
            })
            .collect();

        let mut out = String::new();
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+\n";
        out.push_str(&sep);
        out.push_str(&render_row(&self.header, &widths, &vec![false; ncols]));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&render_row(row, &widths, &numeric));
        }
        out.push_str(&sep);
        out
    }
}

fn looks_numeric(s: &str) -> bool {
    !s.is_empty() && s.trim_end_matches(['x', '%']).trim().parse::<f64>().is_ok()
}

fn render_row(cells: &[String], widths: &[usize], right: &[bool]) -> String {
    let mut line = String::new();
    for ((cell, &w), &r) in cells.iter().zip(widths).zip(right) {
        if r {
            line.push_str(&format!("| {cell:>w$} "));
        } else {
            line.push_str(&format!("| {cell:<w$} "));
        }
    }
    line.push_str("|\n");
    line
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["alpha", "1.5"]).row(vec!["bb", "22"]);
        let s = t.render();
        assert!(s.contains("| name "));
        assert!(s.contains("| alpha "));
        // numeric column right-aligned
        assert!(s.contains("|   1.5 |") || s.contains("| 1.5 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn numeric_detection() {
        assert!(looks_numeric("1.25"));
        assert!(looks_numeric("3.4x"));
        assert!(looks_numeric("85%"));
        assert!(!looks_numeric("BERT-Large"));
        assert!(!looks_numeric(""));
    }

    #[test]
    fn widths_fit_longest_cell() {
        let mut t = Table::new(vec!["h"]);
        t.row(vec!["a-much-longer-cell"]);
        let s = t.render();
        for line in s.lines().filter(|l| l.starts_with('|')) {
            assert_eq!(line.len(), "| a-much-longer-cell |".len());
        }
    }

    #[test]
    fn counts() {
        let mut t = Table::new(vec!["a"]);
        assert!(t.is_empty());
        t.row(vec!["1"]);
        assert_eq!(t.n_rows(), 1);
    }
}
