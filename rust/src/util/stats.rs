//! Summary statistics for experiment reporting.
//!
//! The paper reports averages and standard deviations of per-GEMM
//! *changes* (speedups) in its error-bar figures (Figs 7 and 12); this
//! module provides those plus the usual percentiles/geomean used in the
//! benchmark harness.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    var.sqrt()
}

/// Geometric mean of strictly-positive samples (non-positive samples are
/// skipped — they would make the geomean undefined).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Median (interpolated for even-length input).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp orders NaN after +inf instead of panicking: a NaN
    // sample skews the tail percentile rather than aborting a report.
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Min/max/mean/std/median summary of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std_dev: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        Summary {
            n: xs.len(),
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean: mean(xs),
            std_dev: std_dev(xs),
            median: median(xs),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} σ={:.4} min={:.4} p50={:.4} max={:.4}",
            self.n, self.mean, self.std_dev, self.min, self.median, self.max
        )
    }
}

/// Element-wise ratio `a[i]/b[i]` — the "change" series of Figs 7/12
/// (CiM metric over baseline metric). Pairs with a non-positive
/// denominator are skipped.
pub fn ratios(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len(), "ratio series length mismatch");
    a.iter()
        .zip(b)
        .filter(|(_, &den)| den > 0.0)
        .map(|(&num, &den)| num / den)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_skips_nonpositive() {
        assert!((geomean(&[0.0, 2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 90.0), 90.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn ratios_skip_zero_denominator() {
        let r = ratios(&[1.0, 2.0, 3.0], &[2.0, 0.0, 6.0]);
        assert_eq!(r, vec![0.5, 0.5]);
    }
}
