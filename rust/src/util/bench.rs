//! Timing harness used by the `cargo bench` targets (criterion is
//! unavailable offline; the bench targets set `harness = false` and call
//! into this module).
//!
//! Methodology: warmup runs, then `samples` timed runs of the closure;
//! report mean / σ / min, and optionally a derived throughput. A
//! `black_box` equivalent prevents the optimizer from deleting work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Re-export of `std::hint::black_box` under the criterion-style name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
    /// items per iteration, for throughput reporting (0 = none)
    pub items_per_iter: u64,
}

impl Measurement {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }

    pub fn std_dev(&self) -> Duration {
        if self.samples.len() < 2 {
            return Duration::ZERO;
        }
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        Duration::from_secs_f64(var.sqrt())
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} mean {:>12?}  σ {:>10?}  min {:>12?}  n={}",
            self.name,
            self.mean(),
            self.std_dev(),
            self.min(),
            self.samples.len()
        );
        if self.items_per_iter > 0 {
            let per_sec = self.items_per_iter as f64 / self.mean().as_secs_f64();
            s.push_str(&format!("  ({per_sec:.0} items/s)"));
        }
        s
    }

    /// Machine-readable form (`repro bench --json`): one object per
    /// case with the iteration count and nanosecond timings. Callers
    /// may append case-specific keys (e.g. cache stats) to the
    /// returned object before encoding.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            ("iters".to_string(), Json::Num(self.samples.len() as f64)),
            (
                "ns_per_iter".to_string(),
                Json::Num(self.mean().as_nanos() as f64),
            ),
            ("min_ns".to_string(), Json::Num(self.min().as_nanos() as f64)),
            (
                "stddev_ns".to_string(),
                Json::Num(self.std_dev().as_nanos() as f64),
            ),
            (
                "items_per_iter".to_string(),
                Json::Num(self.items_per_iter as f64),
            ),
        ])
    }
}

/// Bench runner: collects measurements, prints a criterion-like report.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    measurements: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        // WWW_BENCH_SAMPLES / WWW_BENCH_WARMUP tune without rebuilds;
        // keep defaults small enough that `cargo bench` finishes quickly.
        Bencher {
            warmup: env_usize("WWW_BENCH_WARMUP", 2),
            samples: env_usize("WWW_BENCH_SAMPLES", 10),
            measurements: Vec::new(),
        }
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` (its return value is black-boxed) and record under `name`.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.bench_with_items(name, 0, &mut f)
    }

    /// Like [`Bencher::bench`] but also reports `items`/iteration
    /// throughput.
    pub fn bench_with_items<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items: u64,
        f: &mut F,
    ) -> &Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        let m = Measurement {
            name: name.to_string(),
            samples,
            items_per_iter: items,
        };
        println!("{}", m.report());
        self.measurements.push(m);
        // lint: allow(R4): the push on the preceding line guarantees the vec is non-empty
        self.measurements.last().expect("just pushed")
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Final summary block, printed by each bench main.
    pub fn finish(&self, suite: &str) {
        println!("\n== bench suite '{suite}': {} measurements ==", self.measurements.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_records() {
        let mut b = Bencher {
            warmup: 1,
            samples: 3,
            measurements: Vec::new(),
        };
        b.bench("noop", || 42);
        assert_eq!(b.measurements().len(), 1);
        assert_eq!(b.measurements()[0].samples.len(), 3);
    }

    #[test]
    fn mean_min_ordering() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![
                Duration::from_micros(10),
                Duration::from_micros(20),
                Duration::from_micros(30),
            ],
            items_per_iter: 0,
        };
        assert_eq!(m.min(), Duration::from_micros(10));
        assert_eq!(m.mean(), Duration::from_micros(20));
        assert!(m.std_dev() > Duration::ZERO);
    }

    #[test]
    fn json_form_carries_the_timing_fields() {
        let m = Measurement {
            name: "case".into(),
            samples: vec![Duration::from_micros(10), Duration::from_micros(30)],
            items_per_iter: 6,
        };
        let v = m.to_json();
        assert_eq!(v.get("name").and_then(Json::as_str), Some("case"));
        assert_eq!(v.get("iters").and_then(Json::as_u64), Some(2));
        assert_eq!(v.get("ns_per_iter").and_then(Json::as_u64), Some(20_000));
        assert_eq!(v.get("min_ns").and_then(Json::as_u64), Some(10_000));
        assert_eq!(v.get("items_per_iter").and_then(Json::as_u64), Some(6));
        // The object is open for extension (cache stats etc.).
        let Json::Obj(mut fields) = v else { panic!("object expected") };
        fields.push(("cache".to_string(), Json::Null));
        assert!(Json::Obj(fields).encode_compact().contains("\"cache\":null"));
    }

    #[test]
    fn throughput_in_report() {
        let m = Measurement {
            name: "t".into(),
            samples: vec![Duration::from_millis(1)],
            items_per_iter: 1000,
        };
        assert!(m.report().contains("items/s"));
    }
}
