//! Deterministic fault injection for robustness tests and chaos e2e.
//!
//! Production code declares *named fault points* at its failure-prone
//! seams (`faults::check("persist.rename")`); a run arms them through
//! the `REPRO_FAULTS` environment variable. Unarmed — the normal case —
//! a check is one branch on a lazily initialised `None`: no
//! allocation, no atomics, no syscalls, so the hot path is untouched
//! and an unarmed build's output is byte-identical to a build without
//! the layer at all. Armed, every crash/torn-write/overload scenario
//! becomes a reproducible test instead of a hope.
//!
//! ## Spec grammar
//!
//! `REPRO_FAULTS` is a comma-separated list of `point=mode[@n]`
//! clauses:
//!
//! ```text
//! REPRO_FAULTS='persist.rename=fail@1,serve.accept=delay_ms:250@2'
//! ```
//!
//! * `fail` — the point reports [`FaultAction::Fail`]; the caller
//!   returns an injected error (a simulated crash or syscall failure).
//! * `torn` — the point reports [`FaultAction::Torn`]; write-shaped
//!   callers persist only a prefix of their payload (a torn write).
//! * `delay_ms:<d>` — the check sleeps `d` milliseconds in place (a
//!   simulated stall); the caller proceeds normally.
//! * `@n` — fire on the *n*-th hit of the point only (1-based,
//!   default 1). Hits keep counting after the firing, so counters
//!   stay meaningful.
//!
//! A malformed spec disarms the layer with a loud stderr note instead
//! of failing the run — the injection layer must never be able to
//! crash a run on its own.
//!
//! Every armed clause counts its hits and firings; [`snapshot`]
//! reports them aggregated per point, sorted by point name (so
//! rendering is deterministic — the serve daemon's `stats` op exposes
//! the snapshot for CI assertions).
//!
//! ## Known fault points
//!
//! | point            | site                                        |
//! |------------------|---------------------------------------------|
//! | `persist.write`  | cache temp-file write (`sweep::persist`)    |
//! | `persist.rename` | cache rename-into-place (`sweep::persist`)  |
//! | `fsx.write`      | other atomic artifact writes ([`super::fsx`]) |
//! | `fsx.rename`     | their rename-into-place                     |
//! | `serve.accept`   | accepted connection → forced busy rejection |
//! | `shard.spawn`    | orchestrator shard spawn (`scenario::orchestrate`) |

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// What an armed fault point tells its caller to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Not armed, or not this hit: proceed normally.
    None,
    /// Fail the operation with an injected error.
    Fail,
    /// Truncate the write — the caller persists a torn payload.
    Torn,
}

/// Fault mode parsed from one spec clause.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Fail,
    Torn,
    DelayMs(u64),
}

/// One armed clause with its live counters.
#[derive(Debug)]
struct Point {
    name: String,
    mode: Mode,
    /// 1-based hit index the clause fires on.
    at: u64,
    hits: AtomicU64,
    fired: AtomicU64,
}

/// Aggregated hit/fire counts for one point name ([`snapshot`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultCount {
    pub point: String,
    pub hits: u64,
    pub fired: u64,
}

/// Parse one `point=mode[@n]` clause.
fn parse_clause(clause: &str) -> Result<Point, String> {
    let (name, rest) = clause
        .split_once('=')
        .ok_or_else(|| format!("clause {clause:?} wants point=mode[@n]"))?;
    let name = name.trim();
    if name.is_empty() {
        return Err(format!("clause {clause:?} has an empty point name"));
    }
    let (mode_text, at) = match rest.rsplit_once('@') {
        Some((m, n)) => {
            let at = n
                .parse::<u64>()
                .map_err(|_| format!("bad hit index {n:?} in {clause:?}"))?;
            if at == 0 {
                return Err(format!("hit index in {clause:?} is 1-based"));
            }
            (m, at)
        }
        None => (rest, 1),
    };
    let mode = if mode_text == "fail" {
        Mode::Fail
    } else if mode_text == "torn" {
        Mode::Torn
    } else if let Some(d) = mode_text.strip_prefix("delay_ms:") {
        Mode::DelayMs(
            d.parse::<u64>()
                .map_err(|_| format!("bad delay {d:?} in {clause:?}"))?,
        )
    } else {
        return Err(format!(
            "unknown mode {mode_text:?} in {clause:?} (want fail, torn or delay_ms:<d>)"
        ));
    };
    Ok(Point {
        name: name.to_string(),
        mode,
        at,
        hits: AtomicU64::new(0),
        fired: AtomicU64::new(0),
    })
}

/// Parse a whole spec into clauses sorted by point name. Several
/// clauses may share one point (e.g. a delay on hit 1, a failure on
/// hit 3); each keeps its own counters and [`snapshot`] aggregates.
fn parse_spec(spec: &str) -> Result<Vec<Point>, String> {
    let mut points = Vec::new();
    for clause in spec.split(',') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        points.push(parse_clause(clause)?);
    }
    points.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(points)
}

/// The process-wide registry: parsed once from `REPRO_FAULTS` on first
/// check. `None` = unarmed.
static REGISTRY: OnceLock<Option<Vec<Point>>> = OnceLock::new();

fn registry() -> Option<&'static Vec<Point>> {
    REGISTRY
        .get_or_init(|| {
            let spec = match std::env::var("REPRO_FAULTS") {
                Ok(s) => s,
                Err(_) => return None,
            };
            match parse_spec(&spec) {
                Ok(points) if points.is_empty() => None,
                Ok(points) => {
                    eprintln!("[faults] armed: {spec}");
                    Some(points)
                }
                Err(why) => {
                    eprintln!("[faults] ignoring malformed REPRO_FAULTS: {why}");
                    None
                }
            }
        })
        .as_ref()
}

/// True when any fault point is armed.
pub fn armed() -> bool {
    registry().is_some()
}

/// Declare a fault point. Unarmed this is a no-op branch. Armed, it
/// counts the hit, serves `delay_ms` stalls in place, and returns
/// `Fail`/`Torn` for the caller to honour (`Fail` wins when several
/// clauses fire on the same hit).
pub fn check(point: &str) -> FaultAction {
    match registry() {
        Some(points) => check_in(points, point),
        None => FaultAction::None,
    }
}

fn check_in(points: &[Point], point: &str) -> FaultAction {
    let mut action = FaultAction::None;
    for p in points.iter().filter(|p| p.name == point) {
        let hit = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
        if hit != p.at {
            continue;
        }
        p.fired.fetch_add(1, Ordering::Relaxed);
        match p.mode {
            Mode::DelayMs(ms) => {
                eprintln!("[faults] {point}: delaying {ms} ms (hit {hit})");
                std::thread::sleep(Duration::from_millis(ms));
            }
            Mode::Fail => {
                eprintln!("[faults] {point}: injecting failure (hit {hit})");
                action = FaultAction::Fail;
            }
            Mode::Torn => {
                eprintln!("[faults] {point}: tearing write (hit {hit})");
                if action == FaultAction::None {
                    action = FaultAction::Torn;
                }
            }
        }
    }
    action
}

/// Hit/fire counts aggregated per point name, sorted by name (the
/// clause list is kept sorted, so aggregation is a single pass and the
/// order is deterministic). Empty when unarmed.
pub fn snapshot() -> Vec<FaultCount> {
    let Some(points) = registry() else {
        return Vec::new();
    };
    let mut out: Vec<FaultCount> = Vec::new();
    for p in points {
        let hits = p.hits.load(Ordering::Relaxed);
        let fired = p.fired.load(Ordering::Relaxed);
        match out.last_mut() {
            Some(last) if last.point == p.name => {
                last.hits += hits;
                last.fired += fired;
            }
            Some(_) | None => out.push(FaultCount {
                point: p.name.clone(),
                hits,
                fired,
            }),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test here sets REPRO_FAULTS — the registry is
    // process-global and the whole unit-test binary shares it. The
    // env-armed path is exercised end-to-end by the CI chaos step.

    #[test]
    fn clauses_parse_modes_and_hit_indices() {
        let p = parse_clause("persist.rename=fail@3").unwrap();
        assert_eq!((p.name.as_str(), p.mode, p.at), ("persist.rename", Mode::Fail, 3));
        let p = parse_clause("serve.accept=torn").unwrap();
        assert_eq!((p.mode, p.at), (Mode::Torn, 1), "hit index defaults to 1");
        let p = parse_clause("x=delay_ms:250@2").unwrap();
        assert_eq!((p.mode, p.at), (Mode::DelayMs(250), 2));
    }

    #[test]
    fn malformed_clauses_are_rejected_with_context() {
        for (spec, needle) in [
            ("nomode", "point=mode"),
            ("=fail", "empty point name"),
            ("x=explode", "unknown mode"),
            ("x=fail@0", "1-based"),
            ("x=fail@many", "bad hit index"),
            ("x=delay_ms:soon", "bad delay"),
        ] {
            let err = parse_spec(spec).unwrap_err();
            assert!(err.contains(needle), "{spec:?} -> {err:?}");
        }
    }

    #[test]
    fn spec_parses_multiple_clauses_sorted_and_skips_blanks() {
        let points = parse_spec("b=fail, a=torn@2, ,c=delay_ms:1").unwrap();
        let names: Vec<&str> = points.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        assert!(parse_spec("").unwrap().is_empty());
    }

    #[test]
    fn clause_fires_on_its_hit_only_and_counts_every_hit() {
        let points = parse_spec("pt=fail@2").unwrap();
        assert_eq!(check_in(&points, "pt"), FaultAction::None);
        assert_eq!(check_in(&points, "pt"), FaultAction::Fail);
        assert_eq!(check_in(&points, "pt"), FaultAction::None);
        assert_eq!(check_in(&points, "other"), FaultAction::None);
        assert_eq!(points[0].hits.load(Ordering::Relaxed), 3);
        assert_eq!(points[0].fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fail_wins_over_torn_on_the_same_hit() {
        let points = parse_spec("pt=torn@1,pt=fail@1").unwrap();
        assert_eq!(check_in(&points, "pt"), FaultAction::Fail);
    }

    #[test]
    fn torn_fires_as_torn() {
        let points = parse_spec("pt=torn@1").unwrap();
        assert_eq!(check_in(&points, "pt"), FaultAction::Torn);
    }
}
