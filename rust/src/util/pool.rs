//! Scoped worker pool (tokio/rayon are unavailable offline).
//!
//! The coordinator fans evaluation jobs (workload × primitive × level
//! grid cells) out over OS threads. Jobs are CPU-bound and independent,
//! so a shared atomic cursor over the job list (self-balancing: fast
//! workers simply take more items) is all that is needed.
//!
//! For long-lived components ([`crate::serve`]) the module also
//! provides [`BoundedQueue`]: a fixed-capacity MPMC hand-off between an
//! acceptor and a persistent worker pool, with non-blocking rejection
//! on overflow (backpressure instead of unbounded buffering).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Number of worker threads to use by default: `WWW_THREADS` env var or
/// available parallelism (min 1).
pub fn default_threads() -> usize {
    std::env::var("WWW_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (shared across workers by reference).
pub fn map_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                // lint: allow(R4): a poisoned slot means a sibling worker already panicked; propagating is correct
                *results[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned") // lint: allow(R4): the scope above joined every worker; both failures are harness bugs
                .expect("worker skipped an item")
        })
        .collect()
}

/// Fixed-capacity multi-producer/multi-consumer queue for persistent
/// worker pools. Pushes never block: a full (or closed) queue rejects
/// the item back to the caller, which is the backpressure signal the
/// serve daemon turns into an explicit busy response instead of
/// queueing without bound. Pops block until an item arrives or the
/// queue is closed and drained.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    available: Condvar,
}

#[derive(Debug)]
struct QueueState<T> {
    items: VecDeque<T>,
    capacity: usize,
    closed: bool,
}

/// Lock the queue state — the single place this type touches a Mutex.
fn queue_locked<T>(m: &Mutex<QueueState<T>>) -> std::sync::MutexGuard<'_, QueueState<T>> {
    // lint: allow(R4): a poisoned queue means a worker panicked mid-pop; propagating is correct
    m.lock().expect("bounded queue poisoned")
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `capacity` items (minimum 1).
    pub fn new(capacity: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                capacity: capacity.max(1),
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    /// Enqueue without blocking. Returns the item back when the queue
    /// is full or closed — the caller decides what rejection means.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut s = queue_locked(&self.state);
        if s.closed || s.items.len() >= s.capacity {
            return Err(item);
        }
        s.items.push_back(item);
        drop(s);
        self.available.notify_one();
        Ok(())
    }

    /// Dequeue, blocking until an item is available. Returns `None`
    /// once the queue is closed *and* drained (worker shutdown signal).
    pub fn pop(&self) -> Option<T> {
        let mut s = queue_locked(&self.state);
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            // lint: allow(R4): same poisoning contract as queue_locked above
            s = self.available.wait(s).expect("bounded queue poisoned");
        }
    }

    /// Close the queue: pending items still drain, new pushes are
    /// rejected, and blocked `pop`s wake with `None` once empty.
    pub fn close(&self) {
        queue_locked(&self.state).closed = true;
        self.available.notify_all();
    }

    /// Items currently waiting.
    pub fn len(&self) -> usize {
        queue_locked(&self.state).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// `map_parallel` with indices — handy when the closure needs to know
/// which grid cell it is computing.
pub fn map_parallel_indexed<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let indexed: Vec<usize> = (0..items.len()).collect();
    map_parallel(&indexed, threads, |&i| f(i, &items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = map_parallel(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn each_item_processed_once() {
        let items: Vec<usize> = (0..500).collect();
        let counter = AtomicU64::new(0);
        let _ = map_parallel(&items, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(map_parallel(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = map_parallel(&items, 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn indexed_variant() {
        let items = vec![10, 20, 30];
        let out = map_parallel_indexed(&items, 2, |i, &x| i as i32 + x);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn bounded_queue_rejects_overflow_and_preserves_fifo() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "full queue hands the item back");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok(), "pop frees a slot");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_queue_close_drains_then_wakes_poppers() {
        use std::sync::Arc;
        let q = Arc::new(BoundedQueue::new(4));
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(8), "closed queue rejects pushes");
        assert_eq!(q.pop(), Some(7), "pending items still drain");
        assert_eq!(q.pop(), None, "closed + drained = shutdown signal");
        // A popper blocked on an empty queue wakes with None on close.
        let q2 = Arc::new(BoundedQueue::<u32>::new(1));
        let popper = {
            let q2 = Arc::clone(&q2);
            std::thread::spawn(move || q2.pop())
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        q2.close();
        assert_eq!(popper.join().unwrap(), None);
    }

    #[test]
    fn bounded_queue_hand_off_across_threads() {
        use std::sync::Arc;
        let q = Arc::new(BoundedQueue::new(8));
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            })
        };
        for i in 0..100 {
            // Spin on overflow: the consumer drains concurrently.
            let mut item = i;
            while let Err(back) = q.try_push(item) {
                item = back;
                std::thread::yield_now();
            }
        }
        q.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>(), "FIFO order preserved");
    }
}
