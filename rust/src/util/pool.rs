//! Scoped worker pool (tokio/rayon are unavailable offline).
//!
//! The coordinator fans evaluation jobs (workload × primitive × level
//! grid cells) out over OS threads. Jobs are CPU-bound and independent,
//! so a shared atomic cursor over the job list (self-balancing: fast
//! workers simply take more items) is all that is needed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: `WWW_THREADS` env var or
/// available parallelism (min 1).
pub fn default_threads() -> usize {
    std::env::var("WWW_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (shared across workers by reference).
pub fn map_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                // lint: allow(R4): a poisoned slot means a sibling worker already panicked; propagating is correct
                *results[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned") // lint: allow(R4): the scope above joined every worker; both failures are harness bugs
                .expect("worker skipped an item")
        })
        .collect()
}

/// `map_parallel` with indices — handy when the closure needs to know
/// which grid cell it is computing.
pub fn map_parallel_indexed<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let indexed: Vec<usize> = (0..items.len()).collect();
    map_parallel(&indexed, threads, |&i| f(i, &items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = map_parallel(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn each_item_processed_once() {
        let items: Vec<usize> = (0..500).collect();
        let counter = AtomicU64::new(0);
        let _ = map_parallel(&items, 4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(map_parallel(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = map_parallel(&items, 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn indexed_variant() {
        let items = vec![10, 20, 30];
        let out = map_parallel_indexed(&items, 2, |i, &x| i as i32 + x);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
