//! Stable, dependency-free hashing.
//!
//! `std::collections::hash_map::DefaultHasher` is deliberately avoided
//! for anything that crosses a process boundary: its algorithm is
//! unspecified across Rust releases, while sweep fingerprints and
//! mapping fingerprints must compare equal across binaries built on
//! different hosts.

/// FNV-1a 64-bit over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a reference values (offset basis for "", published
        // digest for "a").
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        assert_ne!(fnv1a(b"priority"), fnv1a(b"priority+dup"));
        assert_ne!(fnv1a(b"x"), fnv1a(b"y"));
    }
}
