//! Minimal JSON reader *and* writer (serde_json is unavailable
//! offline).
//!
//! The sweep subsystem emits JSON with hand-rolled encoders
//! ([`crate::sweep::output`], [`crate::sweep::shard`]); this is the
//! matching reader, used by `repro merge` to consume per-shard summary
//! files and by the scenario API ([`crate::scenario`]) to load run
//! descriptions. It parses the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, literals) into a small [`Json`] tree
//! with typed accessors. Object keys keep their document order, and
//! [`Json::encode`] pretty-prints a tree back out *deterministically*
//! (same tree → same bytes), the property the scenario round-trip
//! tests pin.

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            chars: text.chars().collect(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            bail!("json: trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (None for missing keys and non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor: the number must be a non-negative integer
    /// small enough that the f64 carrier held it exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.007_199_254_740_992e15 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Single-line encoding (no indentation, no trailing newline) for
    /// newline-delimited protocols ([`crate::serve`]): one value per
    /// line, so embedded newlines must never appear outside string
    /// escapes. Same determinism contract as [`Json::encode`]: same
    /// tree → same bytes, and `Json::parse` inverts it exactly.
    pub fn encode_compact(&self) -> String {
        let mut out = String::new();
        self.encode_compact_into(&mut out);
        out
    }

    fn encode_compact_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&encode_number(*n)),
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.encode_compact_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.encode_compact_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Pretty-print with two-space indentation and a trailing newline.
    /// Deterministic: object keys are emitted in stored order, numbers
    /// via [`encode_number`], so encoding the same tree twice yields
    /// byte-identical text — and `Json::parse(&j.encode())` returns a
    /// tree equal to `j` (integers and shortest-round-trip floats
    /// survive exactly).
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.encode_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn encode_into(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&encode_number(*n)),
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    pad(out, indent + 1);
                    item.encode_into(out, indent + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    pad(out, indent + 1);
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\": ");
                    v.encode_into(out, indent + 1);
                    out.push_str(if i + 1 < fields.len() { ",\n" } else { "\n" });
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

/// Encode one number: integers (exactly representable in the f64
/// carrier) in plain decimal, everything else via Rust's shortest
/// round-trip float rendering.
fn encode_number(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() <= 9.007_199_254_740_992e15 {
        (n as i64).to_string()
    } else {
        format!("{n:?}")
    }
}

/// Escape a string for a JSON string literal (the encoder counterpart
/// of the reader's escape handling; also used by the sweep summary
/// writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Maximum container nesting. Malformed or hostile input (e.g. a
/// truncated shard file full of `[`) must surface as a parse error,
/// not a recursion-driven stack overflow.
const MAX_DEPTH: usize = 128;

struct Parser {
    chars: Vec<char>,
    pos: usize,
    depth: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect_char(&mut self, want: char) -> Result<()> {
        match self.bump() {
            Some(c) if c == want => Ok(()),
            Some(c) => bail!("json: expected {want:?}, found {c:?} at offset {}", self.pos - 1),
            None => bail!("json: expected {want:?}, found end of input"),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        for want in word.chars() {
            match self.bump() {
                Some(c) if c == want => {}
                _ => bail!("json: malformed literal (expected {word:?})"),
            }
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.nested(Self::object),
            Some('[') => self.nested(Self::array),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("json: unexpected character {c:?} at offset {}", self.pos),
            None => bail!("json: unexpected end of input"),
        }
    }

    fn nested(&mut self, f: fn(&mut Self) -> Result<Json>) -> Result<Json> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("json: nesting deeper than {MAX_DEPTH} levels");
        }
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_char('{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.bump();
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_char(':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(Json::Obj(fields)),
                Some(c) => bail!("json: expected ',' or '}}' in object, found {c:?}"),
                None => bail!("json: unterminated object"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_char('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.bump();
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(Json::Arr(items)),
                Some(c) => bail!("json: expected ',' or ']' in array, found {c:?}"),
                None => bail!("json: unterminated array"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_char('"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("json: unterminated string"),
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000C}'),
                    Some('n') => out.push('\n'),
                    Some('r') => out.push('\r'),
                    Some('t') => out.push('\t'),
                    Some('u') => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: a low surrogate must follow.
                            if self.bump() != Some('\\') || self.bump() != Some('u') {
                                bail!("json: unpaired high surrogate \\u{hi:04x}");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("json: invalid low surrogate \\u{lo:04x}");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            None => bail!("json: invalid unicode escape \\u{code:04x}"),
                        }
                    }
                    Some(c) => bail!("json: invalid escape \\{c}"),
                    None => bail!("json: unterminated escape"),
                },
                Some(c) => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => bail!("json: unterminated \\u escape"),
            };
            let d = match c.to_digit(16) {
                Some(d) => d,
                None => bail!("json: non-hex digit {c:?} in \\u escape"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some('-' | '+' | '.' | 'e' | 'E' | '0'..='9')
        ) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("json: malformed number {text:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn nested_document_with_accessors() {
        let doc = Json::parse(
            r#"{
                "name": "sweep",
                "points": 12,
                "shard": {"index": 0, "count": 2},
                "rows": [["a", "b"], []],
                "ok": true,
                "missing": null
            }"#,
        )
        .unwrap();
        assert_eq!(doc.get("name").and_then(Json::as_str), Some("sweep"));
        assert_eq!(doc.get("points").and_then(Json::as_u64), Some(12));
        let shard = doc.get("shard").unwrap();
        assert_eq!(shard.get("count").and_then(Json::as_u64), Some(2));
        let rows = doc.get("rows").and_then(Json::as_array).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_array().unwrap().len(), 2);
        assert_eq!(doc.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(doc.get("missing"), Some(&Json::Null));
        assert_eq!(doc.get("absent"), None);
    }

    #[test]
    fn string_escapes_round_trip_the_output_encoder() {
        // The shard/summary writers escape with output::json_escape;
        // this reader must invert it exactly.
        let doc = Json::parse(r#""a\"b\\c\n\tAé""#).unwrap();
        assert_eq!(doc.as_str(), Some("a\"b\\c\n\tA\u{e9}"));
    }

    #[test]
    fn surrogate_pairs() {
        let doc = Json::parse(r#""😀""#).unwrap();
        assert_eq!(doc.as_str(), Some("\u{1F600}"));
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn malformed_documents_error() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1 2", "\"", "{\"a\":1,}",
            "[1]]",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn encode_parse_round_trip_is_exact_and_deterministic() {
        let doc = Json::Obj(vec![
            ("name".to_string(), Json::Str("a\"b\\c\n".to_string())),
            ("n".to_string(), Json::Num(42.0)),
            ("x".to_string(), Json::Num(1.5)),
            ("tiny".to_string(), Json::Num(1e-12)),
            ("neg".to_string(), Json::Num(-7.0)),
            ("on".to_string(), Json::Bool(true)),
            ("off".to_string(), Json::Bool(false)),
            ("nothing".to_string(), Json::Null),
            ("empty_arr".to_string(), Json::Arr(vec![])),
            ("empty_obj".to_string(), Json::Obj(vec![])),
            (
                "arr".to_string(),
                Json::Arr(vec![
                    Json::Num(1.0),
                    Json::Obj(vec![("k".to_string(), Json::Str("v".to_string()))]),
                ]),
            ),
        ]);
        let text = doc.encode();
        assert!(text.ends_with('\n'));
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed, doc, "parse(encode(doc)) must be lossless");
        assert_eq!(reparsed.encode(), text, "re-encoding must be byte-identical");
        // Integers render without a fractional part.
        assert!(text.contains("\"n\": 42,"), "{text}");
        assert!(text.contains("\"neg\": -7,"), "{text}");
    }

    #[test]
    fn compact_encoding_is_one_line_and_round_trips() {
        let doc = Json::Obj(vec![
            ("op".to_string(), Json::Str("eval".to_string())),
            ("n".to_string(), Json::Num(42.0)),
            ("x".to_string(), Json::Num(1.5)),
            ("row".to_string(), Json::Str("a,b\nc".to_string())),
            ("arr".to_string(), Json::Arr(vec![Json::Num(1.0), Json::Null])),
            ("obj".to_string(), Json::Obj(vec![("k".to_string(), Json::Bool(true))])),
            ("empty".to_string(), Json::Arr(vec![])),
        ]);
        let line = doc.encode_compact();
        assert!(!line.contains('\n'), "one value per line: {line:?}");
        assert_eq!(Json::parse(&line).unwrap(), doc, "compact must be lossless");
        assert_eq!(
            line,
            r#"{"op":"eval","n":42,"x":1.5,"row":"a,b\nc","arr":[1,null],"obj":{"k":true},"empty":[]}"#
        );
        // Compact and pretty agree on content: re-encoding the parsed
        // compact line pretty-prints identically to the original tree.
        assert_eq!(Json::parse(&line).unwrap().encode(), doc.encode());
    }

    #[test]
    fn as_bool() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn as_u64_rejects_non_integers() {
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-2").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn deep_nesting_is_a_parse_error_not_a_stack_overflow() {
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(format!("{err:#}").contains("nesting"), "{err:#}");
        // ...while reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }
}
