//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 (Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
//! Generators", OOPSLA 2014) is used everywhere randomness is needed:
//! the synthetic GEMM dataset, the heuristic mapping search, and the
//! property-test harness. It is tiny, passes BigCrush when used as a
//! 64-bit generator, and — critically for reproducibility of the paper's
//! experiments — fully deterministic from a seed.

/// SplitMix64 PRNG. `Clone` so search states can be forked.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Seed from the `WWW_SEED` environment variable, falling back to a
    /// fixed default so test runs are reproducible by default.
    pub fn from_env(default: u64) -> Self {
        let seed = std::env::var("WWW_SEED")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(default);
        Rng::new(seed)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (empty ranges panic).
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        let span = hi - lo;
        // Lemire's nearly-divisionless bounded rejection sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.gen_range(0, n as u64) as usize
    }

    /// Uniformly choose an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Log-uniform integer in `[lo, hi]` — used for the synthetic GEMM
    /// dataset so small and large shapes are equally represented, as in
    /// the paper's 16..8192 sweep.
    pub fn log_uniform(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(0 < lo && lo <= hi);
        let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
        let v = (llo + self.next_f64() * (lhi - llo)).exp();
        (v.round() as u64).clamp(lo, hi)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v), "{v} out of [0,1)");
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.gen_range(5, 15);
            assert!((5..15).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should occur");
    }

    #[test]
    fn log_uniform_within_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.log_uniform(16, 8192);
            assert!((16..=8192).contains(&v));
        }
    }

    #[test]
    fn log_uniform_covers_decades() {
        // Small values must not be starved: that's the point of log sampling.
        let mut r = Rng::new(13);
        let (mut small, mut large) = (0, 0);
        for _ in 0..10_000 {
            let v = r.log_uniform(16, 8192);
            if v < 128 {
                small += 1;
            }
            if v >= 1024 {
                large += 1;
            }
        }
        assert!(small > 1_000, "small shapes starved: {small}");
        assert!(large > 1_000, "large shapes starved: {large}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}
