//! Hand-rolled command-line parsing (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [positional ...] [--flag] [--key value]`
//! with `--key=value` also accepted. Unknown-flag detection and simple
//! typed getters cover everything the `repro` CLI needs.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Marker value for boolean flags given without a value.
const PRESENT: &str = "\u{1}true";

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I, S>(argv: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = argv.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(stripped.to_string(), PRESENT.to_string());
                        }
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the current process's arguments.
    pub fn from_env() -> Self {
        Args::parse(std::env::args().skip(1))
    }

    /// Boolean flag: present (with or without a truthy value)?
    pub fn flag(&self, name: &str) -> bool {
        match self.flags.get(name) {
            None => false,
            Some(v) => v == PRESENT || matches!(v.as_str(), "true" | "1" | "yes"),
        }
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|v| {
            if v == PRESENT {
                "true"
            } else {
                v.as_str()
            }
        })
    }

    /// String option with default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option with default; panics with a helpful message on a
    /// malformed value (user error, not programmer error).
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                panic!("--{name}: cannot parse {v:?} as {}", std::any::type_name::<T>())
            }),
        }
    }

    /// All provided flag names (for unknown-flag validation).
    pub fn flag_names(&self) -> impl Iterator<Item = &str> {
        self.flags.keys().map(String::as_str)
    }

    /// Error message listing any flags outside `known`, or None if clean.
    pub fn unknown_flags(&self, known: &[&str]) -> Option<String> {
        let unknown: Vec<&str> = self
            .flag_names()
            .filter(|n| !known.contains(n))
            .collect();
        if unknown.is_empty() {
            None
        } else {
            Some(format!(
                "unknown flag(s): {}; known: {}",
                unknown.join(", "),
                known.join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("experiment fig9 extra");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig9", "extra"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("run --level smem --seed=42");
        assert_eq!(a.get("level"), Some("smem"));
        assert_eq!(a.get_parsed_or::<u64>("seed", 0), 42);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verbose --out file.csv");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("out"), Some("file.csv"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("run --fast --level rf");
        assert!(a.flag("fast"));
        assert_eq!(a.get("level"), Some("rf"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("level", "rf"), "rf");
        assert_eq!(a.get_parsed_or::<usize>("n", 10), 10);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("run --levle rf");
        let err = a.unknown_flags(&["level"]).unwrap();
        assert!(err.contains("levle"));
        assert!(parse("run --level rf").unknown_flags(&["level"]).is_none());
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_typed_flag_panics() {
        let a = parse("run --n abc");
        let _: usize = a.get_parsed_or("n", 0);
    }
}
