//! Hand-rolled command-line parsing (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [positional ...] [--flag] [--key value]`
//! with `--key=value` also accepted. Flags registered as
//! *optional-value* ([`Args::parse_with_optional`]) never consume the
//! following token — their value comes via `--flag=value` only — so
//! `repro run --cache fig2` keeps `fig2` positional instead of
//! silently swallowing it as the cache path. Unknown-flag detection
//! and simple typed getters cover everything the `repro` CLI needs.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Marker value for boolean flags given without a value.
const PRESENT: &str = "\u{1}true";

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]), with no
    /// optional-value flags.
    pub fn parse<I, S>(argv: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        Args::parse_with_optional(argv, &[])
    }

    /// Parse, treating every flag named in `optional_value` as
    /// optional-value: bare `--flag` records presence without touching
    /// the next token (which stays positional/subcommand), and an
    /// explicit value is given as `--flag=value` only.
    pub fn parse_with_optional<I, S>(argv: I, optional_value: &[&str]) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = argv.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if optional_value.contains(&stripped) {
                    args.flags.insert(stripped.to_string(), PRESENT.to_string());
                } else if matches!(iter.peek(), Some(next) if !next.starts_with("--")) {
                    // `--key value`: the next token is the value.
                    if let Some(v) = iter.next() {
                        args.flags.insert(stripped.to_string(), v);
                    }
                } else {
                    args.flags.insert(stripped.to_string(), PRESENT.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the current process's arguments.
    pub fn from_env() -> Self {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse the current process's arguments with optional-value flags.
    pub fn from_env_with_optional(optional_value: &[&str]) -> Self {
        Args::parse_with_optional(std::env::args().skip(1), optional_value)
    }

    /// Boolean flag: present (with or without a truthy value)?
    pub fn flag(&self, name: &str) -> bool {
        match self.flags.get(name) {
            None => false,
            Some(v) => v == PRESENT || matches!(v.as_str(), "true" | "1" | "yes"),
        }
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|v| {
            if v == PRESENT {
                "true"
            } else {
                v.as_str()
            }
        })
    }

    /// String option with default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option with default. A malformed value is a *user* error:
    /// it returns an error naming the flag and the accepted syntax
    /// (surfaced as a usage message, never a panic backtrace).
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(parsed) => Ok(parsed),
                Err(_) => bail!(
                    "--{name}: cannot parse {v:?} as {} \
                     (expected `--{name} <value>` or `--{name}=<value>`)",
                    std::any::type_name::<T>()
                ),
            },
        }
    }

    /// All provided flag names (for unknown-flag validation).
    pub fn flag_names(&self) -> impl Iterator<Item = &str> {
        self.flags.keys().map(String::as_str)
    }

    /// Error message listing any flags outside `known`, or None if clean.
    pub fn unknown_flags(&self, known: &[&str]) -> Option<String> {
        let unknown: Vec<&str> = self
            .flag_names()
            .filter(|n| !known.contains(n))
            .collect();
        if unknown.is_empty() {
            None
        } else {
            Some(format!(
                "unknown flag(s): {}; known: {}",
                unknown.join(", "),
                known.join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace())
    }

    fn parse_opt(s: &str, optional: &[&str]) -> Args {
        Args::parse_with_optional(s.split_whitespace(), optional)
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("experiment fig9 extra");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig9", "extra"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("run --level smem --seed=42");
        assert_eq!(a.get("level"), Some("smem"));
        assert_eq!(a.get_parsed_or::<u64>("seed", 0).unwrap(), 42);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verbose --out file.csv");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("out"), Some("file.csv"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("run --fast --level rf");
        assert!(a.flag("fast"));
        assert_eq!(a.get("level"), Some("rf"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("level", "rf"), "rf");
        assert_eq!(a.get_parsed_or::<usize>("n", 10).unwrap(), 10);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("run --levle rf");
        let err = a.unknown_flags(&["level"]).unwrap();
        assert!(err.contains("levle"));
        assert!(parse("run --level rf").unknown_flags(&["level"]).is_none());
    }

    #[test]
    fn malformed_typed_flag_is_a_user_error() {
        let a = parse("run --n abc");
        let err = a.get_parsed_or::<usize>("n", 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--n") && msg.contains("cannot parse"), "{msg}");
        assert!(msg.contains("--n=<value>"), "must show the syntax: {msg}");
    }

    #[test]
    fn optional_value_flag_never_swallows_a_positional() {
        // The `repro run --cache fig2` regression: fig2 must stay the
        // positional scenario name, --cache a bare presence flag.
        let a = parse_opt("run --cache fig2", &["cache"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["fig2"]);
        assert!(a.flag("cache"));
        assert_eq!(a.get("cache"), Some("true"));
        // An explicit value still comes through `--flag=value`...
        let a = parse_opt("run --cache=results/c.bin fig2", &["cache"]);
        assert_eq!(a.get("cache"), Some("results/c.bin"));
        assert_eq!(a.positional, vec!["fig2"]);
        // ...and unlisted flags keep the greedy `--key value` style.
        let a = parse_opt("run --tag full --cache fig2", &["cache"]);
        assert_eq!(a.get("tag"), Some("full"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn optional_value_flag_before_a_subcommand_keeps_the_subcommand() {
        let a = parse_opt("--emit-scenario sweep", &["emit-scenario"]);
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert!(a.flag("emit-scenario"));
    }

    #[test]
    fn plain_parse_keeps_the_greedy_value_style() {
        let a = parse("run --cache fig2");
        assert_eq!(a.get("cache"), Some("fig2"));
        assert!(a.positional.is_empty());
    }
}
