//! A small token-level Rust lexer for the `repro lint` analyzer.
//!
//! The pre-lint CI enforcement of this repo's invariants was a shell
//! `grep` — which cannot tell an identifier from a comment, a format
//! string from a doc example, or a lifetime from a char literal. This
//! lexer closes exactly that gap and nothing more: it splits source
//! text into identifiers, literals, punctuation and comments with line
//! numbers, handling the constructs that defeat regexes (nested block
//! comments, raw strings with hash fences, `'a` lifetimes vs `'a'`
//! chars, escapes). It does **not** parse: the rule engine
//! ([`super::rules`]) works on adjacency in this token stream, which is
//! enough for every current rule and keeps the pass dependency-free.

/// Classification of one lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifiers and keywords, including the `_` pattern and raw
    /// `r#ident` forms.
    Ident,
    /// A `'name` lifetime (or loop label).
    Lifetime,
    /// Integer or float literal (suffixes included).
    Number,
    /// Any string-like literal: `"…"`, `r#"…"#`, `b"…"`, `'c'`, `b'c'`.
    Str,
    /// Punctuation. Single characters, except `=>` which lexes as one
    /// token so match arms are recognizable by adjacency.
    Punct,
    /// Line or block comment (text includes the delimiters). Kept in
    /// the stream because `// lint: allow(...)` markers live here.
    Comment,
}

/// One token: kind, verbatim source text, and 1-based start line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token<'a> {
    pub kind: TokenKind,
    pub text: &'a str,
    pub line: u32,
}

/// Character cursor over the source with line tracking.
struct Cursor<'a> {
    src: &'a str,
    pos: usize,
    line: u32,
}

impl<'a> Cursor<'a> {
    fn peek(&self) -> Option<char> {
        self.src[self.pos..].chars().next()
    }

    fn peek_at(&self, n: usize) -> Option<char> {
        self.src[self.pos..].chars().nth(n)
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += c.len_utf8();
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }

    /// Consume `c` if it is next.
    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.bump();
            true
        } else {
            false
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens. Never fails: unexpected bytes become `Punct`
/// tokens, unterminated literals run to end of input — a *lint* must
/// degrade gracefully on code it cannot fully understand, because the
/// compiler will reject truly malformed source anyway.
pub fn lex(src: &str) -> Vec<Token<'_>> {
    let mut cur = Cursor { src, pos: 0, line: 1 };
    let mut out = Vec::new();
    while let Some(c) = cur.peek() {
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        let start = cur.pos;
        let line = cur.line;
        let kind = scan_token(&mut cur, c);
        out.push(Token {
            kind,
            text: &src[start..cur.pos],
            line,
        });
    }
    out
}

/// Scan one token starting at `c` (not yet consumed).
fn scan_token(cur: &mut Cursor<'_>, c: char) -> TokenKind {
    match c {
        '/' if cur.peek_at(1) == Some('/') => {
            while let Some(n) = cur.peek() {
                if n == '\n' {
                    break;
                }
                cur.bump();
            }
            TokenKind::Comment
        }
        '/' if cur.peek_at(1) == Some('*') => {
            cur.bump();
            cur.bump();
            block_comment_body(cur);
            TokenKind::Comment
        }
        '"' => {
            cur.bump();
            quoted_body(cur, '"');
            TokenKind::Str
        }
        'r' if matches!(cur.peek_at(1), Some('"' | '#')) => raw_prefixed(cur),
        'b' if matches!(cur.peek_at(1), Some('"' | '\'' | 'r')) => byte_prefixed(cur, c),
        '\'' => {
            cur.bump();
            char_or_lifetime(cur)
        }
        '=' if cur.peek_at(1) == Some('>') => {
            cur.bump();
            cur.bump();
            TokenKind::Punct
        }
        _ if c.is_ascii_digit() => {
            number_body(cur);
            TokenKind::Number
        }
        _ if is_ident_start(c) => {
            ident_body(cur);
            TokenKind::Ident
        }
        _ => {
            cur.bump();
            TokenKind::Punct
        }
    }
}

/// Body of a `/* … */` comment (delimiters of the outermost level
/// already consumed). Rust block comments nest.
fn block_comment_body(cur: &mut Cursor<'_>) {
    let mut depth = 1u32;
    while depth > 0 {
        match cur.bump() {
            None => break,
            Some('*') if cur.peek() == Some('/') => {
                cur.bump();
                depth -= 1;
            }
            Some('/') if cur.peek() == Some('*') => {
                cur.bump();
                depth += 1;
            }
            Some(_) => {}
        }
    }
}

/// Body of an escaped quoted literal up to the closing `quote`
/// (opening quote already consumed).
fn quoted_body(cur: &mut Cursor<'_>, quote: char) {
    while let Some(c) = cur.bump() {
        if c == '\\' {
            cur.bump();
        } else if c == quote {
            break;
        }
    }
}

/// `r"…"`, `r#"…"#`, or a raw identifier `r#ident` (leading `r` not
/// yet consumed).
fn raw_prefixed(cur: &mut Cursor<'_>) -> TokenKind {
    cur.bump(); // the r
    let mut hashes = 0usize;
    while cur.peek() == Some('#') {
        // `r#ident` (raw identifier) vs `r#"…"#` (one-hash raw string):
        // decided by what follows the hash run.
        if hashes == 0 {
            if let Some(next) = cur.peek_at(1) {
                if is_ident_start(next) {
                    cur.bump();
                    ident_body(cur);
                    return TokenKind::Ident;
                }
            }
        }
        cur.bump();
        hashes += 1;
    }
    if !cur.eat('"') {
        // Lone `r#` with nothing sensible after it; treat the run as an
        // identifier and move on.
        return TokenKind::Ident;
    }
    raw_string_body(cur, hashes);
    TokenKind::Str
}

/// `b"…"`, `b'…'`, `br#"…"#` (leading `b` not yet consumed).
fn byte_prefixed(cur: &mut Cursor<'_>, _b: char) -> TokenKind {
    cur.bump(); // the b
    match cur.peek() {
        Some('"') => {
            cur.bump();
            quoted_body(cur, '"');
            TokenKind::Str
        }
        Some('\'') => {
            cur.bump();
            quoted_body(cur, '\'');
            TokenKind::Str
        }
        Some('r') => {
            cur.bump();
            let mut hashes = 0usize;
            while cur.eat('#') {
                hashes += 1;
            }
            if cur.eat('"') {
                raw_string_body(cur, hashes);
            }
            TokenKind::Str
        }
        _ => TokenKind::Ident, // plain identifier starting with b
    }
}

/// Raw-string body: runs to `"` followed by `hashes` hash marks
/// (opening fence already consumed). No escapes inside.
fn raw_string_body(cur: &mut Cursor<'_>, hashes: usize) {
    'scan: while let Some(c) = cur.bump() {
        if c != '"' {
            continue;
        }
        for n in 0..hashes {
            if cur.peek_at(0) != Some('#') {
                // Not the fence — keep scanning; the hashes peeked so
                // far were content and stay unconsumed.
                let _ = n;
                continue 'scan;
            }
            cur.bump();
        }
        break;
    }
}

/// After a consumed `'`: disambiguate char literal from lifetime. The
/// classic rule: `'a` followed by another `'` is a char (`'a'`);
/// otherwise an identifier run after `'` is a lifetime/label.
fn char_or_lifetime(cur: &mut Cursor<'_>) -> TokenKind {
    match cur.peek() {
        Some('\\') => {
            quoted_body(cur, '\'');
            TokenKind::Str
        }
        Some(c) if is_ident_start(c) => {
            ident_body(cur);
            if cur.eat('\'') {
                TokenKind::Str
            } else {
                TokenKind::Lifetime
            }
        }
        Some(_) => {
            // Non-identifier char literal: `' '`, `'{'`, `'1'`.
            cur.bump();
            cur.eat('\'');
            TokenKind::Str
        }
        None => TokenKind::Punct,
    }
}

fn ident_body(cur: &mut Cursor<'_>) {
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        cur.bump();
    }
}

/// Number literal. A `.` joins the token only when a digit follows, so
/// range expressions (`0..n`) do not fuse into the number.
fn number_body(cur: &mut Cursor<'_>) {
    while let Some(c) = cur.peek() {
        if is_ident_continue(c) {
            cur.bump();
        } else if c == '.' && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit()) {
            cur.bump();
        } else {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, &str)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_numbers_punct() {
        assert_eq!(
            kinds("let x = 42;"),
            vec![
                (TokenKind::Ident, "let"),
                (TokenKind::Ident, "x"),
                (TokenKind::Punct, "="),
                (TokenKind::Number, "42"),
                (TokenKind::Punct, ";"),
            ]
        );
    }

    #[test]
    fn comments_are_tokens_not_code() {
        let toks = kinds("a // unwrap() in a comment\nb /* _ => */ c");
        assert_eq!(toks[0], (TokenKind::Ident, "a"));
        assert_eq!(toks[1], (TokenKind::Comment, "// unwrap() in a comment"));
        assert_eq!(toks[2], (TokenKind::Ident, "b"));
        assert_eq!(toks[3], (TokenKind::Comment, "/* _ => */"));
        assert_eq!(toks[4], (TokenKind::Ident, "c"));
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("x /* outer /* inner */ still comment */ y");
        assert_eq!(toks.len(), 3);
        assert_eq!(toks[0], (TokenKind::Ident, "x"));
        assert_eq!(toks[1].0, TokenKind::Comment);
        assert_eq!(toks[2], (TokenKind::Ident, "y"));
    }

    #[test]
    fn strings_swallow_code_like_text() {
        let toks = kinds(r#"let s = "match x { _ => panic!() }";"#);
        let strs: Vec<_> = toks.iter().filter(|t| t.0 == TokenKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(!toks.iter().any(|t| t.0 == TokenKind::Ident && t.1 == "panic"));
    }

    #[test]
    fn escaped_and_raw_strings() {
        let toks = kinds(r#"("a\"b", r"c\", r#"d " e"#)"#);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|t| t.0 == TokenKind::Str)
            .map(|t| t.1)
            .collect();
        assert_eq!(strs, vec![r#""a\"b""#, r#"r"c\""#, r###"r#"d " e"#"###]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(toks.iter().any(|t| t.0 == TokenKind::Lifetime && t.1 == "'a"));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Str && t.1 == "'x'"));
        let toks = kinds(r"('\n', '\u{0008}', ' ', '{')");
        let chars = toks.iter().filter(|t| t.0 == TokenKind::Str).count();
        assert_eq!(chars, 4);
    }

    #[test]
    fn fat_arrow_is_one_token() {
        let toks = kinds("match x { _ => 1, y if y >= 2 => 3 }");
        let arrows = toks.iter().filter(|t| t.1 == "=>").count();
        assert_eq!(arrows, 2);
        // `>=` stays two tokens and never eats into an arrow.
        assert!(toks.iter().any(|t| t.1 == ">"));
    }

    #[test]
    fn ranges_do_not_fuse_into_numbers() {
        let toks = kinds("for i in 0..n { v[i] = 1.5e3; }");
        assert!(toks.iter().any(|t| t.0 == TokenKind::Number && t.1 == "0"));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Number && t.1 == "1.5e3"));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Ident && t.1 == "n"));
    }

    #[test]
    fn line_numbers_survive_multiline_tokens() {
        let src = "a\n\"two\nline string\"\nb /* block\ncomment */ c";
        let toks = lex(src);
        let by_text: Vec<(u32, &str)> = toks.iter().map(|t| (t.line, t.text)).collect();
        assert_eq!(by_text[0], (1, "a"));
        assert_eq!(by_text[1].0, 2, "string starts on line 2");
        assert_eq!(by_text[2], (4, "b"));
        assert_eq!(by_text[4], (5, "c"), "line count includes the block comment's newline");
    }

    #[test]
    fn byte_literals_and_raw_idents() {
        let toks = kinds(r##"(b"bytes", b'\t', r#match, br#"raw"#)"##);
        assert!(toks.iter().any(|t| t.0 == TokenKind::Str && t.1 == "b\"bytes\""));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Str && t.1 == "b'\\t'"));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Ident && t.1 == "r#match"));
        assert!(toks.iter().any(|t| t.0 == TokenKind::Str && t.1 == "br#\"raw\"#"));
    }
}
