//! `repro lint` — a dependency-free static-analysis pass over this
//! repo's own Rust sources.
//!
//! The reproduction's core promise is byte-identical results across
//! caches, shards, processes and batch no-ops. That rests on a small
//! set of invariants (bit-exact float round-trips, engine-only
//! evaluation in experiments, version constants bumped with their
//! models) that used to be enforced by CI greps and reviewer memory.
//! This module promotes them to first-class, fixture-tested rules:
//!
//! | id | invariant |
//! |----|-----------|
//! | R1 | `experiments/` never constructs `CostModel`/`BaselineModel` directly |
//! | R2 | no lossy float formatting in fingerprint/persist/canonical code |
//! | R3 | guarded modules bump their version constant when content changes |
//! | R4 | no `unwrap()`/`expect()`/`panic!` on the library path |
//! | R5 | no wildcard `_ =>` arms in persist/canonical decode code |
//! | R6 | no `HashMap`/`HashSet` in deterministic-output code |
//! | R7 | no un-sorted `read_dir` walks in deterministic-output code |
//! | R8 | persistent-artifact writes go through `util::fsx::write_atomic`, never bare `fs::write` |
//!
//! R1/R2/R4–R8 are token-level checks ([`rules`], over the [`lexer`]
//! stream); R3 is a tree-level pass against the version-guard manifest
//! (`guards.toml`, [`guards`]). Sites with a locally provable
//! justification carry `// lint: allow(Rn): <reason>` markers —
//! mandatory reason, stale markers are themselves errors.
//!
//! Entry point: [`run`] over a repo root (the directory containing
//! `rust/src`), surfaced as `repro lint [--fix-guards] [path]` and a
//! CI job. The pass scans `rust/src` only: integration tests, benches
//! and `build.rs`-style scripts are intentionally out of scope.

pub mod guards;
pub mod lexer;
pub mod rules;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use rules::{check_source, Diagnostic, RULES, RULE_IDS};

/// Manifest location relative to the scanned root.
pub const GUARDS_MANIFEST: &str = "rust/src/lint/guards.toml";

/// Knobs for one lint run.
#[derive(Debug, Clone)]
pub struct LintOptions {
    /// Rewrite the guard manifest after a legitimate version bump
    /// (`--fix-guards`). Never adopts a content change whose version
    /// constant is un-bumped.
    pub fix_guards: bool,
    /// Run the R3 guard pass. Off for pure rule fixtures (temp trees
    /// without a manifest).
    pub check_guards: bool,
}

impl Default for LintOptions {
    fn default() -> Self {
        LintOptions { fix_guards: false, check_guards: true }
    }
}

/// Outcome of one lint run over a tree.
#[derive(Debug)]
pub struct LintReport {
    /// All findings, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Whether `--fix-guards` rewrote the manifest.
    pub guards_rewritten: bool,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable report: one block per diagnostic plus a summary
    /// line (always last).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for diag in &self.diagnostics {
            out.push_str(&diag.render());
            out.push('\n');
        }
        if self.guards_rewritten {
            out.push_str("lint: guard manifest rewritten\n");
        }
        if self.clean() {
            out.push_str(&format!("lint: {} files, clean\n", self.files));
        } else {
            out.push_str(&format!(
                "lint: {} issue(s) across {} files\n",
                self.diagnostics.len(),
                self.files
            ));
        }
        out
    }
}

/// Lint the tree rooted at `root` (must contain `rust/src`). Scans
/// every `.rs` file in deterministic path order, then runs the guard
/// pass. Returns an error only for infrastructure failures (unreadable
/// files, corrupt manifest) — findings are data, in the report.
pub fn run(root: &Path, opts: &LintOptions) -> Result<LintReport> {
    let files = rs_files(root, "rust/src")?;
    if files.is_empty() {
        bail!("lint: no .rs files under {}/rust/src", root.display());
    }
    let mut diagnostics = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("lint: reading {rel}"))?;
        diagnostics.extend(rules::check_source(rel, &src));
    }
    let mut guards_rewritten = false;
    if opts.check_guards {
        if root.join(GUARDS_MANIFEST).is_file() {
            let outcome = guards::check(root, GUARDS_MANIFEST, opts.fix_guards)?;
            diagnostics.extend(outcome.diagnostics);
            guards_rewritten = outcome.rewritten;
        } else {
            diagnostics.push(Diagnostic {
                file: GUARDS_MANIFEST.to_string(),
                line: 0,
                rule: "R3",
                message: "version-guard manifest is missing".to_string(),
                help: "restore rust/src/lint/guards.toml from git (R3 cannot run without it)"
                    .to_string(),
            });
        }
    }
    diagnostics.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(LintReport { diagnostics, files: files.len(), guards_rewritten })
}

/// Every `.rs` file under `root/rel` (a file or directory), as sorted
/// `/`-separated paths relative to `root`. Deterministic so lint
/// output and guard hashes never depend on directory-entry order.
pub fn rs_files(root: &Path, rel: &str) -> Result<Vec<String>> {
    let full = root.join(rel);
    if full.is_file() {
        return Ok(if rel.ends_with(".rs") { vec![rel.to_string()] } else { Vec::new() });
    }
    if !full.is_dir() {
        bail!("lint: {rel:?} does not exist under {}", root.display());
    }
    let mut names = Vec::new();
    for entry in
        std::fs::read_dir(&full).with_context(|| format!("lint: listing {rel}"))?
    {
        let entry = entry.with_context(|| format!("lint: listing {rel}"))?;
        names.push(entry.file_name().to_string_lossy().into_owned());
    }
    names.sort();
    let mut out = Vec::new();
    for name in names {
        let child_rel = format!("{rel}/{name}");
        if full.join(&name).is_dir() {
            out.extend(rs_files(root, &child_rel)?);
        } else if name.ends_with(".rs") {
            out.push(child_rel);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rs_files_walks_sorted_and_recursive() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = rs_files(root, "rust/src/lint").expect("lint dir exists");
        assert_eq!(
            files,
            vec![
                "rust/src/lint/guards.rs",
                "rust/src/lint/lexer.rs",
                "rust/src/lint/mod.rs",
                "rust/src/lint/rules.rs",
            ]
        );
        let single = rs_files(root, "rust/src/lib.rs").expect("file form");
        assert_eq!(single, vec!["rust/src/lib.rs"]);
        assert!(rs_files(root, "rust/src/nonexistent").is_err());
    }

    #[test]
    fn report_renders_summary_last() {
        let report = LintReport { diagnostics: Vec::new(), files: 3, guards_rewritten: false };
        assert!(report.clean());
        assert!(report.render().ends_with("lint: 3 files, clean\n"));
    }
}
