//! R3 — the version-guard pass.
//!
//! Cached sweeps, shard files, and scenario documents are only valid
//! while the code that produced them is semantically unchanged; the
//! repo encodes that as version constants (`MAPPER_VERSION`,
//! `COST_MODEL_VERSION`, `CACHE_FORMAT_VERSION`,
//! `SCENARIO_FORMAT_VERSION`) pinned into every fingerprint and file
//! header. The guard manifest (`lint/guards.toml`) closes the loop:
//! it records, per guarded module, a content hash of its sources and
//! the version the constant held when that hash was taken. Change a
//! guarded module without bumping its constant and the lint fails —
//! the PR-2/PR-3 "model drifted, caches silently stale" class becomes
//! a CI error.
//!
//! Workflow on a legitimate model change:
//! 1. edit the guarded module; 2. bump its version constant;
//! 3. `repro lint --fix-guards` re-records the hash; 4. commit both.
//! `--fix-guards` refuses step 3 while the constant is un-bumped, so
//! it cannot be used to launder a drift. For a provably non-semantic
//! edit (comments, formatting) the escape hatch is deliberate and
//! manual: paste the computed hash from the diagnostic into the
//! manifest by hand.
//!
//! The manifest is a deliberately tiny TOML subset (flat `[[guard]]`
//! tables, string/integer/string-array values, `#` comments) so the
//! pass stays dependency-free.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::lexer::TokenKind;
use super::rs_files;
use super::rules::{Diagnostic, Scan};
use crate::util::hash::fnv1a;

/// One `[[guard]]` manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Guard {
    /// Short id used in diagnostics (e.g. `mapper`).
    pub name: String,
    /// The pinned version constant, e.g. `MAPPER_VERSION`.
    pub version_const: String,
    /// File (relative to root) declaring `const <version_const>: u32`.
    pub version_file: String,
    /// Files/directories (relative to root) whose `.rs` sources the
    /// content hash covers.
    pub paths: Vec<String>,
    /// Value `version_const` held when `hash` was recorded.
    pub version: u64,
    /// fnv1a-64 hex of the guarded sources; `""` = not yet recorded
    /// (bootstrap sentinel that `--fix-guards` adopts).
    pub hash: String,
    /// Line of the `[[guard]]` header in the manifest, for diagnostics.
    pub line: u32,
}

/// Result of the guard pass.
pub struct GuardOutcome {
    pub diagnostics: Vec<Diagnostic>,
    /// Whether `--fix-guards` rewrote the manifest.
    pub rewritten: bool,
}

/// Run the guard pass. `manifest_rel` is the manifest path relative to
/// `root` (diagnostics point at it). With `fix`, legitimate bumps and
/// uninitialized entries are recorded back to the manifest; content
/// drift without a bump is never fixed automatically.
pub fn check(root: &Path, manifest_rel: &str, fix: bool) -> Result<GuardOutcome> {
    let manifest_path = root.join(manifest_rel);
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("guards: reading {}", manifest_path.display()))?;
    let mut guards = parse(&text).context("guards: parsing manifest")?;
    let mut diagnostics = Vec::new();
    let mut dirty = false;

    for guard in &mut guards {
        let diag = |line: u32, message: String, help: String| Diagnostic {
            file: manifest_rel.to_string(),
            line,
            rule: "R3",
            message,
            help,
        };
        let actual = module_hash(root, &guard.paths)
            .with_context(|| format!("guards: hashing module {:?}", guard.name))?;
        let version_src = std::fs::read_to_string(root.join(&guard.version_file))
            .with_context(|| format!("guards: reading {}", guard.version_file))?;
        let Some(version_now) = version_constant(&version_src, &guard.version_const) else {
            diagnostics.push(diag(
                guard.line,
                format!(
                    "guard {:?}: no `const {}: u32` found in {}",
                    guard.name, guard.version_const, guard.version_file
                ),
                "fix the manifest's version_file/version_const or restore the constant"
                    .to_string(),
            ));
            continue;
        };

        if guard.hash.is_empty() {
            // Bootstrap: nothing recorded yet.
            if fix {
                guard.hash = actual;
                guard.version = version_now;
                dirty = true;
            } else {
                diagnostics.push(diag(
                    guard.line,
                    format!("guard {:?} has no recorded content hash yet", guard.name),
                    "run `repro lint --fix-guards` to record the current hash".to_string(),
                ));
            }
        } else if actual == guard.hash {
            if version_now != guard.version {
                // Constant changed while content (which includes the
                // constant's own file only if listed under paths) did
                // not: the manifest's pinned version is stale.
                if fix {
                    guard.version = version_now;
                    dirty = true;
                } else {
                    diagnostics.push(diag(
                        guard.line,
                        format!(
                            "guard {:?}: manifest pins {} = {} but the constant is now {}",
                            guard.name, guard.version_const, guard.version, version_now
                        ),
                        "run `repro lint --fix-guards` to refresh the manifest".to_string(),
                    ));
                }
            }
        } else if version_now == guard.version {
            // THE guarded failure: content drifted, constant did not.
            // Never auto-fixed — even with --fix-guards.
            diagnostics.push(diag(
                guard.line,
                format!(
                    "guarded module {:?} changed (content hash {} != recorded {}) but {} is still {}",
                    guard.name, actual, guard.hash, guard.version_const, guard.version
                ),
                format!(
                    "bump {} in {} and run `repro lint --fix-guards`; cached artifacts keyed \
                     on the old version are stale (for a provably non-semantic edit, paste \
                     the new hash into the manifest by hand)",
                    guard.version_const, guard.version_file
                ),
            ));
        } else {
            // Content changed AND the constant was bumped: legitimate;
            // just needs recording.
            if fix {
                guard.hash = actual;
                guard.version = version_now;
                dirty = true;
            } else {
                diagnostics.push(diag(
                    guard.line,
                    format!(
                        "guard {:?}: {} bumped to {} — the manifest still records \
                         version {} / the old content hash",
                        guard.name, guard.version_const, version_now, guard.version
                    ),
                    "run `repro lint --fix-guards` to record the new hash".to_string(),
                ));
            }
        }
    }

    if dirty {
        std::fs::write(&manifest_path, encode(&guards))
            .with_context(|| format!("guards: rewriting {}", manifest_path.display()))?;
    }
    Ok(GuardOutcome { diagnostics, rewritten: dirty })
}

/// Content hash of one guarded module: fnv1a-64 over every `.rs` file
/// under `paths` in sorted relative-path order, each contributing
/// `<rel path> NUL <contents> NUL` so file renames and content moves
/// both change the hash.
pub fn module_hash(root: &Path, paths: &[String]) -> Result<String> {
    let mut files = Vec::new();
    for rel in paths {
        files.extend(rs_files(root, rel)?);
    }
    files.sort();
    files.dedup();
    let mut bytes = Vec::new();
    for rel in &files {
        let content = std::fs::read(root.join(rel))
            .with_context(|| format!("guards: reading {rel}"))?;
        bytes.extend_from_slice(rel.as_bytes());
        bytes.push(0);
        bytes.extend_from_slice(&content);
        bytes.push(0);
    }
    Ok(format!("{:016x}", fnv1a(&bytes)))
}

/// Find `const <name>: u32 = <N>;` in `src` by token scan (so the
/// constant can live anywhere in the file, but a comment or string
/// mentioning it does not count).
pub fn version_constant(src: &str, name: &str) -> Option<u64> {
    let scan = Scan::new(src);
    let tok = |p: usize| scan.code.get(p).map(|&i| &scan.tokens[i]);
    for p in 1..scan.code.len() {
        let is_decl = tok(p).is_some_and(|t| t.kind == TokenKind::Ident && t.text == name)
            && tok(p - 1).is_some_and(|t| t.kind == TokenKind::Ident && t.text == "const")
            && tok(p + 1).is_some_and(|t| t.text == ":")
            && tok(p + 2).is_some_and(|t| t.text == "u32")
            && tok(p + 3).is_some_and(|t| t.text == "=");
        if !is_decl {
            continue;
        }
        let number = tok(p + 4).filter(|t| t.kind == TokenKind::Number)?;
        return number.text.replace('_', "").parse().ok();
    }
    None
}

// ---------------------------------------------------------------------------
// Manifest encode/decode (flat TOML subset)
// ---------------------------------------------------------------------------

/// Parse the manifest. Accepts exactly what [`encode`] writes: `#`
/// comments, `[[guard]]` headers, and `key = value` with quoted
/// strings, integers, or single-line arrays of quoted strings.
pub fn parse(text: &str) -> Result<Vec<Guard>> {
    let mut guards: Vec<Guard> = Vec::new();
    for (index, raw) in text.lines().enumerate() {
        let lineno = index as u32 + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[guard]]" {
            guards.push(Guard {
                name: String::new(),
                version_const: String::new(),
                version_file: String::new(),
                paths: Vec::new(),
                version: 0,
                hash: String::new(),
                line: lineno,
            });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("guards.toml:{lineno}: expected `key = value`, got {line:?}");
        };
        let Some(guard) = guards.last_mut() else {
            bail!("guards.toml:{lineno}: key outside a [[guard]] table");
        };
        let key = key.trim();
        let value = value.trim();
        match key {
            "name" => guard.name = parse_string(value, lineno)?,
            "version_const" => guard.version_const = parse_string(value, lineno)?,
            "version_file" => guard.version_file = parse_string(value, lineno)?,
            "hash" => guard.hash = parse_string(value, lineno)?,
            "paths" => guard.paths = parse_string_array(value, lineno)?,
            "version" => {
                guard.version = value
                    .parse()
                    .with_context(|| format!("guards.toml:{lineno}: bad integer {value:?}"))?;
            }
            other => bail!("guards.toml:{lineno}: unknown key {other:?}"),
        }
    }
    for guard in &guards {
        if guard.name.is_empty()
            || guard.version_const.is_empty()
            || guard.version_file.is_empty()
            || guard.paths.is_empty()
        {
            bail!(
                "guards.toml:{}: guard needs name, version_const, version_file and paths",
                guard.line
            );
        }
    }
    Ok(guards)
}

fn parse_string(value: &str, lineno: u32) -> Result<String> {
    let inner = value
        .strip_prefix('"')
        .and_then(|v| v.strip_suffix('"'))
        .with_context(|| format!("guards.toml:{lineno}: expected a quoted string, got {value:?}"))?;
    if inner.contains('"') || inner.contains('\\') {
        bail!("guards.toml:{lineno}: quotes/escapes unsupported in {value:?}");
    }
    Ok(inner.to_string())
}

fn parse_string_array(value: &str, lineno: u32) -> Result<Vec<String>> {
    let inner = value
        .strip_prefix('[')
        .and_then(|v| v.strip_suffix(']'))
        .with_context(|| format!("guards.toml:{lineno}: expected [\"…\", …], got {value:?}"))?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(parse_string(part, lineno)?);
    }
    if out.is_empty() {
        bail!("guards.toml:{lineno}: empty paths array");
    }
    Ok(out)
}

/// Serialize guards back to the manifest format (stable field order,
/// one blank line between entries) so `--fix-guards` rewrites produce
/// minimal diffs.
pub fn encode(guards: &[Guard]) -> String {
    let mut out = String::from(
        "# repro lint version-guard manifest (rule R3).\n\
         # hash = fnv1a-64 over every guarded .rs file (sorted rel path NUL contents NUL).\n\
         # On a model change: bump the version constant, then `repro lint --fix-guards`.\n",
    );
    for guard in guards {
        out.push('\n');
        out.push_str("[[guard]]\n");
        out.push_str(&format!("name = \"{}\"\n", guard.name));
        out.push_str(&format!("version_const = \"{}\"\n", guard.version_const));
        out.push_str(&format!("version_file = \"{}\"\n", guard.version_file));
        let paths: Vec<String> = guard.paths.iter().map(|p| format!("\"{p}\"")).collect();
        out.push_str(&format!("paths = [{}]\n", paths.join(", ")));
        out.push_str(&format!("version = {}\n", guard.version));
        out.push_str(&format!("hash = \"{}\"\n", guard.hash));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_round_trips_through_encode_and_parse() {
        let guards = vec![
            Guard {
                name: "mapper".into(),
                version_const: "MAPPER_VERSION".into(),
                version_file: "rust/src/mapping/mod.rs".into(),
                paths: vec!["rust/src/mapping".into()],
                version: 1,
                hash: "00112233aabbccdd".into(),
                line: 5,
            },
            Guard {
                name: "cost-model".into(),
                version_const: "COST_MODEL_VERSION".into(),
                version_file: "rust/src/cost/mod.rs".into(),
                paths: vec!["rust/src/cost".into(), "rust/src/arch".into()],
                version: 3,
                hash: String::new(),
                line: 13,
            },
        ];
        let parsed = parse(&encode(&guards)).expect("encode() output must parse");
        assert_eq!(parsed.len(), 2);
        for (a, b) in guards.iter().zip(&parsed) {
            assert_eq!((&a.name, &a.version_const, &a.version_file), (&b.name, &b.version_const, &b.version_file));
            assert_eq!((&a.paths, a.version, &a.hash), (&b.paths, b.version, &b.hash));
        }
    }

    #[test]
    fn parse_rejects_malformed_manifests() {
        assert!(parse("name = \"orphan\"\n").is_err(), "key outside table");
        assert!(parse("[[guard]]\nname = \"x\"\n").is_err(), "missing required keys");
        assert!(parse("[[guard]]\nbogus = 1\n").is_err(), "unknown key");
        assert!(parse("[[guard]]\nname = unquoted\n").is_err(), "unquoted string");
    }

    #[test]
    fn version_constant_is_found_by_token_scan() {
        let src = "\
//! Talks about MAPPER_VERSION: u32 = 9 in a doc comment.
pub const OTHER: u32 = 7;
/// const MAPPER_VERSION: u32 = 8 (doc, not code)
pub const MAPPER_VERSION: u32 = 2;
";
        assert_eq!(version_constant(src, "MAPPER_VERSION"), Some(2));
        assert_eq!(version_constant(src, "OTHER"), Some(7));
        assert_eq!(version_constant(src, "MISSING"), None);
    }

    #[test]
    fn version_constant_handles_underscored_literals() {
        let src = "pub const CACHE_FORMAT_VERSION: u32 = 1_0;";
        assert_eq!(version_constant(src, "CACHE_FORMAT_VERSION"), Some(10));
    }
}
