//! The rule engine: token-level checks over one file at a time.
//!
//! Each rule pins one of the repo's determinism/correctness invariants
//! (see `lint/README.md` for the full table). Rules fire on token
//! adjacency in the [`super::lexer`] stream — no parsing — which keeps
//! them dependency-free and fast, at the cost of being deliberately
//! conservative: a rule flags every syntactic occurrence in its scope
//! and sites that are genuinely fine carry an inline allow marker.
//!
//! ## Allow markers
//!
//! A site is exempted with a line comment naming the rule **and** a
//! reason (the reason is mandatory — an exemption nobody can justify
//! is a violation):
//!
//! ```text
//! // lint: allow(R4): poisoned lock means a sibling thread panicked
//! ```
//!
//! The marker suppresses that rule on the marker's own line and on the
//! next code line (so it works both trailing a statement and on the
//! line above it; a run of comment lines between marker and code is
//! skipped). Malformed markers, unknown rule ids, and markers that
//! never matched a diagnostic are themselves diagnostics — allowlists
//! cannot silently rot.
//!
//! Doc comments (`///`, `//!`) are never markers, so rule docs can
//! show the syntax without exempting anything.

use super::lexer::{lex, Token, TokenKind};

/// One finding: where, which rule, what, and how to fix it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    pub line: u32,
    /// Rule id (`R1`..`R8`, or `lint` for marker hygiene findings).
    pub rule: &'static str,
    pub message: String,
    /// Suggested fix, one line.
    pub help: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!(
            "{}:{}: [{}] {}\n    fix: {}",
            self.file, self.line, self.rule, self.message, self.help
        )
    }
}

/// Every rule id the analyzer knows, including the guard pass (R3),
/// which runs per-tree in [`super::guards`] rather than per-file here.
pub const RULE_IDS: &[&str] = &["R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"];

/// One token-level rule.
pub struct Rule {
    pub id: &'static str,
    /// One-line invariant statement (doc table / `repro lint --rules`).
    pub summary: &'static str,
    /// Suggested fix attached to every diagnostic of this rule.
    pub fix: &'static str,
    /// Scope predicate over the `/`-separated path relative to root.
    pub applies: fn(&str) -> bool,
    /// Whether `#[test]` / `#[cfg(test)]` regions are exempt.
    pub skip_tests: bool,
    /// Emits `(token_index, message)` pairs for every occurrence.
    pub check: fn(&Scan<'_>, &mut Vec<(usize, String)>),
}

/// Files whose string output feeds fingerprints, cache files, or
/// canonical serializations — where formatting must be bit-exact (R2)
/// and decoding must be exhaustive (R5). `sweep/output.rs` is absent
/// on purpose: its CSVs are *display* artifacts with intentional
/// rounding; byte-identity of those files is pinned by the golden
/// tests, not by bit-exact floats.
const PERSIST_PATHS: &[&str] = &[
    "rust/src/sweep/persist.rs",
    "rust/src/sweep/shard.rs",
    "rust/src/sweep/cache.rs",
    "rust/src/mapping/canonical.rs",
    "rust/src/scenario/mod.rs",
];

/// PERSIST_PATHS minus `sweep/cache.rs` — the cache's in-memory maps
/// are `HashMap` by design (hot path), and `snapshot_stamped()` sorts
/// before anything escapes, so R6 pins the sinks around it instead.
const DECODE_PATHS: &[&str] = &[
    "rust/src/sweep/persist.rs",
    "rust/src/sweep/shard.rs",
    "rust/src/mapping/canonical.rs",
    "rust/src/scenario/mod.rs",
];

/// Code that writes deterministic output: encoders, CSV/JSON sinks,
/// and the hash that fingerprints them.
const OUTPUT_SINK_PATHS: &[&str] = &[
    "rust/src/sweep/persist.rs",
    "rust/src/sweep/shard.rs",
    "rust/src/sweep/output.rs",
    "rust/src/mapping/canonical.rs",
    "rust/src/scenario/mod.rs",
    "rust/src/scenario/exec.rs",
    "rust/src/scenario/orchestrate.rs",
    "rust/src/serve/protocol.rs",
    "rust/src/serve/metrics.rs",
    "rust/src/util/json.rs",
    "rust/src/util/csv.rs",
    "rust/src/util/hash.rs",
];

/// Code that persists artifacts other processes reload (cache files,
/// shard manifests, merged outputs, anything the serve daemon hands
/// back from disk). A bare `fs::write` here can leave a torn file
/// behind a crash; writes must go through `util::fsx::write_atomic`
/// (temp sibling + rename) so readers only ever see whole files.
const ATOMIC_WRITE_PATHS: &[&str] = &[
    "rust/src/sweep/persist.rs",
    "rust/src/scenario/orchestrate.rs",
];

fn in_atomic_write_scope(path: &str) -> bool {
    ATOMIC_WRITE_PATHS.contains(&path) || path.starts_with("rust/src/serve/")
}

fn in_experiments(path: &str) -> bool {
    path.starts_with("rust/src/experiments/")
}

fn in_persist(path: &str) -> bool {
    PERSIST_PATHS.contains(&path) || path == "rust/src/util/json.rs" || path == "rust/src/util/hash.rs"
}

fn in_decode(path: &str) -> bool {
    DECODE_PATHS.contains(&path)
}

fn in_output_sink(path: &str) -> bool {
    OUTPUT_SINK_PATHS.contains(&path)
}

fn library_path(path: &str) -> bool {
    path != "rust/src/main.rs"
}

/// The token-level rules. R3 (version guards) is tree-level and lives
/// in [`super::guards`].
pub const RULES: &[Rule] = &[
    Rule {
        id: "R1",
        summary: "experiments/ must not construct CostModel/BaselineModel directly",
        fix: "evaluate through sweep::SweepEngine (MapperChoice axis) or coordinator::jobs \
              so results flow through the memoized, fingerprinted path",
        applies: in_experiments,
        // The retired CI grep also covered test code, and golden
        // equivalence only holds if tests use the engine too.
        skip_tests: false,
        check: check_cost_model_use,
    },
    Rule {
        id: "R2",
        summary: "no lossy float formatting in fingerprint/persist/canonical code",
        fix: "format floats as f64::to_bits hex (see sweep::persist) so decode round-trips \
              bit-exactly; decimal rounding belongs in display sinks only",
        applies: in_persist,
        skip_tests: true,
        check: check_lossy_float_format,
    },
    Rule {
        id: "R4",
        summary: "no unwrap()/expect()/panic! on the library path",
        fix: "return a typed error (anyhow context) or add `// lint: allow(R4): <reason>` \
              if the invariant is locally provable",
        applies: library_path,
        skip_tests: true,
        check: check_panics,
    },
    Rule {
        id: "R5",
        summary: "no wildcard `_ =>` match arms in persist/canonical decode code",
        fix: "name every variant (or use an explicit or-pattern) so adding a variant is a \
              compile error here instead of a silent aliasing bug",
        applies: in_decode,
        skip_tests: true,
        check: check_wildcard_arms,
    },
    Rule {
        id: "R6",
        summary: "no HashMap/HashSet in deterministic-output code",
        fix: "use BTreeMap/BTreeSet, or collect and sort explicitly before emitting",
        applies: in_output_sink,
        skip_tests: true,
        check: check_hash_collections,
    },
    Rule {
        id: "R7",
        summary: "no un-sorted read_dir walks in deterministic-output code",
        fix: "collect the entries' paths into a Vec and sort before iterating (read_dir \
              order is filesystem-dependent), or add `// lint: allow(R7): <reason>` \
              where order provably cannot escape",
        applies: in_output_sink,
        skip_tests: true,
        check: check_read_dir,
    },
    Rule {
        id: "R8",
        summary: "persistent-artifact writes must go through util::fsx::write_atomic",
        fix: "replace `fs::write` with `util::fsx::write_atomic` (temp sibling + rename) \
              so a crash mid-write leaves the old file intact instead of a torn one, or \
              add `// lint: allow(R8): <reason>` for a provably throwaway file",
        applies: in_atomic_write_scope,
        skip_tests: true,
        check: check_bare_fs_write,
    },
];

/// Pre-lexed view of one file that checks operate on.
pub struct Scan<'a> {
    pub tokens: Vec<Token<'a>>,
    /// Indices of non-comment tokens, in order: rules reason about
    /// *code* adjacency through this list.
    pub code: Vec<usize>,
}

impl<'a> Scan<'a> {
    pub fn new(src: &'a str) -> Self {
        let tokens = lex(src);
        let code = (0..tokens.len())
            .filter(|&i| tokens[i].kind != TokenKind::Comment)
            .collect();
        Scan { tokens, code }
    }

    /// The token at code position `p`, if any.
    fn at(&self, p: usize) -> Option<&Token<'a>> {
        self.code.get(p).map(|&i| &self.tokens[i])
    }

    fn is_punct(&self, p: usize, text: &str) -> bool {
        self.at(p).is_some_and(|t| t.kind == TokenKind::Punct && t.text == text)
    }
}

fn check_cost_model_use(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind == TokenKind::Ident && (t.text == "CostModel" || t.text == "BaselineModel") {
            out.push((
                scan.code[p],
                format!("direct `{}` use in experiments/ bypasses the sweep engine", t.text),
            ));
        }
    }
}

/// A string literal contains a lossy float format spec: `{:.N…}` or
/// `{:e}`/`{:E}`. Detected inside the literal text so comments and
/// identifiers can mention the syntax freely.
fn lossy_float_spec(text: &str) -> Option<&'static str> {
    let bytes = text.as_bytes();
    for (i, w) in bytes.windows(2).enumerate() {
        if w == b":." && bytes.get(i + 2).is_some_and(u8::is_ascii_digit) {
            return Some("{:.N}");
        }
        if (w == b":e" || w == b":E") && bytes.get(i + 2) == Some(&b'}') {
            return Some("{:e}");
        }
    }
    None
}

fn check_lossy_float_format(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind != TokenKind::Str {
            continue;
        }
        if let Some(spec) = lossy_float_spec(t.text) {
            out.push((
                scan.code[p],
                format!("`{spec}` float formatting in persist-path string literal"),
            ));
        }
    }
}

fn check_panics(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind != TokenKind::Ident {
            continue;
        }
        match t.text {
            "unwrap" | "expect" => {
                let method_call = p > 0
                    && scan.is_punct(p - 1, ".")
                    && scan.is_punct(p + 1, "(");
                if method_call {
                    out.push((scan.code[p], format!("`.{}()` on the library path", t.text)));
                }
            }
            "panic" => {
                if scan.is_punct(p + 1, "!") {
                    out.push((scan.code[p], "`panic!` on the library path".to_string()));
                }
            }
            _ => {}
        }
    }
}

fn check_wildcard_arms(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind == TokenKind::Ident && t.text == "_" && self_is_arrow(scan, p + 1) {
            out.push((
                scan.code[p],
                "wildcard `_ =>` arm in decode/serialization code".to_string(),
            ));
        }
    }
}

fn self_is_arrow(scan: &Scan<'_>, p: usize) -> bool {
    scan.is_punct(p, "=>")
}

fn check_read_dir(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind == TokenKind::Ident && t.text == "read_dir" {
            out.push((
                scan.code[p],
                "`read_dir` in deterministic-output code (entry order is \
                 filesystem-dependent)"
                    .to_string(),
            ));
        }
    }
}

fn check_bare_fs_write(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind == TokenKind::Ident
            && t.text == "write"
            && p >= 2
            && scan.is_punct(p - 1, "::")
            && scan.at(p - 2).is_some_and(|q| q.kind == TokenKind::Ident && q.text == "fs")
        {
            out.push((
                scan.code[p],
                "bare `fs::write` in persistence code (torn file behind a crash)".to_string(),
            ));
        }
    }
}

fn check_hash_collections(scan: &Scan<'_>, out: &mut Vec<(usize, String)>) {
    for p in 0..scan.code.len() {
        let Some(t) = scan.at(p) else { continue };
        if t.kind == TokenKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            out.push((
                scan.code[p],
                format!("`{}` in deterministic-output code (iteration order varies)", t.text),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Test-region detection
// ---------------------------------------------------------------------------

/// Per-token mask: `true` for tokens inside an item annotated
/// `#[test]` or `#[cfg(test)]` (attributes included). Found by token
/// scan: match the attribute, skip any further attributes, then cover
/// through the item's brace-matched body (or its terminating `;`).
pub fn test_region_mask(scan: &Scan<'_>) -> Vec<bool> {
    let mut mask = vec![false; scan.tokens.len()];
    let mut p = 0;
    while p < scan.code.len() {
        let Some(end) = test_item_end(scan, p) else {
            p += 1;
            continue;
        };
        let lo = scan.code[p];
        let hi = scan.code[end.min(scan.code.len() - 1)];
        for slot in mask.iter_mut().take(hi + 1).skip(lo) {
            *slot = true;
        }
        p = end + 1;
    }
    mask
}

/// If code position `p` starts a test attribute, return the code
/// position of the annotated item's last token.
fn test_item_end(scan: &Scan<'_>, p: usize) -> Option<usize> {
    if !is_test_attr(scan, p) {
        return None;
    }
    let mut q = attr_close(scan, p)? + 1;
    // Skip any further attributes on the same item (`#[allow(…)]` etc).
    while scan.is_punct(q, "#") && scan.is_punct(q + 1, "[") {
        q = attr_close(scan, q)? + 1;
    }
    // Find the item body: first `{` or `;` outside parens/brackets
    // (a fn's argument list, generics' brackets).
    let mut depth = 0i32;
    while let Some(t) = scan.at(q) {
        if t.kind == TokenKind::Punct {
            match t.text {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                ";" if depth == 0 => return Some(q),
                "{" if depth == 0 => return brace_close(scan, q),
                _ => {}
            }
        }
        q += 1;
    }
    // Unterminated item: cover to end of file.
    Some(scan.code.len().saturating_sub(1))
}

/// Is `#[test]` or `#[cfg(test)]` at code position `p`?
fn is_test_attr(scan: &Scan<'_>, p: usize) -> bool {
    if !(scan.is_punct(p, "#") && scan.is_punct(p + 1, "[")) {
        return false;
    }
    let Some(close) = attr_close(scan, p) else { return false };
    let inner: Vec<&str> = (p + 2..close)
        .filter_map(|q| scan.at(q).map(|t| t.text))
        .collect();
    inner == ["test"] || inner == ["cfg", "(", "test", ")"]
}

/// Code position of the `]` closing the attribute whose `#` is at `p`.
fn attr_close(scan: &Scan<'_>, p: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut q = p + 1;
    while let Some(t) = scan.at(q) {
        if t.kind == TokenKind::Punct {
            match t.text {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(q);
                    }
                }
                _ => {}
            }
        }
        q += 1;
    }
    None
}

/// Code position of the `}` matching the `{` at code position `open`.
fn brace_close(scan: &Scan<'_>, open: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut q = open;
    while let Some(t) = scan.at(q) {
        if t.kind == TokenKind::Punct {
            match t.text {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(q);
                    }
                }
                _ => {}
            }
        }
        q += 1;
    }
    None
}

// ---------------------------------------------------------------------------
// Allow markers
// ---------------------------------------------------------------------------

struct Allow {
    rule: String,
    /// Line the marker comment starts on.
    marker_line: u32,
    /// First code line at or after the marker (trailing comment: the
    /// marker's own line; leading comment: the line below the comment
    /// block). Diagnostics on either line are suppressed.
    anchor_line: u32,
    used: bool,
}

/// Extract allow markers; malformed ones become diagnostics directly.
fn parse_allows(file: &str, scan: &Scan<'_>) -> (Vec<Allow>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut diags = Vec::new();
    for (i, t) in scan.tokens.iter().enumerate() {
        if t.kind != TokenKind::Comment {
            continue;
        }
        let Some(body) = marker_body(t.text) else { continue };
        let meta = |line: u32, message: String| Diagnostic {
            file: file.to_string(),
            line,
            rule: "lint",
            message,
            help: "marker syntax: `// lint: allow(R4): <reason>` — rule id in parens, \
                   then a colon and a non-empty reason"
                .to_string(),
        };
        match parse_marker(body) {
            Ok((rule, _reason)) => {
                if !RULE_IDS.contains(&rule) {
                    diags.push(meta(t.line, format!("allow marker names unknown rule {rule:?}")));
                    continue;
                }
                // Anchor on the next code token, skipping the rest of
                // a multi-line comment block.
                let anchor_line = scan.tokens[i + 1..]
                    .iter()
                    .find(|n| n.kind != TokenKind::Comment)
                    .map_or(t.line, |n| n.line);
                allows.push(Allow {
                    rule: rule.to_string(),
                    marker_line: t.line,
                    anchor_line,
                    used: false,
                });
            }
            Err(why) => diags.push(meta(t.line, format!("malformed lint marker: {why}"))),
        }
    }
    (allows, diags)
}

/// If `comment` is a marker comment, return the text after `lint:`.
/// Only plain `//` comments qualify — doc comments never do.
fn marker_body(comment: &str) -> Option<&str> {
    let rest = comment.strip_prefix("//")?;
    if rest.starts_with('/') || rest.starts_with('!') {
        return None; // doc comment
    }
    let rest = rest.trim_start();
    rest.strip_prefix("lint:")
}

/// Parse `allow(Rn): reason` (input already past `lint:`).
fn parse_marker(body: &str) -> Result<(&str, &str), String> {
    let body = body.trim();
    let Some(rest) = body.strip_prefix("allow(") else {
        return Err(format!("expected `allow(<rule>)`, got {body:?}"));
    };
    let Some(close) = rest.find(')') else {
        return Err("unclosed `allow(` marker".to_string());
    };
    let rule = rest[..close].trim();
    let tail = rest[close + 1..].trim_start();
    let Some(reason) = tail.strip_prefix(':') else {
        return Err("missing `: <reason>` after allow(…)".to_string());
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Err("empty reason — justify the exemption".to_string());
    }
    Ok((rule, reason))
}

// ---------------------------------------------------------------------------
// Per-file driver
// ---------------------------------------------------------------------------

/// Run every applicable rule over one file's source. `file` is the
/// `/`-separated path relative to the scanned root (scopes key off
/// it). Returns diagnostics sorted by line, then rule.
pub fn check_source(file: &str, src: &str) -> Vec<Diagnostic> {
    let scan = Scan::new(src);
    let mask = test_region_mask(&scan);
    let (mut allows, mut diags) = parse_allows(file, &scan);
    for rule in RULES {
        if !(rule.applies)(file) {
            continue;
        }
        let mut raw = Vec::new();
        (rule.check)(&scan, &mut raw);
        for (token_index, message) in raw {
            if rule.skip_tests && mask[token_index] {
                continue;
            }
            let line = scan.tokens[token_index].line;
            let exempted = allows
                .iter_mut()
                .find(|a| a.rule == rule.id && (a.marker_line == line || a.anchor_line == line));
            if let Some(allow) = exempted {
                allow.used = true;
                continue;
            }
            diags.push(Diagnostic {
                file: file.to_string(),
                line,
                rule: rule.id,
                message,
                help: rule.fix.to_string(),
            });
        }
    }
    for allow in &allows {
        if !allow.used {
            diags.push(Diagnostic {
                file: file.to_string(),
                line: allow.marker_line,
                rule: "lint",
                message: format!(
                    "allow({}) marker never matched a diagnostic — stale exemption",
                    allow.rule
                ),
                help: "delete the marker (or move it onto the line it is meant to exempt)"
                    .to_string(),
            });
        }
    }
    diags.sort_by(|a, b| (a.line, a.rule, &a.message).cmp(&(b.line, b.rule, &b.message)));
    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(file: &str, src: &str) -> Vec<&'static str> {
        check_source(file, src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn r1_fires_only_in_experiments() {
        let src = "pub fn f(s: &CimSystem) { let m = CostModel::new(s); }";
        assert_eq!(rules_fired("rust/src/experiments/fig9.rs", src), vec!["R1"]);
        assert_eq!(rules_fired("rust/src/coordinator/jobs.rs", src), Vec::<&str>::new());
        // Comment and string mentions do not fire (grep would flag both).
        let quiet = "// CostModel is banned here\npub fn f() -> &'static str { \"CostModel\" }";
        assert_eq!(rules_fired("rust/src/experiments/fig9.rs", quiet), Vec::<&str>::new());
    }

    #[test]
    fn r1_covers_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { let _ = BaselineModel::new(); }\n}";
        assert_eq!(rules_fired("rust/src/experiments/fig9.rs", src), vec!["R1"]);
    }

    #[test]
    fn r2_fires_on_lossy_float_specs() {
        let firing = r#"fn enc(x: f64) -> String { format!("{x:.6}") }"#;
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", firing), vec!["R2"]);
        let sci = r#"fn enc(x: f64) -> String { format!("{:e}", x) }"#;
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", sci), vec!["R2"]);
        let clean = r#"fn enc(x: f64) -> String { format!("{:016x}", x.to_bits()) }"#;
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", clean), Vec::<&str>::new());
        // Display sinks are out of scope by design.
        assert_eq!(rules_fired("rust/src/sweep/output.rs", firing), Vec::<&str>::new());
    }

    #[test]
    fn r4_fires_on_unwrap_expect_panic() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", src), vec!["R4"]);
        let src = "fn f(o: Option<u32>) -> u32 { o.expect(\"set\") }";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", src), vec!["R4"]);
        let src = "fn f() { panic!(\"boom\") }";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", src), vec!["R4"]);
    }

    #[test]
    fn r4_skips_main_tests_and_lookalikes() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        assert_eq!(rules_fired("rust/src/main.rs", src), Vec::<&str>::new());
        let test_code = "#[test]\nfn t() { None::<u32>.unwrap(); panic!(\"in test\") }";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", test_code), Vec::<&str>::new());
        // Our own `expect`-named method definitions/calls that are not
        // `.expect(` method calls stay quiet, as do should_panic
        // attributes and `std::panic::catch_unwind`.
        let lookalike = "fn expect(x: u32) -> u32 { expect(x) }\nfn g() { std::panic::catch_unwind(|| 1); }";
        assert_eq!(rules_fired("rust/src/util/json.rs", lookalike), Vec::<&str>::new());
    }

    #[test]
    fn r5_fires_on_wildcard_arms_in_decode_scope() {
        let src = "fn f(x: u32) -> u32 { match x { 0 => 1, _ => 2 } }";
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", src), vec!["R5"]);
        // `_` as a binding or or-pattern member is fine; json.rs (out
        // of scope) keeps its accessor wildcards.
        let clean = "fn f(x: Option<u32>) -> u32 { let _ = 3; match x { Some(v) => v, None => 0 } }";
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", clean), Vec::<&str>::new());
        assert_eq!(rules_fired("rust/src/util/json.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn r6_fires_on_hash_collections_in_sink_scope() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        let fired = rules_fired("rust/src/util/csv.rs", src);
        assert!(fired.iter().all(|r| *r == "R6") && !fired.is_empty());
        assert_eq!(rules_fired("rust/src/sweep/cache.rs", src), Vec::<&str>::new());
        let clean = "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u32, u32> = BTreeMap::new(); }";
        assert_eq!(rules_fired("rust/src/util/csv.rs", clean), Vec::<&str>::new());
    }

    #[test]
    fn r7_fires_on_read_dir_in_sink_scope() {
        let src = "fn f(d: &std::path::Path) {\n    for e in std::fs::read_dir(d).unwrap() {\n        drop(e);\n    }\n}";
        let fired = rules_fired("rust/src/sweep/output.rs", src);
        // read_dir fires R7; the unwrap alongside it fires R4.
        assert!(fired.contains(&"R7"), "{fired:?}");
        // Out of sink scope: no R7 (walking a dir for internal state
        // is fine; only deterministic-output code is pinned).
        let elsewhere = rules_fired("rust/src/mapping/priority.rs", src);
        assert!(!elsewhere.contains(&"R7"), "{elsewhere:?}");
        // Sorting after collecting is the idiom — no read_dir token,
        // nothing fires.
        let clean = "fn f(paths: &mut Vec<std::path::PathBuf>) {\n    paths.sort();\n}";
        assert_eq!(rules_fired("rust/src/sweep/output.rs", clean), Vec::<&str>::new());
        // An allow marker with a reason exempts a provably-sorted walk.
        let allowed = "fn f(d: &std::path::Path) -> std::io::Result<()> {\n    // lint: allow(R7): entries are collected and sorted two lines down\n    let it = std::fs::read_dir(d)?;\n    drop(it);\n    Ok(())\n}";
        assert_eq!(rules_fired("rust/src/sweep/output.rs", allowed), Vec::<&str>::new());
    }

    #[test]
    fn r8_fires_on_bare_fs_write_in_persistence_scope() {
        let src = "fn f(p: &std::path::Path) -> std::io::Result<()> {\n    std::fs::write(p, \"x\")\n}";
        let fired = rules_fired("rust/src/sweep/persist.rs", src);
        assert!(fired.contains(&"R8"), "{fired:?}");
        // The serve tree is covered by prefix, not by an explicit list entry.
        let fired = rules_fired("rust/src/serve/handler.rs", src);
        assert!(fired.contains(&"R8"), "{fired:?}");
        // Out of scope: fsx.rs itself hosts the one sanctioned fs::write.
        let elsewhere = rules_fired("rust/src/util/fsx.rs", src);
        assert!(!elsewhere.contains(&"R8"), "{elsewhere:?}");
        // The replacement idiom and non-path `write` idents stay quiet.
        let clean = "fn f(p: &std::path::Path) -> anyhow::Result<()> {\n    crate::util::fsx::write_atomic(p, \"x\")\n}";
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", clean), Vec::<&str>::new());
        let method = "fn f(w: &mut dyn std::io::Write, b: &[u8]) { let _ = w.write(b); }";
        let fired = rules_fired("rust/src/sweep/persist.rs", method);
        assert!(!fired.contains(&"R8"), "{fired:?}");
        // An allow marker with a reason exempts a throwaway file.
        let allowed = "fn f(p: &std::path::Path) -> std::io::Result<()> {\n    // lint: allow(R8): scratch probe file, never reloaded\n    std::fs::write(p, \"x\")\n}";
        assert_eq!(rules_fired("rust/src/sweep/persist.rs", allowed), Vec::<&str>::new());
    }

    #[test]
    fn allow_marker_suppresses_same_line_and_next_code_line() {
        let trailing = "fn f(o: Option<u32>) -> u32 { o.unwrap() } // lint: allow(R4): fixture\n";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", trailing), Vec::<&str>::new());
        let leading = "fn f(o: Option<u32>) -> u32 {\n    // lint: allow(R4): fixture\n    o.unwrap()\n}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", leading), Vec::<&str>::new());
        // A multi-line comment block between marker and code still anchors.
        let block = "fn f(o: Option<u32>) -> u32 {\n    // lint: allow(R4): fixture reason\n    // spanning two comment lines\n    o.unwrap()\n}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", block), Vec::<&str>::new());
        // One trailing marker covers chained calls continuing on the next line.
        let chained = "fn f(a: Option<u32>, b: Option<u32>) -> u32 {\n    a.unwrap() // lint: allow(R4): both halves of one probe\n        + b.unwrap()\n}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", chained), Vec::<&str>::new());
    }

    #[test]
    fn allow_marker_hygiene_is_enforced() {
        // Wrong rule id: original diagnostic stands AND the marker is
        // stale (sorted by line: marker on 2, unwrap on 3).
        let wrong = "fn f(o: Option<u32>) -> u32 {\n    // lint: allow(R5): wrong rule\n    o.unwrap()\n}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", wrong), vec!["lint", "R4"]);
        // Unknown rule id.
        let unknown = "// lint: allow(R99): nope\nfn f() {}";
        let diags = check_source("rust/src/cost/mod.rs", unknown);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("unknown rule"));
        // Missing reason.
        let bare = "fn f(o: Option<u32>) -> u32 { o.unwrap() } // lint: allow(R4)";
        let diags = check_source("rust/src/cost/mod.rs", bare);
        assert!(diags.iter().any(|d| d.rule == "lint" && d.message.contains("malformed")));
        assert!(diags.iter().any(|d| d.rule == "R4"));
        // Unused marker.
        let stale = "// lint: allow(R4): nothing here anymore\nfn f() {}";
        let diags = check_source("rust/src/cost/mod.rs", stale);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("never matched"));
    }

    #[test]
    fn doc_comments_are_not_markers() {
        let src = "/// Exempt sites with `// lint: allow(R4): reason`.\nfn f() {}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", src), Vec::<&str>::new());
        let inner = "//! lint: allow(R4): module doc, not a marker\nfn f() {}";
        assert_eq!(rules_fired("rust/src/cost/mod.rs", inner), Vec::<&str>::new());
    }

    #[test]
    fn cfg_test_region_covers_nested_items_and_stops() {
        let src = "\
fn live(o: Option<u32>) -> u32 { o.unwrap() }
#[cfg(test)]
mod tests {
    fn helper(o: Option<u32>) -> u32 { o.unwrap() }
    #[test]
    fn t() { assert_eq!(helper(Some(1)), 1); }
}
fn also_live(o: Option<u32>) -> u32 { o.unwrap() }
";
        let diags = check_source("rust/src/cost/mod.rs", src);
        let lines: Vec<u32> = diags.iter().map(|d| d.line).collect();
        assert_eq!(lines, vec![1, 8], "only the two non-test unwraps fire");
    }

    #[test]
    fn diagnostics_render_with_location_rule_and_fix() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let diags = check_source("rust/src/cost/mod.rs", src);
        let text = diags[0].render();
        assert!(text.starts_with("rust/src/cost/mod.rs:1: [R4] "));
        assert!(text.contains("fix: "));
    }
}
