//! `repro` — the www-cim command-line leader.
//!
//! Subcommands:
//! * `evaluate`    — one GEMM on one system, full metric breakdown
//! * `compare`     — one GEMM across baseline + all primitives
//! * `run`         — execute any scenario: a `*.json` file or a
//!   built-in name (every experiment id + the default sweep)
//! * `orchestrate` — run a sweep scenario as n shard subprocesses and
//!   merge on completion (multi-process sweeps in one command)
//! * `sweep`       — grid flags parsed into a scenario and executed
//!   (`--emit-scenario` writes the scenario instead of running it)
//! * `merge`       — combine per-shard sweep summaries into one result
//! * `serve`       — persistent warm-cache evaluation daemon (JSON
//!   protocol over TCP; see `rust/src/serve/README.md`)
//! * `query`       — client for a running `serve` daemon
//! * `experiment`  — regenerate a paper table/figure (`all` for every one)
//! * `validate`    — replay mappings through the PJRT artifacts
//! * `roofline`    — ridge-point analysis
//! * `bench`       — in-process benchmark suite (`--json` for
//!   machine-readable results)
//! * `lint`        — static analysis over the repo's own sources
//! * `list`        — primitives / workloads / experiments / scenarios
//!
//! Dispatch and the usage text both derive from the [`SUBCOMMANDS`]
//! table, and experiment listings from [`experiments::REGISTRY`], so
//! neither can drift from what actually runs (the ISSUE 4 bug class).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::validate::validate_mappings;
use www_cim::cost::{BaselineModel, CostModel, Metrics};
use www_cim::experiments;
use www_cim::lint;
use www_cim::mapping::PriorityMapper;
use www_cim::roofline::Roofline;
use www_cim::runtime::{default_artifacts_dir, Engine};
use www_cim::scenario::{self, exec, Scenario, ScenarioKind};
use www_cim::serve::{self, Client, RetryPolicy, ServeOptions, Server};
use www_cim::sweep::{output, shard, spec, EvalCache, ShardId};
use www_cim::util::bench::Bencher;
use www_cim::util::cli::Args;
use www_cim::util::fsx;
use www_cim::util::json::Json;
use www_cim::util::table::Table;
use www_cim::workload::{synthetic, Gemm};

/// Flags whose value is optional: bare `--cache` / `--emit-scenario`
/// record presence (the conventional default path / stdout) without
/// consuming the next token, so `repro run --cache fig2` keeps `fig2`
/// as the scenario name. An explicit value is `--flag=value`.
const OPTIONAL_VALUE_FLAGS: &[&str] = &["cache", "emit-scenario"];

fn main() {
    let args = Args::from_env_with_optional(OPTIONAL_VALUE_FLAGS);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// One CLI subcommand. Dispatch and the usage text are both generated
/// from [`SUBCOMMANDS`], so a subcommand cannot exist without a help
/// entry or vice versa (the ISSUE 4 missing-ids bug class, applied to
/// subcommands).
struct Subcommand {
    name: &'static str,
    /// Usage block lines: the first continues the `  name ` column,
    /// the rest are indented under it. `{builtins}`/`{experiments}`
    /// expand to the registry-derived id listings.
    usage: &'static [&'static str],
    run: fn(&Args) -> Result<()>,
}

/// Every subcommand, in help order.
const SUBCOMMANDS: &[Subcommand] = &[
    Subcommand {
        name: "evaluate",
        usage: &["--gemm MxNxK [--prim d1|d2|a1|a2] [--level rf|smem] [--smem-config a|b]"],
        run: cmd_evaluate,
    },
    Subcommand {
        name: "compare",
        usage: &["--gemm MxNxK"],
        run: cmd_compare,
    },
    Subcommand {
        name: "run",
        usage: &[
            "<scenario.json|name> [--shard i/n] [--quick] [--seed N]",
            "[--threads N] [--out dir] [--tag name] [--json]",
            "[--cache[=results/cache.bin]] [--cache-max-mb N]",
            "(executes any scenario; built-in names:",
            " {builtins})",
        ],
        run: cmd_run,
    },
    Subcommand {
        name: "orchestrate",
        usage: &[
            "<scenario.json|name> [--procs n] [--shard-timeout-s N]",
            "[--shard-retries N] [--resume] [+ run's overrides]",
            "(spawns n supervised shard subprocesses of the sweep",
            " scenario — timeout + retry + resume — merges their",
            " results on completion, and writes a run manifest)",
        ],
        run: cmd_orchestrate,
    },
    Subcommand {
        name: "sweep",
        usage: &[
            "[--workloads all|real|bert,gptj,...|synthetic[:N]]",
            "[--prims baseline,all|d1,d2,a1,a2] [--levels rf,smem-a,smem-b]",
            "[--sms 1,2,4] [--batch 1,4,16,64] [--threads N]",
            "[--mapper priority|priority:t<n>|priority:order-<mnk perm>|",
            "          dup[:t<n>]|heuristic[:budget]|",
            "          exhaustive[:energy|delay|edp]]",
            "[--seed N] [--out results] [--tag name] [--json]",
            "[--cache[=results/cache.bin]] [--cache-max-mb N] [--shard i/n]",
            "[--emit-scenario[=file.json]]",
            "(defaults sweep the full zoo x 13 systems, >= 500 points;",
            " --batch expands every workload at each batch size,",
            " --cache persists the memo cache across runs with an",
            " optional LRU size cap, --shard runs one deterministic",
            " 1/n slice, --emit-scenario writes the equivalent",
            " scenario instead of running)",
        ],
        run: cmd_sweep,
    },
    Subcommand {
        name: "merge",
        usage: &["<shard.json> <shard.json> ... [--tag name] [--out results] [--json]"],
        run: cmd_merge,
    },
    Subcommand {
        name: "serve",
        usage: &[
            "[--addr 127.0.0.1:7878] [--workers N] [--queue N]",
            "[--cache[=results/cache.bin]] [--cache-max-mb N]",
            "(persistent warm-cache evaluation daemon: newline-delimited",
            " JSON ops eval/ping/stats/flush/shutdown over TCP; drains",
            " in-flight requests and flushes the cache on SIGTERM —",
            " protocol spec in rust/src/serve/README.md)",
        ],
        run: cmd_serve,
    },
    Subcommand {
        name: "query",
        usage: &[
            "<scenario.json|name> [--addr 127.0.0.1:7878] [--op eval|ping|",
            "stats|flush|shutdown] [--out results] [--tag name]",
            "[--threads N] [--seed N]",
            "(client for a running serve daemon; eval writes the",
            " response rows as <out>/<name>.csv, byte-identical to",
            " what `repro run` produces for the same scenario)",
        ],
        run: cmd_query,
    },
    Subcommand {
        name: "experiment",
        usage: &[
            "<{experiments}>",
            "[--quick] [--out results] [--threads N] [--seed N]",
            "[--cache[=results/cache.bin]] [--cache-max-mb N]",
        ],
        run: cmd_experiment,
    },
    Subcommand {
        name: "validate",
        usage: &["[--artifacts artifacts] [--seed N]"],
        run: cmd_validate,
    },
    Subcommand {
        name: "roofline",
        usage: &["(ridge-point analysis per system)"],
        run: cmd_roofline,
    },
    Subcommand {
        name: "bench",
        usage: &[
            "[--json[=BENCH_sweep.json]] [--samples N] [--warmup N]",
            "(in-process benchmark suite: cold/warm sweep and cold/warm",
            " serve round-trips; --json writes machine-readable results",
            " for perf tracking)",
        ],
        run: cmd_bench,
    },
    Subcommand {
        name: "lint",
        usage: &[
            "[--fix-guards] [--rules] [path]",
            "(static analysis over rust/src: determinism, versioning and",
            " cache-correctness rules R1-R8 — see rust/src/lint/README.md;",
            " --fix-guards refreshes the version-guard manifest after a",
            " legitimate version bump, --rules prints the rule table)",
        ],
        run: cmd_lint,
    },
    Subcommand {
        name: "list",
        usage: &["(primitives / workloads / experiments / built-in scenarios)"],
        run: cmd_list,
    },
];

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some(name) => match SUBCOMMANDS.iter().find(|s| s.name == name) {
            Some(sub) => (sub.run)(args),
            None => bail!("unknown subcommand {name:?} — try `repro list`"),
        },
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Wrap a `|`-separated id list at `width` columns with a hanging
/// indent (usage-text formatting for the registry-derived listings).
fn wrap_ids(ids: &[&str], indent: usize, width: usize) -> String {
    let mut lines: Vec<String> = Vec::new();
    let mut line = String::new();
    for id in ids {
        if !line.is_empty() && indent + line.len() + 1 + id.len() > width {
            // Keep the alternation syntax intact across the break: the
            // finished line ends with its separator.
            line.push('|');
            lines.push(std::mem::take(&mut line));
        }
        if !line.is_empty() {
            line.push('|');
        }
        line.push_str(id);
    }
    if !line.is_empty() {
        lines.push(line);
    }
    lines.join(&format!("\n{}", " ".repeat(indent)))
}

/// The usage text, generated from [`SUBCOMMANDS`] (so no subcommand
/// can be missing from help) with experiment/scenario ids expanded
/// from their registries (so no runnable id can be missing either —
/// the regression ISSUE 4 fixed: `optimality`, `scaling`, `zoo`, …
/// used to be hand-listed and absent).
fn usage() -> String {
    let mut exp_ids: Vec<&str> = experiments::ids();
    exp_ids.push("all");
    let mut body = String::new();
    for sub in SUBCOMMANDS {
        for (i, line) in sub.usage.iter().enumerate() {
            let formatted = if i == 0 {
                format!("  {:<11} {line}", sub.name)
            } else {
                format!("              {line}")
            };
            body.push_str(formatted.trim_end());
            body.push('\n');
        }
    }
    let body = body
        .replace("{builtins}", &wrap_ids(&scenario::builtin_names(), 15, 76))
        .replace("{experiments}", &wrap_ids(&exp_ids, 15, 76));
    format!(
        "repro — WWW: What, When, Where to Compute-in-Memory (reproduction)\n\n\
         usage: repro <subcommand> [options]\n\n{}",
        body.trim_end()
    )
}

fn parse_gemm(s: &str) -> Result<Gemm> {
    let dims: Vec<u64> = s
        .split(['x', 'X', ','])
        .map(|d| d.parse().context("GEMM dims must be integers"))
        .collect::<Result<Vec<_>>>()?;
    if dims.len() != 3 {
        bail!("--gemm wants MxNxK, got {s:?}");
    }
    Ok(Gemm::new(dims[0], dims[1], dims[2]))
}

fn parse_system(args: &Args, arch: &Architecture) -> Result<Option<CimSystem>> {
    let prim_name = args.get_or("prim", "d1");
    if prim_name == "baseline" || prim_name == "tcore" {
        return Ok(None);
    }
    let prim = CimPrimitive::parse(prim_name)
        .with_context(|| format!("unknown primitive {prim_name:?} (d1,d2,a1,a2)"))?;
    let level = MemLevel::parse(args.get_or("level", "rf"))
        .context("--level must be rf or smem")?;
    let sys = match level {
        MemLevel::Smem => {
            let cfg = match args.get_or("smem-config", "b") {
                "a" | "A" => SmemConfig::ConfigA,
                "b" | "B" => SmemConfig::ConfigB,
                other => bail!("--smem-config must be a or b, got {other:?}"),
            };
            CimSystem::at_smem(arch, prim, cfg)
        }
        MemLevel::RegisterFile => CimSystem::at_level(arch, prim, level),
        other => bail!("CiM integrates at rf or smem, not {other}"),
    };
    Ok(Some(sys))
}

fn metrics_table(rows: &[(String, Metrics)]) -> Table {
    let mut t = Table::new(vec![
        "system", "TOPS/W", "GFLOPS", "util", "fJ/MAC", "cycles", "bound",
    ]);
    for (name, m) in rows {
        t.row(vec![
            name.clone(),
            format!("{:.3}", m.tops_per_watt),
            format!("{:.0}", m.gflops),
            format!("{:.2}", m.utilization),
            format!("{:.0}", m.fj_per_mac()),
            m.total_cycles.to_string(),
            if m.memory_bound() { "memory" } else { "compute" }.to_string(),
        ]);
    }
    t
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    if let Some(err) =
        args.unknown_flags(&["gemm", "prim", "level", "smem-config", "verbose"])
    {
        bail!(err);
    }
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    match parse_system(args, &arch)? {
        None => {
            let m = BaselineModel::new(&arch).evaluate(&gemm);
            print!("{}", metrics_table(&[("Tensor-core".into(), m)]));
        }
        Some(sys) => {
            let mapping = PriorityMapper::new(&sys).map(&gemm);
            let m = CostModel::new(&sys).evaluate(&gemm, &mapping);
            print!("{}", metrics_table(&[(sys.label(), m)]));
            if args.flag("verbose") {
                println!("mapping: {}", mapping.describe());
                let b = &m.breakdown;
                println!(
                    "energy pJ: dram={:.0} smem={:.0} rf={:.0} mac={:.0} red={:.0}",
                    b.dram_pj, b.smem_pj, b.rf_pj, b.mac_pj, b.reduction_pj
                );
            }
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    let mut rows = vec![(
        "Tensor-core".to_string(),
        BaselineModel::new(&arch).evaluate(&gemm),
    )];
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        let m = CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm));
        rows.push((sys.label(), m));
    }
    println!("{gemm} across systems (RF, iso-area):");
    print!("{}", metrics_table(&rows));
    Ok(())
}

/// `--cache [path]` — the persistent sweep cache location. A bare
/// `--cache` uses the conventional `results/cache.bin`.
fn cache_path_flag(args: &Args) -> Option<PathBuf> {
    args.get("cache").map(|v| {
        if v == "true" {
            PathBuf::from("results/cache.bin")
        } else {
            PathBuf::from(v)
        }
    })
}

/// `--cache-max-mb N` — the persisted cache's LRU size cap, in MiB.
fn cache_cap_flag(args: &Args) -> Result<Option<u64>> {
    match args.get("cache-max-mb") {
        None => Ok(None),
        Some(v) => {
            let bytes = v
                .parse::<u64>()
                .ok()
                .filter(|mb| *mb >= 1)
                .and_then(|mb| mb.checked_mul(1024 * 1024))
                .with_context(|| {
                    format!("--cache-max-mb wants a positive integer of MiB, got {v:?}")
                })?;
            Ok(Some(bytes))
        }
    }
}

/// Resolve a `repro run`/`repro orchestrate` target. Anything that
/// looks like a path (a `.json` suffix or a separator) is a scenario
/// file; otherwise built-in names win — a stray file or directory in
/// the working directory that happens to share a name (say, a `fig2`
/// output dir) must not shadow the built-in — and only then is a bare
/// existing filename tried.
fn resolve_scenario(target: &str) -> Result<Scenario> {
    let path = Path::new(target);
    let looks_like_path = target.ends_with(".json")
        || target.contains('/')
        || target.contains(std::path::MAIN_SEPARATOR);
    if looks_like_path {
        return Scenario::from_json_file(path);
    }
    if scenario::builtin_names().contains(&target) {
        return scenario::builtin(target);
    }
    if path.is_file() {
        return Scenario::from_json_file(path);
    }
    // Not a builtin, not a file: report the builtin listing.
    scenario::builtin(target)
}

/// Apply the CLI override flags shared by `run` and `orchestrate` on
/// top of a resolved scenario.
fn apply_overrides(sc: &mut Scenario, args: &Args) -> Result<()> {
    if let Some(dir) = args.get("out") {
        sc.output.dir = PathBuf::from(dir);
    }
    if let Some(tag) = args.get("tag") {
        sc.output.tag = Some(tag.to_string());
    }
    if let Some(t) = args.get("threads") {
        sc.threads = Some(t.parse().context("--threads wants a positive integer")?);
    }
    if let Some(s) = args.get("seed") {
        sc.seed = s.parse().context("--seed wants an integer")?;
    }
    if args.flag("quick") {
        match &mut sc.kind {
            ScenarioKind::Experiment { quick, .. } => *quick = true,
            ScenarioKind::Sweep(_) => bail!("--quick applies to experiment scenarios"),
        }
    }
    if let Some(path) = cache_path_flag(args) {
        sc.cache.path = Some(path);
    }
    if let Some(cap) = cache_cap_flag(args)? {
        sc.cache.max_bytes = Some(cap);
    }
    if args.flag("json") {
        sc.output.stdout_json = true;
    }
    sc.validate()
}

/// `repro run <scenario.json|name>` — execute any scenario: a file, or
/// a built-in (every experiment id plus the default sweep).
fn cmd_run(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "shard", "out", "tag", "threads", "seed", "quick", "cache", "cache-max-mb", "json",
    ]) {
        bail!(err);
    }
    let target = args.positional.first().context(
        "usage: repro run <scenario.json|name> [--shard i/n] [--out dir] [--tag name] \
         [--quick] [--seed N] [--threads N] [--cache[=path]] [--cache-max-mb N] [--json] \
         — `repro list` names the built-in scenarios",
    )?;
    let mut sc = resolve_scenario(target)?;
    apply_overrides(&mut sc, args)?;
    let shard_id = args.get("shard").map(ShardId::parse).transpose()?;
    scenario::exec::execute(&sc, shard_id)
}

/// `repro orchestrate <scenario.json|name> --procs n` — multi-process
/// sweeps in one command: spawn the shard subprocesses (supervised:
/// per-shard timeout, retries with backoff, `--resume`), merge on
/// completion, and write the `<base>.orchestrate.json` run manifest.
fn cmd_orchestrate(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "procs",
        "out",
        "tag",
        "threads",
        "seed",
        "cache",
        "cache-max-mb",
        "json",
        "shard-timeout-s",
        "shard-retries",
        "resume",
    ]) {
        bail!(err);
    }
    let target = args.positional.first().context(
        "usage: repro orchestrate <scenario.json|name> [--procs n] [--out dir] [--tag name] \
         [--shard-timeout-s N] [--shard-retries N] [--resume]",
    )?;
    let mut sc = resolve_scenario(target)?;
    apply_overrides(&mut sc, args)?;
    let procs = match args.get("procs") {
        Some(v) => v
            .parse()
            .ok()
            .filter(|p| *p >= 1)
            .with_context(|| format!("--procs wants a positive integer, got {v:?}"))?,
        // The scenario's shard plan, else every shard in one process
        // would be pointless — default to 2.
        None => sc.shards.unwrap_or(2),
    };
    // Supervision defaults come from the scenario's orchestrate block;
    // the flags override per invocation.
    let mut opts = scenario::orchestrate::OrchestrateOptions::from_scenario(&sc, procs);
    if let Some(t) = args.get("shard-timeout-s") {
        let secs: u64 = t
            .parse()
            .ok()
            .filter(|s| *s >= 1)
            .with_context(|| format!("--shard-timeout-s wants a positive integer, got {t:?}"))?;
        opts.timeout = Some(std::time::Duration::from_secs(secs));
    }
    if let Some(r) = args.get("shard-retries") {
        opts.retries = r
            .parse()
            .with_context(|| format!("--shard-retries wants an integer, got {r:?}"))?;
    }
    opts.resume = args.flag("resume");
    scenario::orchestrate::orchestrate_scenario(&sc, &opts)
}

/// Construct the scenario `repro sweep`'s grid flags describe — the
/// thin-parser half of the sweep command (ISSUE 4: flags build a
/// [`Scenario`]; execution is the scenario path for both).
fn scenario_from_sweep_flags(args: &Args) -> Result<Scenario> {
    let seed = args.get_parsed_or("seed", synthetic::DEFAULT_SEED)?;
    // Grid axes (singular flags are aliases for the plural ones).
    let workloads = args
        .get("workloads")
        .or_else(|| args.get("workload"))
        .unwrap_or(spec::DEFAULT_WORKLOADS);
    let prims = args
        .get("prims")
        .or_else(|| args.get("prim"))
        .unwrap_or(spec::DEFAULT_PRIMS);
    let levels = args
        .get("levels")
        .or_else(|| args.get("level"))
        .unwrap_or(spec::DEFAULT_LEVELS);

    let mut b = Scenario::builder("sweep")
        .workloads(workloads)
        .prims(prims)
        .levels(levels)
        .sms(args.get_or("sms", "1"))
        .batch(args.get_or("batch", "1"))
        .mapper(args.get_or("mapper", "priority"))
        .seed(seed)
        .out_dir(Path::new(args.get_or("out", "results")))
        .stdout_json(args.flag("json"));
    if let Some(t) = args.get("threads") {
        b = b.threads(t.parse().context("--threads wants a positive integer")?);
    }
    if let Some(tag) = args.get("tag") {
        b = b.tag(tag);
    }
    if let Some(path) = cache_path_flag(args) {
        b = b.cache_path(&path);
    }
    if let Some(cap) = cache_cap_flag(args)? {
        b = b.cache_max_bytes(cap);
    }
    b.build()
}

/// `repro sweep` — the design-space sweep engine on the CLI: cartesian
/// grid flags parsed into a [`Scenario`] and executed through the
/// scenario path (CSV + JSON mirrors, `--cache [path]` persistence
/// with an optional `--cache-max-mb` LRU cap, deterministic
/// `--shard i/n` slicing). `--emit-scenario [file]` writes the
/// constructed scenario (stdout without a file) instead of running it.
fn cmd_sweep(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "workload", "workloads", "prim", "prims", "level", "levels", "sms", "batch",
        "threads", "mapper", "seed", "out", "json", "cache", "cache-max-mb", "shard",
        "tag", "emit-scenario",
    ]) {
        bail!(err);
    }
    let sc = scenario_from_sweep_flags(args)?;
    if let Some(dest) = args.get("emit-scenario") {
        if args.get("shard").is_some() {
            // A scenario describes the *whole* grid; the slice is a
            // run-time argument (`repro run <file> --shard i/n`).
            // Dropping the flag silently would emit a scenario that
            // reruns the full grid.
            bail!(
                "--emit-scenario captures the full grid; pass --shard to \
                 `repro run` (or use `repro orchestrate`) instead"
            );
        }
        if dest == "true" {
            print!("{}", sc.to_json());
        } else {
            sc.write(Path::new(dest))?;
            println!("[scenario] -> {dest} (execute with `repro run {dest}`)");
        }
        return Ok(());
    }
    let shard_id = args.get("shard").map(ShardId::parse).transpose()?;
    scenario::exec::execute(&sc, shard_id)
}

/// `repro merge` — validate and combine per-shard sweep summaries into
/// the unsharded sweep.csv/sweep.json (byte-identical CSV).
fn cmd_merge(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&["out", "tag", "json"]) {
        bail!(err);
    }
    if args.positional.is_empty() {
        bail!("usage: repro merge <shard.json> <shard.json> ... [--tag name] [--out results]");
    }
    let paths: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    let merged = shard::merge_files(&paths)?;
    println!(
        "merged {} shard(s) of sweep {:?}: {} points (fingerprint {})",
        merged.shard_count,
        merged.spec_name,
        merged.results.len(),
        merged.fingerprint
    );
    print!("{}", output::summary_table(&merged.results));

    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    let base = args.get_or("tag", &merged.spec_name).to_string();
    let csv = output::results_csv(&merged.results)?;
    let csv_path = out_dir.join(format!("{base}.csv"));
    csv.write(&csv_path)?;
    println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
    // csv.write above already created out_dir.
    let json_path = out_dir.join(format!("{base}.json"));
    fsx::write_atomic(&json_path, &shard::merged_json(&merged))?;
    println!("[json] merged summary -> {}", json_path.display());
    if args.flag("json") {
        print!("{}", shard::merged_json(&merged));
    }
    Ok(())
}

/// `repro serve` — the persistent warm-cache evaluation daemon. Owns
/// the calling thread until drained (SIGTERM/SIGINT or a `shutdown`
/// op), then flushes the cache under the save lock and returns.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(err) =
        args.unknown_flags(&["addr", "workers", "queue", "cache", "cache-max-mb"])
    {
        bail!(err);
    }
    let defaults = ServeOptions::default();
    let workers = args.get_parsed_or("workers", defaults.workers)?;
    let opts = ServeOptions {
        addr: args.get_or("addr", &defaults.addr).to_string(),
        workers,
        queue_depth: args.get_parsed_or("queue", workers * 2)?,
        cache_path: cache_path_flag(args),
        cache_max_bytes: cache_cap_flag(args)?,
        // The CLI daemon drains on real signals; in-process servers
        // (tests, bench) use the shutdown op instead.
        watch_signals: true,
        quiet: false,
    };
    if opts.workers == 0 {
        bail!("--workers wants a positive integer");
    }
    Server::bind(opts)?.run()
}

/// `repro query` — client for a running serve daemon. `eval` writes
/// the streamed rows as `<out>/<name>.csv` (byte-identical to `repro
/// run`'s CSV for the same scenario); the other ops print the daemon's
/// response line. `--retries`/`--backoff-ms`/`--deadline-ms` configure
/// the deterministic retry policy for transient failures (busy daemon,
/// refused connection, torn response).
fn cmd_query(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "addr", "op", "out", "tag", "threads", "seed", "retries", "backoff-ms",
        "deadline-ms",
    ]) {
        bail!(err);
    }
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let policy = RetryPolicy {
        retries: args.get_parsed_or("retries", RetryPolicy::default().retries)?,
        backoff_ms: args.get_parsed_or("backoff-ms", RetryPolicy::default().backoff_ms)?,
        deadline_ms: args
            .get_parsed_or("deadline-ms", RetryPolicy::default().deadline_ms)?,
    };
    let op = args.get_or("op", "eval");
    match op {
        "ping" | "stats" | "flush" | "shutdown" => {
            let response = serve::simple_with_retry(addr, op, &policy)?;
            println!("{}", response.encode_compact());
            Ok(())
        }
        "eval" => {
            let target = args.positional.first().context(
                "usage: repro query <scenario.json|name> [--addr host:port] [--op eval|\
                 ping|stats|flush|shutdown] [--out dir] [--tag name] [--threads N] \
                 [--seed N] [--retries N] [--backoff-ms N] [--deadline-ms N]",
            )?;
            let mut sc = resolve_scenario(target)?;
            apply_overrides(&mut sc, args)?;
            let response = serve::eval_with_retry(addr, &sc, &policy)?;
            let stat = |key: &str| {
                response.stats.get(key).and_then(Json::as_u64).unwrap_or(0)
            };
            println!(
                "[serve] eval {:?}: {} points in {:.3}s",
                response.name,
                stat("points"),
                stat("elapsed_us") as f64 / 1e6,
            );
            // Same accounting shape as the batch paths; the CI warm
            // pass greps for "0 misses" and "0 mapper call(s)" here.
            println!(
                "[serve] run stats: {} hits / {} misses, {} mapper call(s)",
                stat("hits"),
                stat("misses"),
                stat("mapper_calls"),
            );
            let out_dir = PathBuf::from(args.get_or("out", "results"));
            let csv_path = out_dir.join(format!("{}.csv", response.name));
            fsx::write_atomic(&csv_path, &response.csv)?;
            println!(
                "[csv] {} rows -> {}",
                response.csv.lines().count().saturating_sub(1),
                csv_path.display()
            );
            Ok(())
        }
        other => bail!(
            "--op {other:?} is not a serve op (expected eval, ping, stats, flush \
             or shutdown)"
        ),
    }
}

/// `repro experiment <id|all>` — kept as the familiar spelling; the
/// flags construct an experiment [`Scenario`] and execution goes
/// through the same scenario path as `repro run <id>`, so the two are
/// byte-identical by construction (and pinned by the golden
/// equivalence suite).
fn cmd_experiment(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "quick", "out", "threads", "seed", "cache", "cache-max-mb",
    ]) {
        bail!(err);
    }
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut b = Scenario::builder(id)
        .experiment(id)
        .quick(args.flag("quick"))
        .seed(args.get_parsed_or("seed", synthetic::DEFAULT_SEED)?)
        .out_dir(Path::new(args.get_or("out", "results")));
    if let Some(t) = args.get("threads") {
        b = b.threads(t.parse().context("--threads wants a positive integer")?);
    }
    if let Some(path) = cache_path_flag(args) {
        b = b.cache_path(&path);
    }
    if let Some(cap) = cache_cap_flag(args)? {
        b = b.cache_max_bytes(cap);
    }
    scenario::exec::execute(&b.build()?, None)
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let engine = Engine::load(&dir)?;
    println!(
        "PJRT platform: {}, {} artifacts",
        engine.platform(),
        engine.manifest().len()
    );
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let gemms = [
        Gemm::new(64, 32, 256),
        Gemm::new(128, 32, 512),
        Gemm::new(16, 64, 64),
        Gemm::new(100, 48, 300), // awkward non-divisible shape
        Gemm::new(1, 64, 256),   // GEMV
    ];
    let seed = args.get_parsed_or("seed", 7u64)?;
    let report = validate_mappings(&engine, &sys, &gemms, seed)?;
    let mut t = Table::new(vec!["GEMM", "kernel calls", "|diff| oracle", "|diff| artifact"]);
    for c in &report.cases {
        t.row(vec![
            c.gemm.to_string(),
            c.kernel_calls.to_string(),
            c.diff_vs_oracle.to_string(),
            c.diff_vs_full_artifact
                .map(|d| d.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{t}");
    if report.all_exact() {
        println!("validation OK: every mapped dataflow is bit-exact");
        Ok(())
    } else {
        bail!("validation FAILED: mapped execution diverges from the oracle")
    }
}

fn cmd_roofline(_args: &Args) -> Result<()> {
    let arch = Architecture::default_sm();
    let mut t = Table::new(vec!["system", "peak GOPS", "ridge SMEM", "ridge DRAM"]);
    t.row(vec![
        "Tensor-core".to_string(),
        format!("{:.0}", arch.tensor_core.peak_gops()),
        format!("{:.1}", arch.tensor_core.peak_gops() / 42.0),
        format!("{:.1}", arch.tensor_core.peak_gops() / 32.0),
    ]);
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        t.row(vec![
            sys.label(),
            format!("{:.0}", sys.peak_gops()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Smem).ridge_point()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Dram).ridge_point()),
        ]);
    }
    print!("{t}");
    Ok(())
}

/// The fixed grid every bench case evaluates: small enough that a
/// full suite stays interactive, big enough to exercise the engine's
/// parallel path (6 points: 3 GEMMs x {baseline, d1@rf}).
fn bench_scenario() -> Result<Scenario> {
    Scenario::builder("bench-serve")
        .workloads("synthetic:3")
        .prims("baseline,d1")
        .levels("rf")
        .seed(13)
        .threads(2)
        .build()
}

/// One full daemon lifecycle against a cold cache: bind on a free
/// port, serve one eval, drain. Returns the per-request stats.
fn serve_roundtrip_cold(sc: &Scenario) -> Result<Json> {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_depth: 4,
        quiet: true,
        ..ServeOptions::default()
    })?;
    let addr = server.local_addr()?;
    let daemon = std::thread::spawn(move || server.run());
    let mut client = Client::connect(&addr.to_string())?;
    let response = client.eval(sc)?;
    client.shutdown()?;
    daemon
        .join()
        .map_err(|_| anyhow::anyhow!("daemon thread panicked"))??;
    Ok(response.stats)
}

/// `repro bench` — the in-process benchmark suite (same cases as
/// `cargo bench`, plus the serve round-trips). `--json` writes
/// machine-readable results so the repo's perf trajectory is tracked.
fn cmd_bench(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&["json", "samples", "warmup"]) {
        bail!(err);
    }
    let mut b = Bencher::new();
    b.samples = args.get_parsed_or("samples", b.samples)?;
    b.warmup = args.get_parsed_or("warmup", b.warmup)?;
    if b.samples == 0 {
        bail!("--samples wants a positive integer");
    }
    let sc = bench_scenario()?;
    let cache_stats = |hits: u64, misses: u64, mapper_calls: u64| {
        Json::Obj(vec![
            ("hits".to_string(), Json::Num(hits as f64)),
            ("misses".to_string(), Json::Num(misses as f64)),
            ("mapper_calls".to_string(), Json::Num(mapper_calls as f64)),
        ])
    };
    // One cache-stats object per case, parallel to the measurements.
    let mut extras: Vec<Json> = Vec::new();

    let cold = exec::eval_sweep(&sc, std::sync::Arc::new(EvalCache::new()))?;
    let points = cold.points as u64;
    b.bench_with_items("sweep/cold (fresh cache)", points, &mut || {
        exec::eval_sweep(&sc, std::sync::Arc::new(EvalCache::new()))
            .map(|e| e.points)
            .unwrap_or(0)
    });
    extras.push(cache_stats(0, cold.misses, cold.mapper_calls));

    let warm_cache = std::sync::Arc::new(EvalCache::new());
    exec::eval_sweep(&sc, std::sync::Arc::clone(&warm_cache))?;
    b.bench_with_items("sweep/warm (shared cache)", points, &mut || {
        exec::eval_sweep(&sc, std::sync::Arc::clone(&warm_cache))
            .map(|e| e.points)
            .unwrap_or(0)
    });
    extras.push(cache_stats(
        warm_cache.hits(),
        warm_cache.misses(),
        warm_cache.mapper_calls(),
    ));

    let mut last_cold_stats = Json::Null;
    b.bench_with_items("serve/roundtrip-cold (bind+eval+drain)", points, &mut || {
        match serve_roundtrip_cold(&sc) {
            Ok(stats) => last_cold_stats = stats,
            Err(e) => eprintln!("serve/roundtrip-cold failed: {e:#}"),
        }
    });
    extras.push(last_cold_stats);

    // Warm round-trips: one long-lived daemon, one keep-alive client.
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        queue_depth: 4,
        quiet: true,
        ..ServeOptions::default()
    })?;
    let addr = server.local_addr()?;
    let daemon = std::thread::spawn(move || server.run());
    let mut client = Client::connect(&addr.to_string())?;
    client.eval(&sc)?; // warm the daemon's cache
    b.bench_with_items("serve/roundtrip-warm (keep-alive eval)", points, &mut || {
        if let Err(e) = client.eval(&sc) {
            eprintln!("serve/roundtrip-warm failed: {e:#}");
        }
    });
    let daemon_stats = client.stats()?;
    extras.push(daemon_stats.get("cache").cloned().unwrap_or(Json::Null));
    client.shutdown()?;
    daemon
        .join()
        .map_err(|_| anyhow::anyhow!("daemon thread panicked"))??;

    b.finish("sweep");

    if let Some(file) = args.get("json") {
        let path = PathBuf::from(if file == "true" { "BENCH_sweep.json" } else { file });
        let cases: Vec<Json> = b
            .measurements()
            .iter()
            .zip(extras)
            .map(|(m, cache)| {
                let Json::Obj(mut fields) = m.to_json() else {
                    return Json::Null;
                };
                fields.push(("cache".to_string(), cache));
                Json::Obj(fields)
            })
            .collect();
        let doc = Json::Obj(vec![
            ("suite".to_string(), Json::Str("sweep".to_string())),
            ("samples".to_string(), Json::Num(b.samples as f64)),
            ("warmup".to_string(), Json::Num(b.warmup as f64)),
            ("cases".to_string(), Json::Arr(cases)),
        ]);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, doc.encode())?;
        println!("[json] bench results -> {}", path.display());
    }
    Ok(())
}

/// `repro lint [--fix-guards] [--rules] [path]` — run the static
/// analyzer ([`www_cim::lint`]) over a repo tree (default: the
/// current directory if it contains `rust/src`, else the tree this
/// binary was built from). Exits non-zero on any finding, so CI can
/// gate on it directly.
fn cmd_lint(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&["fix-guards", "rules"]) {
        bail!(err);
    }
    if args.flag("rules") {
        for id in lint::RULE_IDS {
            let summary = lint::RULES
                .iter()
                .find(|r| r.id == *id)
                .map(|r| r.summary)
                .unwrap_or(
                    "version guards: guarded modules must bump their version constant \
                     when content changes (lint/guards.toml)",
                );
            println!("{id}  {summary}");
        }
        return Ok(());
    }
    let root = match args.positional.first() {
        Some(p) => PathBuf::from(p),
        None => default_lint_root(),
    };
    let opts = lint::LintOptions {
        fix_guards: args.flag("fix-guards"),
        ..lint::LintOptions::default()
    };
    let report = lint::run(&root, &opts)?;
    print!("{}", report.render());
    if report.clean() {
        Ok(())
    } else {
        bail!("lint found {} issue(s)", report.diagnostics.len())
    }
}

/// Where `repro lint` looks when no path is given: the working
/// directory if it is a repo root, otherwise the source tree this
/// binary was compiled from (covers `cargo run -- lint` anywhere).
fn default_lint_root() -> PathBuf {
    let cwd = PathBuf::from(".");
    if cwd.join("rust").join("src").is_dir() {
        cwd
    } else {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }
}

fn cmd_list(_args: &Args) -> Result<()> {
    println!("primitives (Table IV):");
    for p in CimPrimitive::all() {
        println!(
            "  {:11} ({}) Rp={} Cp={} Rh={} Ch={} latency={}ns mac={}pJ area={}x",
            p.name,
            p.short_label(),
            p.rp,
            p.cp,
            p.rh,
            p.ch,
            p.latency_ns,
            p.mac_energy_pj,
            p.area_overhead
        );
    }
    println!("\nworkloads: BERT-Large, GPT-J, ResNet50, DLRM, synthetic");
    println!("\nexperiments (repro experiment <id>, or repro run <id>):");
    for e in experiments::REGISTRY {
        println!("  {:24} {}", e.id, e.title);
    }
    println!(
        "\nbuilt-in scenarios (repro run/orchestrate <name>): {}",
        scenario::builtin_names().join(", ")
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE 4 regression: the usage text used to hand-list experiment
    /// ids and silently dropped six of them. Both listings now derive
    /// from the registry, so every runnable id must appear.
    #[test]
    fn usage_lists_every_experiment_and_builtin_scenario() {
        let text = usage();
        for id in experiments::ids() {
            assert!(text.contains(id), "usage() omits experiment {id:?}");
        }
        for name in scenario::builtin_names() {
            assert!(text.contains(name), "usage() omits built-in scenario {name:?}");
        }
        for sub in SUBCOMMANDS {
            assert!(
                text.contains(&format!("\n  {}", sub.name)),
                "usage() omits subcommand {:?}",
                sub.name
            );
        }
        assert!(!text.contains('{'), "unexpanded placeholder in usage text");
    }

    /// The subcommand table is the single source of truth for dispatch
    /// and help (this PR's bug-class fix): names must be unique, every
    /// entry needs a usage block, and the new `lint`/`list` entries are
    /// present with their documented flags.
    #[test]
    fn subcommand_table_is_coherent() {
        for (i, sub) in SUBCOMMANDS.iter().enumerate() {
            assert!(!sub.name.is_empty());
            assert!(
                !sub.usage.is_empty(),
                "{}: every subcommand documents its usage",
                sub.name
            );
            assert!(
                !SUBCOMMANDS[i + 1..].iter().any(|s| s.name == sub.name),
                "duplicate subcommand {:?}",
                sub.name
            );
        }
        for required in ["lint", "list"] {
            assert!(SUBCOMMANDS.iter().any(|s| s.name == required));
        }
        let text = usage();
        assert!(text.contains("--fix-guards"), "lint flags documented");
    }

    #[test]
    fn wrap_ids_wraps_and_preserves_every_id() {
        let ids = ["alpha", "beta", "gamma", "delta", "epsilon"];
        let wrapped = wrap_ids(&ids, 4, 20);
        for id in ids {
            assert!(wrapped.contains(id));
        }
        let lines: Vec<&str> = wrapped.lines().collect();
        assert!(lines.len() > 1, "must wrap at width 20");
        for (i, line) in lines.iter().enumerate() {
            assert!(4 + line.trim_start().len() <= 25, "overlong line {line:?}");
            // The alternation separator survives every line break.
            if i + 1 < lines.len() {
                assert!(line.ends_with('|'), "broken alternation at {line:?}");
            }
        }
        // Reassembling yields the unbroken a|b|c list.
        let joined: String = lines.iter().map(|l| l.trim_start()).collect();
        assert_eq!(joined, "alpha|beta|gamma|delta|epsilon");
    }

    #[test]
    fn sweep_flags_build_the_documented_scenario() {
        let args = Args::parse_with_optional(
            "sweep --workloads synthetic:6 --prims baseline,d1 --levels rf \
             --sms 1,2 --batch 1,4 --mapper dup:t3 --seed 9 --tag t --out o --json \
             --cache=c.bin --cache-max-mb 2"
                .split_whitespace(),
            OPTIONAL_VALUE_FLAGS,
        );
        let sc = scenario_from_sweep_flags(&args).unwrap();
        assert_eq!(sc.name, "sweep");
        assert_eq!(sc.base_name(), "t");
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.output.dir, PathBuf::from("o"));
        assert!(sc.output.stdout_json);
        assert_eq!(sc.cache.path, Some(PathBuf::from("c.bin")));
        assert_eq!(sc.cache.max_bytes, Some(2 * 1024 * 1024));
        let spec = sc.sweep_spec().unwrap();
        assert_eq!(spec.sm_counts, vec![1, 2]);
        assert_eq!(spec.systems.len(), 2);
        assert_eq!(spec.batches, vec![1, 4]);
        assert_eq!(spec.workloads.len(), 2, "synthetic:6 at each of 2 batches");
        // Defaults: no flags → the default >= 500-point grid scenario.
        let sc = scenario_from_sweep_flags(&Args::parse(["sweep"])).unwrap();
        assert!(sc.sweep_spec().unwrap().n_points() >= 500);
        assert_eq!(sc.threads, None);
        assert_eq!(sc.cache, www_cim::scenario::CachePolicy::default());
    }

    /// The optional-value regression (this PR): a bare `--cache` before
    /// the positional scenario name must not swallow it.
    #[test]
    fn bare_cache_flag_keeps_the_scenario_name_positional() {
        let args = Args::parse_with_optional(
            "run --cache fig2".split_whitespace(),
            OPTIONAL_VALUE_FLAGS,
        );
        assert_eq!(args.positional, vec!["fig2"]);
        assert_eq!(
            cache_path_flag(&args),
            Some(PathBuf::from("results/cache.bin"))
        );
        let args = Args::parse_with_optional(
            "run --cache=elsewhere/c.bin fig2".split_whitespace(),
            OPTIONAL_VALUE_FLAGS,
        );
        assert_eq!(args.positional, vec!["fig2"]);
        assert_eq!(cache_path_flag(&args), Some(PathBuf::from("elsewhere/c.bin")));
    }

    #[test]
    fn overrides_apply_on_top_of_a_resolved_scenario() {
        let mut sc = scenario::builtin("fig9").unwrap();
        let args = Args::parse(
            "run fig9 --quick --out results-x --seed 3 --threads 2 --cache --cache-max-mb 1"
                .split_whitespace(),
        );
        apply_overrides(&mut sc, &args).unwrap();
        assert_eq!(sc.output.dir, PathBuf::from("results-x"));
        assert_eq!(sc.seed, 3);
        assert_eq!(sc.threads, Some(2));
        assert_eq!(sc.cache.path, Some(PathBuf::from("results/cache.bin")));
        assert_eq!(sc.cache.max_bytes, Some(1024 * 1024));
        match sc.kind {
            ScenarioKind::Experiment { quick, .. } => assert!(quick),
            _ => panic!("builtin fig9 must be an experiment scenario"),
        }
        // --quick on a sweep scenario is an error, not a silent no-op.
        let mut sweep = scenario::builtin("sweep").unwrap();
        let args = Args::parse("run sweep --quick".split_whitespace());
        assert!(apply_overrides(&mut sweep, &args).is_err());
    }
}
