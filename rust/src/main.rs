//! `repro` — the www-cim command-line leader.
//!
//! Subcommands:
//! * `evaluate`   — one GEMM on one system, full metric breakdown
//! * `compare`    — one GEMM across baseline + all primitives
//! * `sweep`      — a workload across systems (per-layer table)
//! * `experiment` — regenerate a paper table/figure (`all` for every one)
//! * `validate`   — replay mappings through the PJRT artifacts
//! * `roofline`   — ridge-point analysis
//! * `list`       — available primitives / workloads / experiments

use anyhow::{bail, Context, Result};

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::jobs::{Grid, SystemSpec};
use www_cim::coordinator::validate::validate_mappings;
use www_cim::cost::{BaselineModel, CostModel, Metrics};
use www_cim::experiments::{self, Ctx};
use www_cim::mapping::PriorityMapper;
use www_cim::roofline::Roofline;
use www_cim::runtime::{default_artifacts_dir, Engine};
use www_cim::util::cli::Args;
use www_cim::util::table::Table;
use www_cim::workload::{models, Gemm};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("evaluate") => cmd_evaluate(args),
        Some("compare") => cmd_compare(args),
        Some("sweep") => cmd_sweep(args),
        Some("experiment") => cmd_experiment(args),
        Some("validate") => cmd_validate(args),
        Some("roofline") => cmd_roofline(),
        Some("list") => cmd_list(),
        Some(other) => bail!("unknown subcommand {other:?} — try `repro list`"),
        None => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
repro — WWW: What, When, Where to Compute-in-Memory (reproduction)

usage: repro <subcommand> [options]

  evaluate   --gemm MxNxK [--prim d1|d2|a1|a2] [--level rf|smem] [--smem-config a|b]
  compare    --gemm MxNxK
  sweep      --workload bert|gptj|resnet50|dlrm [--prim d1] [--level rf|smem]
  experiment <fig2|fig7|table2|fig9|fig10|fig11|fig12|fig13|table6|roofline|
              ablation-threshold|ablation-order|all> [--quick] [--out results]
  validate   [--artifacts artifacts] [--seed N]
  roofline
  list";

fn parse_gemm(s: &str) -> Result<Gemm> {
    let dims: Vec<u64> = s
        .split(['x', 'X', ','])
        .map(|d| d.parse().context("GEMM dims must be integers"))
        .collect::<Result<Vec<_>>>()?;
    if dims.len() != 3 {
        bail!("--gemm wants MxNxK, got {s:?}");
    }
    Ok(Gemm::new(dims[0], dims[1], dims[2]))
}

fn parse_system(args: &Args, arch: &Architecture) -> Result<Option<CimSystem>> {
    let prim_name = args.get_or("prim", "d1");
    if prim_name == "baseline" || prim_name == "tcore" {
        return Ok(None);
    }
    let prim = CimPrimitive::parse(prim_name)
        .with_context(|| format!("unknown primitive {prim_name:?} (d1,d2,a1,a2)"))?;
    let level = MemLevel::parse(args.get_or("level", "rf"))
        .context("--level must be rf or smem")?;
    let sys = match level {
        MemLevel::Smem => {
            let cfg = match args.get_or("smem-config", "b") {
                "a" | "A" => SmemConfig::ConfigA,
                "b" | "B" => SmemConfig::ConfigB,
                other => bail!("--smem-config must be a or b, got {other:?}"),
            };
            CimSystem::at_smem(arch, prim, cfg)
        }
        MemLevel::RegisterFile => CimSystem::at_level(arch, prim, level),
        other => bail!("CiM integrates at rf or smem, not {other}"),
    };
    Ok(Some(sys))
}

fn metrics_table(rows: &[(String, Metrics)]) -> Table {
    let mut t = Table::new(vec![
        "system", "TOPS/W", "GFLOPS", "util", "fJ/MAC", "cycles", "bound",
    ]);
    for (name, m) in rows {
        t.row(vec![
            name.clone(),
            format!("{:.3}", m.tops_per_watt),
            format!("{:.0}", m.gflops),
            format!("{:.2}", m.utilization),
            format!("{:.0}", m.fj_per_mac()),
            m.total_cycles.to_string(),
            if m.memory_bound() { "memory" } else { "compute" }.to_string(),
        ]);
    }
    t
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    if let Some(err) =
        args.unknown_flags(&["gemm", "prim", "level", "smem-config", "verbose"])
    {
        bail!(err);
    }
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    match parse_system(args, &arch)? {
        None => {
            let m = BaselineModel::new(&arch).evaluate(&gemm);
            print!("{}", metrics_table(&[("Tensor-core".into(), m)]));
        }
        Some(sys) => {
            let mapping = PriorityMapper::new(&sys).map(&gemm);
            let m = CostModel::new(&sys).evaluate(&gemm, &mapping);
            print!("{}", metrics_table(&[(sys.label(), m)]));
            if args.flag("verbose") {
                println!("mapping: {}", mapping.describe());
                let b = &m.breakdown;
                println!(
                    "energy pJ: dram={:.0} smem={:.0} rf={:.0} mac={:.0} red={:.0}",
                    b.dram_pj, b.smem_pj, b.rf_pj, b.mac_pj, b.reduction_pj
                );
            }
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    let mut rows = vec![(
        "Tensor-core".to_string(),
        BaselineModel::new(&arch).evaluate(&gemm),
    )];
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        let m = CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm));
        rows.push((sys.label(), m));
    }
    println!("{gemm} across systems (RF, iso-area):");
    print!("{}", metrics_table(&rows));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let arch = Architecture::default_sm();
    let name = args.get_or("workload", "bert");
    let wl = match name.to_ascii_lowercase().as_str() {
        "bert" | "bert-large" => models::bert_large(),
        "gptj" | "gpt-j" => models::gpt_j(),
        "resnet" | "resnet50" => models::resnet50(),
        "dlrm" => models::dlrm(),
        other => bail!("unknown workload {other:?} (bert, gptj, resnet50, dlrm)"),
    };
    let grid = Grid::new(arch.clone());
    let spec = match parse_system(args, &arch)? {
        None => SystemSpec::Baseline,
        Some(sys) => match (sys.level, sys.smem_config) {
            (MemLevel::RegisterFile, _) => SystemSpec::CimAtRf(sys.primitive),
            (MemLevel::Smem, Some(cfg)) => SystemSpec::CimAtSmem(sys.primitive, cfg),
            _ => unreachable!(),
        },
    };
    let gemms: Vec<Gemm> = wl.unique_with_counts().into_iter().map(|(g, _)| g).collect();
    let jobs = grid.cross(&[(wl.name.clone(), gemms)], &[spec]);
    let results = grid.run(&jobs);
    let rows: Vec<(String, Metrics)> = results
        .iter()
        .map(|r| (r.gemm.to_string(), r.metrics))
        .collect();
    println!("{} on {}:", wl.name, results[0].system);
    print!("{}", metrics_table(&rows));
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut ctx = Ctx::default();
    ctx.quick = args.flag("quick");
    ctx.out_dir = args.get_or("out", "results").into();
    ctx.threads = args.get_parsed_or("threads", ctx.threads);
    ctx.seed = args.get_parsed_or("seed", ctx.seed);
    experiments::run(id, &ctx)
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let engine = Engine::load(&dir)?;
    println!(
        "PJRT platform: {}, {} artifacts",
        engine.platform(),
        engine.manifest().len()
    );
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let gemms = [
        Gemm::new(64, 32, 256),
        Gemm::new(128, 32, 512),
        Gemm::new(16, 64, 64),
        Gemm::new(100, 48, 300), // awkward non-divisible shape
        Gemm::new(1, 64, 256),   // GEMV
    ];
    let seed = args.get_parsed_or("seed", 7u64);
    let report = validate_mappings(&engine, &sys, &gemms, seed)?;
    let mut t = Table::new(vec!["GEMM", "kernel calls", "|diff| oracle", "|diff| artifact"]);
    for c in &report.cases {
        t.row(vec![
            c.gemm.to_string(),
            c.kernel_calls.to_string(),
            c.diff_vs_oracle.to_string(),
            c.diff_vs_full_artifact
                .map(|d| d.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{t}");
    if report.all_exact() {
        println!("validation OK: every mapped dataflow is bit-exact");
        Ok(())
    } else {
        bail!("validation FAILED: mapped execution diverges from the oracle")
    }
}

fn cmd_roofline() -> Result<()> {
    let arch = Architecture::default_sm();
    let mut t = Table::new(vec!["system", "peak GOPS", "ridge SMEM", "ridge DRAM"]);
    t.row(vec![
        "Tensor-core".to_string(),
        format!("{:.0}", arch.tensor_core.peak_gops()),
        format!("{:.1}", arch.tensor_core.peak_gops() / 42.0),
        format!("{:.1}", arch.tensor_core.peak_gops() / 32.0),
    ]);
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        t.row(vec![
            sys.label(),
            format!("{:.0}", sys.peak_gops()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Smem).ridge_point()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Dram).ridge_point()),
        ]);
    }
    print!("{t}");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("primitives (Table IV):");
    for p in CimPrimitive::all() {
        println!(
            "  {:11} ({}) Rp={} Cp={} Rh={} Ch={} latency={}ns mac={}pJ area={}x",
            p.name,
            p.short_label(),
            p.rp,
            p.cp,
            p.rh,
            p.ch,
            p.latency_ns,
            p.mac_energy_pj,
            p.area_overhead
        );
    }
    println!("\nworkloads: BERT-Large, GPT-J, ResNet50, DLRM, synthetic");
    println!("\nexperiments: {}", experiments::ALL.join(", "));
    Ok(())
}
