//! `repro` — the www-cim command-line leader.
//!
//! Subcommands:
//! * `evaluate`   — one GEMM on one system, full metric breakdown
//! * `compare`    — one GEMM across baseline + all primitives
//! * `sweep`      — parallel memoized design-space sweep (grid flags,
//!   `--cache` persistence, `--shard i/n` slicing)
//! * `merge`      — combine per-shard sweep summaries into one result
//! * `experiment` — regenerate a paper table/figure (`all` for every one)
//! * `validate`   — replay mappings through the PJRT artifacts
//! * `roofline`   — ridge-point analysis
//! * `list`       — available primitives / workloads / experiments

use anyhow::{bail, Context, Result};

use www_cim::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
use www_cim::cim::CimPrimitive;
use www_cim::coordinator::validate::validate_mappings;
use www_cim::cost::{BaselineModel, CostModel, Metrics};
use www_cim::experiments::{self, Ctx};
use www_cim::mapping::PriorityMapper;
use www_cim::roofline::Roofline;
use www_cim::runtime::{default_artifacts_dir, Engine};
use www_cim::sweep::{output, persist, shard, spec, MapperChoice, ShardId, SweepEngine, SweepSpec};
use www_cim::util::cli::Args;
use www_cim::util::pool;
use www_cim::util::table::Table;
use www_cim::workload::{synthetic, Gemm};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("evaluate") => cmd_evaluate(args),
        Some("compare") => cmd_compare(args),
        Some("sweep") => cmd_sweep(args),
        Some("merge") => cmd_merge(args),
        Some("experiment") => cmd_experiment(args),
        Some("validate") => cmd_validate(args),
        Some("roofline") => cmd_roofline(),
        Some("list") => cmd_list(),
        Some(other) => bail!("unknown subcommand {other:?} — try `repro list`"),
        None => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
repro — WWW: What, When, Where to Compute-in-Memory (reproduction)

usage: repro <subcommand> [options]

  evaluate   --gemm MxNxK [--prim d1|d2|a1|a2] [--level rf|smem] [--smem-config a|b]
  compare    --gemm MxNxK
  sweep      [--workloads all|real|bert,gptj,...|synthetic[:N]]
             [--prims baseline,all|d1,d2,a1,a2] [--levels rf,smem-a,smem-b]
             [--sms 1,2,4] [--threads N]
             [--mapper priority|priority:t<n>|dup|heuristic[:budget]|
                       exhaustive[:energy|delay|edp]]
             [--seed N] [--out results] [--tag name] [--json]
             [--cache [results/cache.bin]] [--shard i/n]
             (defaults sweep the full zoo x 13 systems, >= 500 points;
              --cache persists the memo cache across runs, --shard runs
              one deterministic 1/n slice of the grid)
  merge      <shard.json> <shard.json> ... [--tag name] [--out results] [--json]
  experiment <fig2|fig7|table2|fig9|fig10|fig11|fig12|fig13|table6|roofline|
              ablation-threshold|ablation-order|all> [--quick] [--out results]
             [--cache [results/cache.bin]]
  validate   [--artifacts artifacts] [--seed N]
  roofline
  list";

fn parse_gemm(s: &str) -> Result<Gemm> {
    let dims: Vec<u64> = s
        .split(['x', 'X', ','])
        .map(|d| d.parse().context("GEMM dims must be integers"))
        .collect::<Result<Vec<_>>>()?;
    if dims.len() != 3 {
        bail!("--gemm wants MxNxK, got {s:?}");
    }
    Ok(Gemm::new(dims[0], dims[1], dims[2]))
}

fn parse_system(args: &Args, arch: &Architecture) -> Result<Option<CimSystem>> {
    let prim_name = args.get_or("prim", "d1");
    if prim_name == "baseline" || prim_name == "tcore" {
        return Ok(None);
    }
    let prim = CimPrimitive::parse(prim_name)
        .with_context(|| format!("unknown primitive {prim_name:?} (d1,d2,a1,a2)"))?;
    let level = MemLevel::parse(args.get_or("level", "rf"))
        .context("--level must be rf or smem")?;
    let sys = match level {
        MemLevel::Smem => {
            let cfg = match args.get_or("smem-config", "b") {
                "a" | "A" => SmemConfig::ConfigA,
                "b" | "B" => SmemConfig::ConfigB,
                other => bail!("--smem-config must be a or b, got {other:?}"),
            };
            CimSystem::at_smem(arch, prim, cfg)
        }
        MemLevel::RegisterFile => CimSystem::at_level(arch, prim, level),
        other => bail!("CiM integrates at rf or smem, not {other}"),
    };
    Ok(Some(sys))
}

fn metrics_table(rows: &[(String, Metrics)]) -> Table {
    let mut t = Table::new(vec![
        "system", "TOPS/W", "GFLOPS", "util", "fJ/MAC", "cycles", "bound",
    ]);
    for (name, m) in rows {
        t.row(vec![
            name.clone(),
            format!("{:.3}", m.tops_per_watt),
            format!("{:.0}", m.gflops),
            format!("{:.2}", m.utilization),
            format!("{:.0}", m.fj_per_mac()),
            m.total_cycles.to_string(),
            if m.memory_bound() { "memory" } else { "compute" }.to_string(),
        ]);
    }
    t
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    if let Some(err) =
        args.unknown_flags(&["gemm", "prim", "level", "smem-config", "verbose"])
    {
        bail!(err);
    }
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    match parse_system(args, &arch)? {
        None => {
            let m = BaselineModel::new(&arch).evaluate(&gemm);
            print!("{}", metrics_table(&[("Tensor-core".into(), m)]));
        }
        Some(sys) => {
            let mapping = PriorityMapper::new(&sys).map(&gemm);
            let m = CostModel::new(&sys).evaluate(&gemm, &mapping);
            print!("{}", metrics_table(&[(sys.label(), m)]));
            if args.flag("verbose") {
                println!("mapping: {}", mapping.describe());
                let b = &m.breakdown;
                println!(
                    "energy pJ: dram={:.0} smem={:.0} rf={:.0} mac={:.0} red={:.0}",
                    b.dram_pj, b.smem_pj, b.rf_pj, b.mac_pj, b.reduction_pj
                );
            }
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let arch = Architecture::default_sm();
    let gemm = parse_gemm(args.get("gemm").context("--gemm MxNxK required")?)?;
    let mut rows = vec![(
        "Tensor-core".to_string(),
        BaselineModel::new(&arch).evaluate(&gemm),
    )];
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        let m = CostModel::new(&sys).evaluate(&gemm, &PriorityMapper::new(&sys).map(&gemm));
        rows.push((sys.label(), m));
    }
    println!("{gemm} across systems (RF, iso-area):");
    print!("{}", metrics_table(&rows));
    Ok(())
}

/// `--cache [path]` — the persistent sweep cache location. A bare
/// `--cache` uses the conventional `results/cache.bin`.
fn cache_path_flag(args: &Args) -> Option<std::path::PathBuf> {
    args.get("cache").map(|v| {
        if v == "true" {
            std::path::PathBuf::from("results/cache.bin")
        } else {
            std::path::PathBuf::from(v)
        }
    })
}

/// `repro sweep` — the design-space sweep engine on the CLI: cartesian
/// grid flags expanded into a parallel, memoized evaluation with CSV +
/// JSON mirrors, optional disk persistence of the memo cache
/// (`--cache`) and deterministic `--shard i/n` slicing for distributed
/// runs.
fn cmd_sweep(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&[
        "workload", "workloads", "prim", "prims", "level", "levels", "sms", "threads",
        "mapper", "seed", "out", "json", "cache", "shard", "tag",
    ]) {
        bail!(err);
    }
    let arch = Architecture::default_sm();
    let seed = args.get_parsed_or("seed", synthetic::DEFAULT_SEED);
    let threads = args.get_parsed_or("threads", pool::default_threads());

    // Grid axes (singular flags are aliases for the plural ones).
    let workloads_arg = args
        .get("workloads")
        .or_else(|| args.get("workload"))
        .unwrap_or(spec::DEFAULT_WORKLOADS);
    let prims_arg = args
        .get("prims")
        .or_else(|| args.get("prim"))
        .unwrap_or(spec::DEFAULT_PRIMS);
    let levels_arg = args
        .get("levels")
        .or_else(|| args.get("level"))
        .unwrap_or(spec::DEFAULT_LEVELS);

    let sweep_spec = SweepSpec::new("sweep")
        .workloads(spec::parse_workloads(workloads_arg, seed)?)
        .systems(spec::parse_systems(prims_arg, levels_arg)?)
        .sm_counts(spec::parse_sm_counts(args.get_or("sms", "1"))?)
        .mapper(MapperChoice::parse(args.get_or("mapper", "priority"), seed)?);

    println!(
        "sweep: {} grid points ({} workload(s) x {} system(s) x {} SM count(s)), {} threads",
        sweep_spec.n_points(),
        sweep_spec.workloads.len(),
        sweep_spec.systems.len(),
        sweep_spec.sm_counts.len(),
        threads
    );
    let engine = SweepEngine::new(arch).threads(threads);

    // Persistent cache: warm from disk if a compatible file exists.
    let cache_path = cache_path_flag(args);
    if let Some(path) = &cache_path {
        let load = persist::load_into(engine.cache(), path)?;
        println!("[cache] {} ({})", load.describe(), path.display());
    }

    // Shard slicing: expand the full grid, run the deterministic
    // round-robin slice (the whole grid without --shard).
    let shard_id = args.get("shard").map(ShardId::parse).transpose()?;
    let all_jobs = sweep_spec.jobs();
    let run = match shard_id {
        None => engine.run_jobs_named(&sweep_spec.name, &all_jobs),
        Some(s) => {
            let slice = s.slice(&all_jobs);
            println!("shard {s}: {} of {} grid points", slice.len(), all_jobs.len());
            engine.run_jobs_named(&sweep_spec.name, &slice)
        }
    };
    println!(
        "evaluated {} points in {:.3}s (cache: {} unique, {} duplicate hits)",
        run.n_points(),
        run.elapsed.as_secs_f64(),
        run.cache_misses,
        run.cache_hits
    );
    if let Some(path) = &cache_path {
        let n = persist::save(engine.cache(), path)?;
        println!("[cache] saved {n} design points -> {}", path.display());
    }

    // Small grids get the full per-point table; every run gets the
    // per-system summary.
    if run.results.len() <= 80 {
        print!("{}", output::detail_table(&run.results));
    }
    print!("{}", output::summary_table(&run.results));

    // CSV + JSON mirrors, named by --tag (default: the spec name, so
    // plain sweeps keep writing sweep.csv/sweep.json) and the shard
    // identity — successive tagged or sharded sweeps never overwrite
    // each other.
    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    let base = args.get_or("tag", &sweep_spec.name).to_string();
    let csv = output::results_csv(&run.results)?;
    match shard_id {
        None => {
            let csv_path = out_dir.join(format!("{base}.csv"));
            csv.write(&csv_path)?;
            println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
            let json_path = out_dir.join(format!("{base}.json"));
            output::write_json_summary(&run, &json_path)?;
            println!("[json] summary -> {}", json_path.display());
            if args.flag("json") {
                print!("{}", output::json_summary(&run));
            }
        }
        Some(s) => {
            let fp = shard::sweep_fingerprint(engine.arch(), &sweep_spec);
            let csv_path = out_dir.join(format!("{base}-{}.csv", s.file_tag()));
            csv.write(&csv_path)?;
            println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
            let json_path = out_dir.join(format!("{base}-{}.json", s.file_tag()));
            shard::write_shard_json(&run, s, &fp, all_jobs.len(), &json_path)?;
            println!(
                "[json] shard summary -> {} (merge all {} shards with `repro merge`)",
                json_path.display(),
                s.count
            );
            if args.flag("json") {
                print!("{}", shard::shard_json(&run, s, &fp, all_jobs.len()));
            }
        }
    }
    Ok(())
}

/// `repro merge` — validate and combine per-shard sweep summaries into
/// the unsharded sweep.csv/sweep.json (byte-identical CSV).
fn cmd_merge(args: &Args) -> Result<()> {
    if let Some(err) = args.unknown_flags(&["out", "tag", "json"]) {
        bail!(err);
    }
    if args.positional.is_empty() {
        bail!("usage: repro merge <shard.json> <shard.json> ... [--tag name] [--out results]");
    }
    let paths: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    let merged = shard::merge_files(&paths)?;
    println!(
        "merged {} shard(s) of sweep {:?}: {} points (fingerprint {})",
        merged.shard_count,
        merged.spec_name,
        merged.results.len(),
        merged.fingerprint
    );
    print!("{}", output::summary_table(&merged.results));

    let out_dir = std::path::PathBuf::from(args.get_or("out", "results"));
    let base = args.get_or("tag", &merged.spec_name).to_string();
    let csv = output::results_csv(&merged.results)?;
    let csv_path = out_dir.join(format!("{base}.csv"));
    csv.write(&csv_path)?;
    println!("[csv] {} rows -> {}", csv.n_rows(), csv_path.display());
    // csv.write above already created out_dir.
    let json_path = out_dir.join(format!("{base}.json"));
    std::fs::write(&json_path, shard::merged_json(&merged))?;
    println!("[json] merged summary -> {}", json_path.display());
    if args.flag("json") {
        print!("{}", shard::merged_json(&merged));
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut ctx = Ctx::default();
    ctx.quick = args.flag("quick");
    ctx.out_dir = args.get_or("out", "results").into();
    ctx.threads = args.get_parsed_or("threads", ctx.threads);
    ctx.seed = args.get_parsed_or("seed", ctx.seed);
    ctx.cache_path = cache_path_flag(args);
    ctx.load_persistent_cache()?;
    let result = experiments::run(id, &ctx);
    // Run-level cache accounting: on a warm persisted cache this must
    // read "0 misses (100.0% hit rate), 0 mapper call(s)" — the CI e2e
    // step greps for it to prove no experiment bypasses the engine.
    println!("{}", ctx.cache_stats_line());
    // Persist whatever was scored even if one experiment failed — the
    // cache entries themselves are valid. A save failure must not mask
    // the experiment's own error, so it is reported, not propagated.
    if let Err(e) = ctx.save_persistent_cache() {
        eprintln!("warning: could not persist the sweep cache: {e:#}");
    }
    result
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let engine = Engine::load(&dir)?;
    println!(
        "PJRT platform: {}, {} artifacts",
        engine.platform(),
        engine.manifest().len()
    );
    let arch = Architecture::default_sm();
    let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
    let gemms = [
        Gemm::new(64, 32, 256),
        Gemm::new(128, 32, 512),
        Gemm::new(16, 64, 64),
        Gemm::new(100, 48, 300), // awkward non-divisible shape
        Gemm::new(1, 64, 256),   // GEMV
    ];
    let seed = args.get_parsed_or("seed", 7u64);
    let report = validate_mappings(&engine, &sys, &gemms, seed)?;
    let mut t = Table::new(vec!["GEMM", "kernel calls", "|diff| oracle", "|diff| artifact"]);
    for c in &report.cases {
        t.row(vec![
            c.gemm.to_string(),
            c.kernel_calls.to_string(),
            c.diff_vs_oracle.to_string(),
            c.diff_vs_full_artifact
                .map(|d| d.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{t}");
    if report.all_exact() {
        println!("validation OK: every mapped dataflow is bit-exact");
        Ok(())
    } else {
        bail!("validation FAILED: mapped execution diverges from the oracle")
    }
}

fn cmd_roofline() -> Result<()> {
    let arch = Architecture::default_sm();
    let mut t = Table::new(vec!["system", "peak GOPS", "ridge SMEM", "ridge DRAM"]);
    t.row(vec![
        "Tensor-core".to_string(),
        format!("{:.0}", arch.tensor_core.peak_gops()),
        format!("{:.1}", arch.tensor_core.peak_gops() / 42.0),
        format!("{:.1}", arch.tensor_core.peak_gops() / 32.0),
    ]);
    for prim in CimPrimitive::all() {
        let sys = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
        t.row(vec![
            sys.label(),
            format!("{:.0}", sys.peak_gops()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Smem).ridge_point()),
            format!("{:.1}", Roofline::of(&sys, MemLevel::Dram).ridge_point()),
        ]);
    }
    print!("{t}");
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("primitives (Table IV):");
    for p in CimPrimitive::all() {
        println!(
            "  {:11} ({}) Rp={} Cp={} Rh={} Ch={} latency={}ns mac={}pJ area={}x",
            p.name,
            p.short_label(),
            p.rp,
            p.cp,
            p.rh,
            p.ch,
            p.latency_ns,
            p.mac_energy_pj,
            p.area_overhead
        );
    }
    println!("\nworkloads: BERT-Large, GPT-J, ResNet50, DLRM, synthetic");
    println!("\nexperiments: {}", experiments::ALL.join(", "));
    Ok(())
}
