//! # www-cim — What, When, Where to Compute-in-Memory
//!
//! Reproduction of *"WWW: What, When, Where to Compute-in-Memory"*
//! (Sharma, Ali, Chakraborty, Roy — cs.AR 2023): an analytical
//! architecture-evaluation framework that integrates SRAM
//! compute-in-memory (CiM) primitives into the cache levels of a
//! tensor-core-like GPU streaming multiprocessor and evaluates
//! energy-efficiency (TOPS/W), throughput (GFLOPS) and utilization for
//! the GEMM shapes found in ML inference.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the paper's system contribution: the CiM
//!   primitive model ([`cim`]), the memory-hierarchy/architecture model
//!   ([`arch`]), the workload substrate ([`workload`]), the
//!   priority-based dataflow mapper and its heuristic-search comparator
//!   ([`mapping`]), the analytical cost model ([`cost`]), roofline
//!   analysis ([`roofline`]), the evaluation coordinator
//!   ([`coordinator`]), the parallel memoized design-space sweep engine
//!   ([`sweep`]) and one regenerator per paper table/figure
//!   ([`experiments`]).
//! * **L2/L1 (python, build-time)** — a JAX model whose hot loop is a
//!   Pallas weight-stationary int8 GEMM kernel mirroring the paper's CiM
//!   decomposition, AOT-lowered to HLO text under `artifacts/`.
//! * **[`runtime`]** — loads those artifacts through the PJRT C API
//!   (`xla` crate) and replays mapped dataflows tile-by-tile to validate
//!   mappings numerically. Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use www_cim::prelude::*;
//!
//! let arch = Architecture::default_sm();
//! let prim = CimPrimitive::digital_6t();
//! let gemm = Gemm::new(512, 1024, 1024);
//! let system = CimSystem::at_level(&arch, prim, MemLevel::RegisterFile);
//! let mapping = PriorityMapper::new(&system).map(&gemm);
//! let metrics = CostModel::new(&system).evaluate(&gemm, &mapping);
//! println!("{:.2} TOPS/W, {:.0} GFLOPS, util {:.1}%",
//!          metrics.tops_per_watt, metrics.gflops, 100.0 * metrics.utilization);
//! ```

pub mod arch;
pub mod cim;
pub mod coordinator;
pub mod cost;
pub mod experiments;
pub mod lint;
pub mod mapping;
pub mod roofline;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sweep;
pub mod util;
pub mod workload;

/// Convenience re-exports of the most common public types.
pub mod prelude {
    pub use crate::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
    pub use crate::cim::{CimPrimitive, CellType, ComputeType};
    pub use crate::cost::{CostModel, Metrics};
    pub use crate::mapping::{HeuristicMapper, Mapping, PriorityMapper};
    pub use crate::scenario::Scenario;
    pub use crate::sweep::{SweepEngine, SweepSpec};
    pub use crate::workload::{Gemm, Workload};
}
