//! Roofline / ridge-point analysis (paper Appendix B, after Williams
//! et al. [46]).
//!
//! The ridge point of a (peak GOPS, bandwidth) pair is the arithmetic
//! intensity below which a workload is bandwidth-bound:
//! `ridge = peak / bandwidth` (ops per byte). The paper quotes ridge
//! points of 32.5 (SMEM, 42 B/cycle) and 42.6 (DRAM, 32 B/cycle) for
//! the 3×Digital-6T register-file integration.

use crate::arch::{CimSystem, MemLevel};
use crate::workload::Gemm;

/// Roofline of one system against one bandwidth-limited level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    /// Peak compute throughput, GOPS.
    pub peak_gops: f64,
    /// Sustained bandwidth, GB/s (= bytes/cycle at 1 GHz).
    pub bandwidth_gbs: f64,
}

impl Roofline {
    pub fn of(sys: &CimSystem, level: MemLevel) -> Self {
        Roofline {
            peak_gops: sys.peak_gops(),
            bandwidth_gbs: sys.arch.level(level).bandwidth_bytes_per_cycle,
        }
    }

    /// Arithmetic intensity (ops/byte) where compute and bandwidth
    /// bounds intersect.
    pub fn ridge_point(&self) -> f64 {
        self.peak_gops / self.bandwidth_gbs
    }

    /// Attainable GOPS at a given arithmetic intensity.
    pub fn attainable_gops(&self, intensity: f64) -> f64 {
        self.peak_gops.min(self.bandwidth_gbs * intensity)
    }

    /// Whether a GEMM's *algorithmic* reuse puts it under the ridge
    /// (memory-bound in the ideal case).
    pub fn memory_bound(&self, gemm: &Gemm) -> bool {
        gemm.algorithmic_reuse() < self.ridge_point()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;

    fn d1_rf() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn appendix_b_ridge_points() {
        let sys = d1_rf();
        let smem = Roofline::of(&sys, MemLevel::Smem);
        let dram = Roofline::of(&sys, MemLevel::Dram);
        // Paper: 32.5 for SMEM (42 B/cycle), 42.6 for DRAM (32 B/cycle).
        assert!((smem.ridge_point() - 32.5).abs() < 0.1, "{}", smem.ridge_point());
        assert!((dram.ridge_point() - 42.6).abs() < 0.1, "{}", dram.ridge_point());
    }

    #[test]
    fn attainable_is_min_of_bounds() {
        let r = Roofline {
            peak_gops: 1000.0,
            bandwidth_gbs: 10.0,
        };
        assert_eq!(r.attainable_gops(1.0), 10.0);
        assert_eq!(r.attainable_gops(1000.0), 1000.0);
        assert_eq!(r.attainable_gops(r.ridge_point()), 1000.0);
    }

    #[test]
    fn gemv_under_ridge_gemm_above() {
        let sys = d1_rf();
        let dram = Roofline::of(&sys, MemLevel::Dram);
        assert!(dram.memory_bound(&Gemm::new(1, 4096, 4096)));
        assert!(!dram.memory_bound(&Gemm::new(512, 1024, 1024)));
    }
}
