//! Memory-access counting over a tiled loop nest.
//!
//! For each tensor we know its *residency chain*: the block indices at
//! which a tile of the tensor is buffered (always starting at block 0,
//! DRAM). The traffic filling each residency follows the Fig 4
//! semantics implemented in [`crate::mapping::loopnest::refetches`]:
//! `visits × tile` elements cross into the residency, of which
//! `distinct × tile` are first-time fetches. For the output tensor the
//! difference is exactly the partial-sum reload traffic.

use crate::mapping::loopnest::{distinct_at, refetches_at, LoopNest, Tensor};

/// Traffic filling one residency of one tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fill {
    /// Block index of the residency being filled.
    pub boundary: usize,
    /// Tile size at this residency, elements.
    pub tile: u64,
    /// Times the residency is (re)filled.
    pub visits: u64,
    /// Distinct tiles among those visits.
    pub distinct: u64,
}

impl Fill {
    /// Total elements crossing into the residency.
    pub fn elems(&self) -> u64 {
        self.tile.saturating_mul(self.visits)
    }

    /// Re-fetched elements (for outputs: partial-sum reloads).
    pub fn partial_elems(&self) -> u64 {
        self.tile.saturating_mul(self.visits - self.distinct)
    }

    /// First-time elements (distinct data volume through this boundary).
    pub fn distinct_elems(&self) -> u64 {
        self.tile.saturating_mul(self.distinct)
    }
}

/// Fill at a single residency boundary (allocation-free — the
/// cost-model hot path uses this directly).
pub fn fill_at(nest: &LoopNest, tensor: Tensor, b: usize) -> Fill {
    debug_assert!(b > 0 && b < nest.blocks.len());
    Fill {
        boundary: b,
        tile: nest.tile_elems(b, tensor),
        visits: refetches_at(nest, b, tensor),
        distinct: distinct_at(nest, b, tensor),
    }
}

/// Compute the fills for `tensor` along its residency `chain` (block
/// indices, ascending, starting at 0). Returns one [`Fill`] per chain
/// entry after the first.
pub fn fills(nest: &LoopNest, tensor: Tensor, chain: &[usize]) -> Vec<Fill> {
    assert!(!chain.is_empty() && chain[0] == 0, "chain must start at DRAM (block 0)");
    assert!(
        chain.windows(2).all(|w| w[0] < w[1]),
        "chain must be strictly ascending"
    );
    assert!(
        chain.last().is_some_and(|&b| b < nest.blocks.len()),
        "chain index out of range"
    );
    chain[1..].iter().map(|&b| fill_at(nest, tensor, b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MemLevel;
    use crate::mapping::loopnest::{Block, Dim, Loop};
    use crate::workload::Gemm;

    /// GEMM(64, 32, 128): DRAM[K2=2, M2=4] / SMEM[N1=2] / CiM[N16 K64 M16].
    fn nest() -> LoopNest {
        LoopNest::new(
            Gemm::new(64, 32, 128),
            vec![
                Block::new(
                    MemLevel::Dram,
                    vec![Loop::new(Dim::K, 2), Loop::new(Dim::M, 4)],
                ),
                Block::new(MemLevel::Smem, vec![Loop::new(Dim::N, 2)]),
                Block::new(
                    MemLevel::RegisterFile,
                    vec![
                        Loop::new(Dim::N, 16),
                        Loop::new(Dim::K, 64),
                        Loop::new(Dim::M, 16),
                    ],
                ),
            ],
        )
    }

    #[test]
    fn input_fills() {
        let n = nest();
        let f = fills(&n, Tensor::Input, &[0, 1, 2]);
        assert_eq!(f.len(), 2);
        // SMEM residency: tile = 16m x 64k = 1024; prefix [K2, M4]:
        // both relevant -> 8 visits, 8 distinct (A fetched exactly once).
        assert_eq!(f[0], Fill { boundary: 1, tile: 1024, visits: 8, distinct: 8 });
        // CiM boundary: same tile (no A dims in block 1); prefix adds
        // N1=2 (irrelevant, no relevant deeper) -> still 8 visits.
        assert_eq!(f[1].visits, 8);
        // Total A traffic into CiM = the full matrix once.
        assert_eq!(f[1].elems(), 64 * 128);
    }

    #[test]
    fn weight_fills_reload_per_m_tile() {
        let n = nest();
        let f = fills(&n, Tensor::Weight, &[0, 2]);
        assert_eq!(f.len(), 1);
        // W tile = 64k x 16n = 1024. Prefix [K2, M4, N1]: K relevant x2,
        // M irrelevant but N deeper -> x4, N relevant x2 => 16 visits of
        // 4 distinct tiles (weights reload for every M tile).
        assert_eq!(f[0].tile, 1024);
        assert_eq!(f[0].visits, 16);
        assert_eq!(f[0].distinct, 4);
        assert_eq!(f[0].partial_elems(), 12 * 1024);
    }

    #[test]
    fn output_partial_sums() {
        let n = nest();
        let f = fills(&n, Tensor::Output, &[0, 1, 2]);
        // SMEM Z tile = 16m x 32n = 512. Prefix [K2, M4]: K outermost
        // irrelevant with M deeper -> x2; M relevant x4 => 8 visits of
        // 4 distinct tiles -> half the traffic is partial reloads.
        assert_eq!(f[0].tile, 512);
        assert_eq!(f[0].visits, 8);
        assert_eq!(f[0].distinct, 4);
        assert_eq!(f[0].partial_elems(), 4 * 512);
        // CiM outbuf tile = 16m x 16n = 256; prefix adds N1=2 (relevant).
        assert_eq!(f[1].tile, 256);
        assert_eq!(f[1].visits, 16);
        assert_eq!(f[1].distinct, 8);
    }

    #[test]
    fn chain_skipping_intermediate_level() {
        let n = nest();
        // W direct DRAM -> CiM equals W with chain [0,2].
        let f = fills(&n, Tensor::Weight, &[0, 2]);
        assert_eq!(f[0].boundary, 2);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn non_ascending_chain_rejected() {
        let n = nest();
        fills(&n, Tensor::Input, &[0, 2, 1]);
    }

    #[test]
    #[should_panic(expected = "DRAM")]
    fn chain_must_start_at_zero() {
        let n = nest();
        fills(&n, Tensor::Input, &[1, 2]);
    }

    #[test]
    fn conservation_distinct_volume_is_matrix_size() {
        // The distinct volume through the outermost boundary equals the
        // tensor size (every element enters the chip at least once,
        // exactly once when counted distinctly) for exact tilings.
        let n = nest();
        let g = n.gemm;
        let a = fills(&n, Tensor::Input, &[0, 1, 2]);
        assert_eq!(a[0].distinct_elems(), g.m * g.k);
        let w = fills(&n, Tensor::Weight, &[0, 2]);
        assert_eq!(w[0].distinct_elems(), g.k * g.n);
        let z = fills(&n, Tensor::Output, &[0, 1, 2]);
        assert_eq!(z[0].distinct_elems(), g.m * g.n);
    }
}
