//! Analytical cost model (paper §V-D).
//!
//! Total energy = MAC energy + weighted memory accesses + temporal
//! reductions. Throughput assumes a fully pipelined system: total
//! cycles = max(compute cycles, per-level memory cycles). TOPS/W is
//! ops per pJ; GFLOPS is ops per ns at 1 GHz.

pub mod access;
pub mod baseline;

pub use baseline::BaselineModel;

/// Version of the analytical cost model. Bump this whenever a change
/// can alter any produced [`Metrics`] value (energy weights, cycle
/// accounting, utilization, …): persisted sweep caches embed the
/// constant in their header and are discarded wholesale on mismatch
/// ([`crate::sweep::persist`]), so a model change can never silently
/// serve stale metrics from a previous run's cache file. Mapping
/// *algorithm* changes are covered separately by
/// [`crate::mapping::MAPPER_VERSION`], which is embedded in the cache
/// keys themselves.
pub const COST_MODEL_VERSION: u32 = 1;

use crate::arch::{CimSystem, MemLevel};
use crate::cost::access::fill_at;
use crate::mapping::loopnest::{Dim, Tensor};
use crate::mapping::Mapping;
use crate::workload::Gemm;

/// Energy breakdown in pJ (Fig 13's stacked bars).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub dram_pj: f64,
    pub smem_pj: f64,
    pub rf_pj: f64,
    pub pe_buf_pj: f64,
    pub mac_pj: f64,
    pub reduction_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.dram_pj + self.smem_pj + self.rf_pj + self.pe_buf_pj + self.mac_pj + self.reduction_pj
    }

    pub fn add_level(&mut self, lvl: MemLevel, pj: f64) {
        match lvl {
            MemLevel::Dram => self.dram_pj += pj,
            MemLevel::Smem => self.smem_pj += pj,
            MemLevel::RegisterFile => self.rf_pj += pj,
            MemLevel::PeBuffer => self.pe_buf_pj += pj,
        }
    }
}

/// Evaluation result for one GEMM on one system (§V-D metrics).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Metrics {
    pub macs: u64,
    pub ops: u64,
    pub energy_pj: f64,
    pub breakdown: EnergyBreakdown,
    /// Tera-operations per second per watt = ops / pJ.
    pub tops_per_watt: f64,
    pub compute_cycles: u64,
    pub dram_cycles: u64,
    pub smem_cycles: u64,
    /// max(compute, dram, smem) — fully pipelined overlap.
    pub total_cycles: u64,
    /// Giga-ops per second at 1 GHz.
    pub gflops: f64,
    /// Fraction of MAC positions occupied (CiM) or PE-cycles used
    /// (baseline).
    pub utilization: f64,
    /// Bytes moved at the DRAM boundary (roofline analysis).
    pub dram_bytes: u64,
    /// Bytes moved at the SMEM boundary.
    pub smem_bytes: u64,
}

impl Metrics {
    /// Energy per MAC in femtojoules (Fig 13's y-axis).
    pub fn fj_per_mac(&self) -> f64 {
        1000.0 * self.energy_pj / self.macs as f64
    }

    /// Whether the run is memory-bound (bandwidth throttled).
    pub fn memory_bound(&self) -> bool {
        self.total_cycles > self.compute_cycles
    }
}

/// Analytical cost model for a CiM-integrated system.
#[derive(Debug, Clone)]
pub struct CostModel<'a> {
    sys: &'a CimSystem,
}

impl<'a> CostModel<'a> {
    pub fn new(sys: &'a CimSystem) -> Self {
        CostModel { sys }
    }

    /// Evaluate a mapping of `gemm` on the system.
    pub fn evaluate(&self, gemm: &Gemm, mapping: &Mapping) -> Metrics {
        assert_eq!(*gemm, mapping.gemm, "mapping was built for a different GEMM");
        let sys = self.sys;
        let e = &sys.arch.energy;
        let nest = &mapping.nest;
        let macs = gemm.macs();
        let ops = gemm.ops();

        // Residency chains (see DESIGN.md "Model notes"): with an
        // on-chip staging level (CiM at RF stages tiles in SMEM) the
        // input/output chains pass through it; CiM at SMEM streams
        // directly from DRAM. Weights always load straight into the
        // CiM arrays.
        let staging = sys.staging_level();
        let has_staging = staging != MemLevel::Dram;

        let mut bd = EnergyBreakdown::default();
        let mut dram_bytes: u64 = 0;
        let mut smem_bytes: u64 = 0;
        let mut track = |lvl: MemLevel, elems: u64| match lvl {
            MemLevel::Dram => dram_bytes += elems,
            MemLevel::Smem => smem_bytes += elems,
            _ => {}
        };

        // --- Inputs (A) ---
        // Innermost fill streams into the primitive's input driver,
        // whose energy is folded into the per-MAC cost (Fig 5); we pay
        // the read at the source level.
        let a_inner = fill_at(nest, Tensor::Input, 2);
        if has_staging {
            let a_stage = fill_at(nest, Tensor::Input, 1);
            bd.add_level(MemLevel::Dram, a_stage.elems() as f64 * e.elem_pj(MemLevel::Dram));
            track(MemLevel::Dram, a_stage.elems());
            bd.add_level(staging, a_stage.elems() as f64 * e.elem_pj(staging)); // write
            bd.add_level(staging, a_inner.elems() as f64 * e.elem_pj(staging)); // read
            track(staging, a_stage.elems() + a_inner.elems());
        } else {
            bd.add_level(MemLevel::Dram, a_inner.elems() as f64 * e.elem_pj(MemLevel::Dram));
            track(MemLevel::Dram, a_inner.elems());
        }

        // --- Weights (W) ---
        // DRAM read + write into the CiM host level per (re)load.
        // Weight duplication loads every replica (m_prims copies).
        let w_load = fill_at(nest, Tensor::Weight, 2);
        let w_elems = w_load.elems().saturating_mul(mapping.spatial.m_prims);
        bd.add_level(MemLevel::Dram, w_elems as f64 * e.elem_pj(MemLevel::Dram));
        track(MemLevel::Dram, w_elems);
        bd.add_level(sys.level, w_elems as f64 * e.elem_pj(sys.level));
        if sys.level == MemLevel::Smem {
            track(MemLevel::Smem, 0); // host writes are in-array, not SMEM port traffic
        }

        // --- Outputs (Z) ---
        // Each residency eviction writes outward; each revisit reloads
        // partial sums (read) and merges them (temporal reduction).
        let mut reductions: u64 = 0;
        let z_inner = fill_at(nest, Tensor::Output, 2);
        let outer_of_inner = if has_staging { staging } else { MemLevel::Dram };
        bd.add_level(outer_of_inner, z_inner.elems() as f64 * e.elem_pj(outer_of_inner));
        bd.add_level(outer_of_inner, z_inner.partial_elems() as f64 * e.elem_pj(outer_of_inner));
        track(outer_of_inner, z_inner.elems() + z_inner.partial_elems());
        reductions += z_inner.partial_elems();
        if has_staging {
            let z_stage = fill_at(nest, Tensor::Output, 1);
            // SMEM tile evictions to DRAM (write) + partial refills (read).
            bd.add_level(MemLevel::Dram, z_stage.elems() as f64 * e.elem_pj(MemLevel::Dram));
            bd.add_level(
                MemLevel::Dram,
                z_stage.partial_elems() as f64 * e.elem_pj(MemLevel::Dram),
            );
            track(MemLevel::Dram, z_stage.elems() + z_stage.partial_elems());
            // SMEM side of those transfers.
            bd.add_level(staging, z_stage.elems() as f64 * e.elem_pj(staging));
            bd.add_level(staging, z_stage.partial_elems() as f64 * e.elem_pj(staging));
            track(staging, z_stage.elems() + z_stage.partial_elems());
            reductions += z_stage.partial_elems();
        }

        // --- Compute ---
        bd.mac_pj = macs as f64 * sys.primitive.mac_energy_pj;
        bd.reduction_pj = reductions as f64 * e.reduction_pj;

        let energy_pj = bd.total_pj();

        // --- Cycles ---
        let inner_sweeps: u64 = nest.blocks[..2]
            .iter()
            .flat_map(|b| b.loops.iter())
            .map(|l| l.factor)
            .product();
        // Weight duplication splits the streamed M rows across the
        // replica groups, dividing the sequential row count.
        let m1 = nest.blocks[2]
            .dim_factor(Dim::M)
            .div_ceil(mapping.spatial.m_prims);
        let compute_cycles = inner_sweeps
            * m1
            * mapping.spatial.passes_per_row(sys)
            * sys.primitive.latency_cycles();
        let dram_bw = sys.arch.level(MemLevel::Dram).bandwidth_bytes_per_cycle;
        let smem_bw = sys.arch.level(MemLevel::Smem).bandwidth_bytes_per_cycle;
        let dram_cycles = (dram_bytes as f64 / dram_bw).ceil() as u64;
        let smem_cycles = if sys.level == MemLevel::Smem {
            0 // CiM arrays are the SMEM; its port bandwidth is not on the path
        } else {
            (smem_bytes as f64 / smem_bw).ceil() as u64
        };
        let total_cycles = compute_cycles.max(dram_cycles).max(smem_cycles).max(1);

        Metrics {
            macs,
            ops,
            energy_pj,
            breakdown: bd,
            tops_per_watt: ops as f64 / energy_pj,
            compute_cycles,
            dram_cycles,
            smem_cycles,
            total_cycles,
            gflops: ops as f64 / total_cycles as f64,
            utilization: mapping.spatial.utilization(sys),
            dram_bytes,
            smem_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, SmemConfig};
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn rf_sys(p: CimPrimitive) -> CimSystem {
        CimSystem::at_level(&Architecture::default_sm(), p, MemLevel::RegisterFile)
    }

    fn eval(sys: &CimSystem, g: Gemm) -> Metrics {
        let m = PriorityMapper::new(sys).map(&g);
        CostModel::new(sys).evaluate(&g, &m)
    }

    #[test]
    fn energy_positive_and_consistent() {
        let sys = rf_sys(CimPrimitive::digital_6t());
        let m = eval(&sys, Gemm::new(512, 1024, 1024));
        assert!(m.energy_pj > 0.0);
        assert!((m.breakdown.total_pj() - m.energy_pj).abs() < 1e-6);
        assert!(m.tops_per_watt > 0.0);
        assert!(m.gflops > 0.0);
    }

    #[test]
    fn large_regular_gemm_hits_paper_magnitudes() {
        // §VI-A: CiM at RF reaches roughly 1.7-2 TOPS/W for large
        // regular shapes with D-1, bounded by ~3 TOPS/W overall.
        let sys = rf_sys(CimPrimitive::digital_6t());
        let m = eval(&sys, Gemm::new(512, 1024, 1024));
        assert!(
            m.tops_per_watt > 0.8 && m.tops_per_watt < 4.0,
            "TOPS/W = {}",
            m.tops_per_watt
        );
    }

    #[test]
    fn gemv_is_memory_bound_and_inefficient() {
        // §VI-C: M=1 layers collapse to ~0.03 TOPS/W, dominated by DRAM.
        let sys = rf_sys(CimPrimitive::digital_6t());
        let gemv = eval(&sys, Gemm::new(1, 4096, 4096));
        let gemm = eval(&sys, Gemm::new(512, 4096, 4096));
        assert!(gemv.tops_per_watt < 0.1, "{}", gemv.tops_per_watt);
        assert!(gemv.memory_bound());
        assert!(gemm.tops_per_watt > 10.0 * gemv.tops_per_watt);
    }

    #[test]
    fn throughput_capped_by_peak() {
        let sys = rf_sys(CimPrimitive::digital_6t());
        for g in [
            Gemm::new(512, 1024, 1024),
            Gemm::new(4096, 4096, 4096),
            Gemm::new(64, 64, 64),
        ] {
            let m = eval(&sys, g);
            assert!(
                m.gflops <= sys.peak_gops() * 1.001,
                "{g}: {} > peak {}",
                m.gflops,
                sys.peak_gops()
            );
        }
    }

    #[test]
    fn large_gemm_approaches_peak() {
        let sys = rf_sys(CimPrimitive::digital_6t());
        let m = eval(&sys, Gemm::new(1024, 4096, 4096));
        assert!(
            m.gflops > 0.6 * sys.peak_gops(),
            "{} vs peak {}",
            m.gflops,
            sys.peak_gops()
        );
    }

    #[test]
    fn analog8t_lowest_energy_for_amortized_shapes() {
        // Table V "What": Analog-8T achieves the highest energy
        // efficiency once memory costs amortize — i.e. when the
        // reduction dimension fits the primitives' in-situ capability
        // (the paper's own qualifier: "the size of CiM primitive based
        // accelerators should be tailored to accommodate
        // workload-specific reductions in dimension K").
        let g = Gemm::new(4096, 4096, 128);
        let a2 = eval(&rf_sys(CimPrimitive::analog_8t()), g);
        let d1 = eval(&rf_sys(CimPrimitive::digital_6t()), g);
        let d2 = eval(&rf_sys(CimPrimitive::digital_8t()), g);
        assert!(a2.tops_per_watt > d1.tops_per_watt, "{} vs {}", a2.tops_per_watt, d1.tops_per_watt);
        assert!(a2.tops_per_watt > d2.tops_per_watt);
    }

    #[test]
    fn large_k_erodes_analog_advantage() {
        // Counterpart: when K far exceeds the reduction capability,
        // partial-sum traffic penalizes the narrow-K0 analog macro
        // (Fig 10(c) mechanism).
        let small_k = Gemm::new(4096, 4096, 128);
        let large_k = Gemm::new(4096, 4096, 8192);
        let ratio = |g: Gemm| {
            eval(&rf_sys(CimPrimitive::analog_8t()), g).tops_per_watt
                / eval(&rf_sys(CimPrimitive::digital_6t()), g).tops_per_watt
        };
        assert!(ratio(large_k) < ratio(small_k));
    }

    #[test]
    fn digital6t_highest_throughput() {
        // Table V "What": D-1's full row/column parallelism wins
        // throughput for medium/large shapes.
        let g = Gemm::new(1024, 1024, 1024);
        let d1 = eval(&rf_sys(CimPrimitive::digital_6t()), g);
        for p in [
            CimPrimitive::analog_6t(),
            CimPrimitive::analog_8t(),
            CimPrimitive::digital_8t(),
        ] {
            let other = eval(&rf_sys(p.clone()), g);
            assert!(
                d1.gflops >= other.gflops,
                "D-1 {} vs {} {}",
                d1.gflops,
                p.name,
                other.gflops
            );
        }
    }

    #[test]
    fn smem_configb_outperforms_rf_throughput() {
        // §VI-C: configB exceeds RF throughput ~10x via 16x primitives.
        let arch = Architecture::default_sm();
        let rf = rf_sys(CimPrimitive::digital_6t());
        let smem = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        let g = Gemm::new(2048, 4096, 4096);
        let m_rf = eval(&rf, g);
        let m_smem = eval(&smem, g);
        assert!(
            m_smem.gflops > 5.0 * m_rf.gflops,
            "smem {} vs rf {}",
            m_smem.gflops,
            m_rf.gflops
        );
    }

    #[test]
    fn smem_configa_worse_energy_than_rf() {
        // §VI-C: same primitive count at SMEM loses the intermediate
        // staging level -> more DRAM accesses -> lower TOPS/W.
        let arch = Architecture::default_sm();
        let rf = rf_sys(CimPrimitive::digital_6t());
        let smem_a = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigA);
        let g = Gemm::new(2048, 1024, 1024);
        assert!(eval(&rf, g).tops_per_watt > eval(&smem_a, g).tops_per_watt);
    }

    #[test]
    fn k_beyond_reduction_capacity_raises_partial_traffic() {
        // Fig 10(c): K past the in-CiM reduction capability costs
        // partial-sum accesses -> fj/mac rises.
        let sys = rf_sys(CimPrimitive::digital_6t());
        let small_k = eval(&sys, Gemm::new(512, 512, 256));
        let big_k = eval(&sys, Gemm::new(512, 512, 8192));
        assert!(big_k.breakdown.reduction_pj > small_k.breakdown.reduction_pj);
    }

    #[test]
    fn utilization_in_unit_range() {
        let sys = rf_sys(CimPrimitive::digital_6t());
        for g in [Gemm::new(16, 16, 16), Gemm::new(512, 1024, 1024)] {
            let m = eval(&sys, g);
            assert!((0.0..=1.0).contains(&m.utilization), "{g}: {}", m.utilization);
        }
    }
}
