//! Baseline tensor-core cost model (paper §V-A, §VI-C "Comparison with
//! baseline").
//!
//! The baseline SM computes GEMMs on 4 sub-cores of 16×16 PEs with a
//! conventional DRAM → SMEM → RF → PE-buffer hierarchy. Unlike CiM it
//! is *not* weight-stationary constrained: the mapper blocks all three
//! dimensions at RF and SMEM (cuBLAS-style tiling, §III-B) and keeps
//! outputs stationary in the PE accumulators, which is why small-M
//! GEMMs still utilize the hardware well (§VI-C).

use super::access::fills;
use super::{EnergyBreakdown, Metrics};
use crate::arch::{Architecture, MemLevel};
use crate::mapping::loopnest::{Block, Dim, Loop, LoopNest, Tensor};
use crate::mapping::priority::greedy_order;
use crate::workload::Gemm;

/// Tile extents chosen by the baseline mapper at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl Tile {
    /// Operand + accumulator footprint in INT-8 elements.
    pub fn footprint(&self) -> u64 {
        self.m * self.k + self.k * self.n + self.m * self.n
    }
}

/// Analytical model of the baseline SM.
#[derive(Debug, Clone)]
pub struct BaselineModel<'a> {
    arch: &'a Architecture,
}

impl<'a> BaselineModel<'a> {
    pub fn new(arch: &'a Architecture) -> Self {
        BaselineModel { arch }
    }

    /// Greedily grow a blocked tile (doubling one dimension at a time,
    /// round-robin) until the capacity or the GEMM extents stop it.
    fn block_tile(gemm: &Gemm, start: Tile, capacity: u64) -> Tile {
        let mut t = Tile {
            m: start.m.min(gemm.m),
            n: start.n.min(gemm.n),
            k: start.k.min(gemm.k),
        };
        // If even the seed tile does not fit, shrink it (tiny caches).
        while t.footprint() > capacity {
            let max = t.m.max(t.n).max(t.k);
            if max == 1 {
                break;
            }
            if t.m == max {
                t.m = (t.m / 2).max(1);
            } else if t.n == max {
                t.n = (t.n / 2).max(1);
            } else {
                t.k = (t.k / 2).max(1);
            }
        }
        loop {
            let mut grew = false;
            for dim in 0..3 {
                let cand = match dim {
                    0 => Tile { m: (t.m * 2).min(gemm.m), ..t },
                    1 => Tile { n: (t.n * 2).min(gemm.n), ..t },
                    _ => Tile { k: (t.k * 2).min(gemm.k), ..t },
                };
                if cand != t && cand.footprint() <= capacity {
                    t = cand;
                    grew = true;
                }
            }
            if !grew {
                break;
            }
        }
        t
    }

    /// Build the baseline's blocked loop nest for a GEMM.
    pub fn nest(&self, gemm: &Gemm) -> LoopNest {
        let tc = &self.arch.tensor_core;
        let rf_cap = self.arch.capacity(MemLevel::RegisterFile);
        let smem_cap = self.arch.capacity(MemLevel::Smem);

        let seed = Tile {
            m: tc.tile_m(),
            n: tc.tile_n(),
            k: 64,
        };
        let rf = Self::block_tile(gemm, seed, rf_cap);
        let smem = Self::block_tile(gemm, rf, smem_cap);

        // K streams innermost at every temporal level (cuBLAS-style
        // "split-K last"): output tiles stay resident in the inner
        // levels across the reduction, so partial sums never bounce
        // through SMEM/DRAM. M and N are greedy-ordered among
        // themselves (smallest factor outermost).
        let mut block0_loops = greedy_order(vec![
            Loop::new(Dim::M, gemm.m.div_ceil(smem.m)),
            Loop::new(Dim::N, gemm.n.div_ceil(smem.n)),
        ]);
        block0_loops.push(Loop::new(Dim::K, gemm.k.div_ceil(smem.k)));
        let block0 = Block::new(MemLevel::Dram, block0_loops);
        let mut block1_loops = greedy_order(vec![
            Loop::new(Dim::M, smem.m.div_ceil(rf.m)),
            Loop::new(Dim::N, smem.n.div_ceil(rf.n)),
        ]);
        block1_loops.push(Loop::new(Dim::K, smem.k.div_ceil(rf.k)));
        let block1 = Block::new(MemLevel::Smem, block1_loops);
        // RF block iterates PE-array passes; K innermost keeps the
        // output tile stationary in the PE accumulators.
        let block2 = Block::new(
            MemLevel::RegisterFile,
            vec![
                Loop::new(Dim::N, rf.n.div_ceil(tc.tile_n())),
                Loop::new(Dim::M, rf.m.div_ceil(tc.tile_m())),
                Loop::new(Dim::K, rf.k),
            ],
        );
        // PE-buffer residency: the spatial tile broadcast across the
        // PE grid each cycle.
        let block3 = Block::new(
            MemLevel::PeBuffer,
            vec![
                Loop::new(Dim::M, tc.tile_m().min(gemm.m)),
                Loop::new(Dim::N, tc.tile_n().min(gemm.n)),
            ],
        );

        LoopNest::new(*gemm, vec![block0, block1, block2, block3])
    }

    /// Evaluate a GEMM on the baseline SM.
    pub fn evaluate(&self, gemm: &Gemm) -> Metrics {
        let e = &self.arch.energy;
        let tc = &self.arch.tensor_core;
        let nest = self.nest(gemm);
        let macs = gemm.macs();
        let ops = gemm.ops();

        let chain = [0usize, 1, 2, 3];
        let a = fills(&nest, Tensor::Input, &chain);
        let w = fills(&nest, Tensor::Weight, &chain);
        let z = fills(&nest, Tensor::Output, &chain);

        let mut bd = EnergyBreakdown::default();
        let mut dram_bytes: u64 = 0;
        let mut smem_bytes: u64 = 0;

        // Operand tensors: each boundary crossing reads the outer level
        // and writes the inner one.
        let boundary_mems = [
            (MemLevel::Dram, MemLevel::Smem),
            (MemLevel::Smem, MemLevel::RegisterFile),
            (MemLevel::RegisterFile, MemLevel::PeBuffer),
        ];
        for fl in a.iter().chain(w.iter()) {
            let (src, dst) = boundary_mems[fl.boundary - 1];
            let elems = fl.elems() as f64;
            bd.add_level(src, elems * e.elem_pj(src));
            bd.add_level(dst, elems * e.elem_pj(dst));
            match src {
                MemLevel::Dram => dram_bytes += fl.elems(),
                MemLevel::Smem => smem_bytes += fl.elems(),
                _ => {}
            }
        }
        // Output tensor: evictions write outward, revisits reload
        // partial sums and merge them.
        let mut reductions: u64 = 0;
        for fl in &z {
            let (outer, inner) = boundary_mems[fl.boundary - 1];
            let evict = fl.elems() as f64;
            let partial = fl.partial_elems() as f64;
            bd.add_level(outer, (evict + partial) * e.elem_pj(outer));
            bd.add_level(inner, (evict + partial) * e.elem_pj(inner));
            match outer {
                MemLevel::Dram => dram_bytes += fl.elems() + fl.partial_elems(),
                MemLevel::Smem => smem_bytes += fl.elems() + fl.partial_elems(),
                _ => {}
            }
            reductions += fl.partial_elems();
        }

        // Per-MAC operand reads from the PE buffer (two operands; the
        // accumulator lives in the PE registers).
        bd.add_level(MemLevel::PeBuffer, 2.0 * macs as f64 * e.elem_pj(MemLevel::PeBuffer));
        bd.mac_pj = macs as f64 * e.mac_pj;
        bd.reduction_pj = reductions as f64 * e.reduction_pj;
        let energy_pj = bd.total_pj();

        // Cycles: the PE grid retires tile_m x tile_n MACs per cycle.
        let compute_cycles =
            gemm.m.div_ceil(tc.tile_m()) * gemm.n.div_ceil(tc.tile_n()) * gemm.k;
        let dram_bw = self.arch.level(MemLevel::Dram).bandwidth_bytes_per_cycle;
        let smem_bw = self.arch.level(MemLevel::Smem).bandwidth_bytes_per_cycle;
        let dram_cycles = (dram_bytes as f64 / dram_bw).ceil() as u64;
        let smem_cycles = (smem_bytes as f64 / smem_bw).ceil() as u64;
        let total_cycles = compute_cycles.max(dram_cycles).max(smem_cycles).max(1);

        Metrics {
            macs,
            ops,
            energy_pj,
            breakdown: bd,
            tops_per_watt: ops as f64 / energy_pj,
            compute_cycles,
            dram_cycles,
            smem_cycles,
            total_cycles,
            gflops: ops as f64 / total_cycles as f64,
            utilization: macs as f64 / (compute_cycles * tc.macs_per_cycle()) as f64,
            dram_bytes,
            smem_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> Architecture {
        Architecture::default_sm()
    }

    #[test]
    fn tile_growth_respects_capacity() {
        let g = Gemm::new(8192, 8192, 8192);
        let t = BaselineModel::block_tile(&g, Tile { m: 16, n: 64, k: 64 }, 16 * 1024);
        assert!(t.footprint() <= 16 * 1024);
        assert!(t.m >= 16 && t.n >= 64);
    }

    #[test]
    fn tile_clamped_to_gemm() {
        let g = Gemm::new(8, 8, 8);
        let t = BaselineModel::block_tile(&g, Tile { m: 16, n: 64, k: 64 }, 16 * 1024);
        assert_eq!(t, Tile { m: 8, n: 8, k: 8 });
    }

    #[test]
    fn nest_valid_for_odd_shapes() {
        let arch = model();
        let bm = BaselineModel::new(&arch);
        for g in [
            Gemm::new(12544, 64, 147),
            Gemm::new(1, 1000, 2048),
            Gemm::new(512, 1024, 1024),
            Gemm::new(3, 5, 7),
        ] {
            assert!(bm.nest(&g).validate().is_ok(), "{g}");
        }
    }

    #[test]
    fn peak_throughput_for_large_gemms() {
        let arch = model();
        let bm = BaselineModel::new(&arch);
        let m = bm.evaluate(&Gemm::new(4096, 4096, 4096));
        assert!(m.gflops <= arch.tensor_core.peak_gops() * 1.001);
        assert!(m.gflops > 0.8 * arch.tensor_core.peak_gops(), "{}", m.gflops);
        assert!(m.utilization > 0.9);
    }

    #[test]
    fn small_m_still_utilizes_partially() {
        // §VI-C: flexible mapping keeps baseline competitive at small M
        // (it loses parallelism only on the PE rows).
        let arch = model();
        let bm = BaselineModel::new(&arch);
        let m = bm.evaluate(&Gemm::new(1, 4096, 4096));
        assert!(m.utilization >= 1.0 / 16.0 - 1e-9, "{}", m.utilization);
    }

    #[test]
    fn energy_scales_with_work() {
        let arch = model();
        let bm = BaselineModel::new(&arch);
        let small = bm.evaluate(&Gemm::new(256, 256, 256));
        let large = bm.evaluate(&Gemm::new(1024, 1024, 1024));
        assert!(large.energy_pj > small.energy_pj);
        // but energy *per MAC* improves or holds with amortization
        assert!(large.fj_per_mac() <= small.fj_per_mac() * 1.5);
    }

    #[test]
    fn baseline_pays_rf_and_pebuf_energy() {
        // The costs CiM integration eliminates must be present here.
        let arch = model();
        let bm = BaselineModel::new(&arch);
        let m = bm.evaluate(&Gemm::new(512, 1024, 1024));
        assert!(m.breakdown.rf_pj > 0.0);
        assert!(m.breakdown.pe_buf_pj > 0.0);
    }

    #[test]
    fn memory_bound_gemv() {
        let arch = model();
        let bm = BaselineModel::new(&arch);
        let m = bm.evaluate(&Gemm::new(1, 256, 512));
        assert!(m.memory_bound());
    }
}
