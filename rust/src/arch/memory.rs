//! Memory-hierarchy levels of the modelled SM.

/// A level of the on-chip/off-chip memory hierarchy, ordered
/// outermost (DRAM) to innermost (PE buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MemLevel {
    /// Main memory; assumed large enough to hold all matrices (§IV-B).
    Dram,
    /// Shared memory of the SM: 256 KB, 42 B/cycle (§V-A).
    Smem,
    /// Register file: 4×4 KB (§V-A).
    RegisterFile,
    /// Per-PE operand buffer of the baseline tensor core.
    PeBuffer,
}

impl MemLevel {
    pub fn short_name(self) -> &'static str {
        match self {
            MemLevel::Dram => "DRAM",
            MemLevel::Smem => "SMEM",
            MemLevel::RegisterFile => "RF",
            MemLevel::PeBuffer => "PEBUF",
        }
    }

    /// Parse a user-facing level name (CLI).
    pub fn parse(s: &str) -> Option<MemLevel> {
        match s.to_ascii_lowercase().as_str() {
            "dram" => Some(MemLevel::Dram),
            "smem" | "shared" => Some(MemLevel::Smem),
            "rf" | "regfile" | "registerfile" => Some(MemLevel::RegisterFile),
            "pebuf" | "pebuffer" => Some(MemLevel::PeBuffer),
            _ => None,
        }
    }

    /// All levels, outermost first.
    pub fn all() -> [MemLevel; 4] {
        [
            MemLevel::Dram,
            MemLevel::Smem,
            MemLevel::RegisterFile,
            MemLevel::PeBuffer,
        ]
    }

    /// The next level outward (toward DRAM).
    pub fn outer(self) -> Option<MemLevel> {
        match self {
            MemLevel::Dram => None,
            MemLevel::Smem => Some(MemLevel::Dram),
            MemLevel::RegisterFile => Some(MemLevel::Smem),
            MemLevel::PeBuffer => Some(MemLevel::RegisterFile),
        }
    }
}

impl std::fmt::Display for MemLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.short_name())
    }
}

/// Static description of one memory level.
#[derive(Debug, Clone)]
pub struct MemoryLevelSpec {
    pub level: MemLevel,
    /// Storage capacity in bytes. `u64::MAX` for DRAM ("large enough to
    /// fit all the matrices", §IV-B).
    pub capacity_bytes: u64,
    /// Sustained bandwidth into the level below, bytes per cycle (§V-A).
    pub bandwidth_bytes_per_cycle: f64,
}

impl MemoryLevelSpec {
    pub fn dram() -> Self {
        MemoryLevelSpec {
            level: MemLevel::Dram,
            capacity_bytes: u64::MAX,
            bandwidth_bytes_per_cycle: 32.0,
        }
    }

    pub fn smem() -> Self {
        MemoryLevelSpec {
            level: MemLevel::Smem,
            capacity_bytes: 256 * 1024,
            bandwidth_bytes_per_cycle: 42.0,
        }
    }

    pub fn rf() -> Self {
        MemoryLevelSpec {
            level: MemLevel::RegisterFile,
            capacity_bytes: 4 * 4 * 1024,
            // RF feeds the PEs every cycle; modelled as not
            // bandwidth-limiting (the paper limits only SMEM/DRAM).
            bandwidth_bytes_per_cycle: f64::INFINITY,
        }
    }

    pub fn pe_buffer() -> Self {
        MemoryLevelSpec {
            level: MemLevel::PeBuffer,
            // 16x16 PEs x a few operand registers; capacity is not a
            // binding constraint in the paper's model.
            capacity_bytes: 2 * 1024,
            bandwidth_bytes_per_cycle: f64::INFINITY,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_outer_to_inner() {
        assert!(MemLevel::Dram < MemLevel::Smem);
        assert!(MemLevel::Smem < MemLevel::RegisterFile);
        assert!(MemLevel::RegisterFile < MemLevel::PeBuffer);
    }

    #[test]
    fn parse_names() {
        assert_eq!(MemLevel::parse("rf"), Some(MemLevel::RegisterFile));
        assert_eq!(MemLevel::parse("SMEM"), Some(MemLevel::Smem));
        assert_eq!(MemLevel::parse("dram"), Some(MemLevel::Dram));
        assert_eq!(MemLevel::parse("bogus"), None);
    }

    #[test]
    fn outer_chain() {
        assert_eq!(MemLevel::PeBuffer.outer(), Some(MemLevel::RegisterFile));
        assert_eq!(MemLevel::RegisterFile.outer(), Some(MemLevel::Smem));
        assert_eq!(MemLevel::Smem.outer(), Some(MemLevel::Dram));
        assert_eq!(MemLevel::Dram.outer(), None);
    }

    #[test]
    fn display_matches_short_name() {
        assert_eq!(MemLevel::Smem.to_string(), "SMEM");
    }

    #[test]
    fn dram_is_unbounded() {
        assert_eq!(MemoryLevelSpec::dram().capacity_bytes, u64::MAX);
    }
}
