//! Interconnect cost model (extension; paper §VI-D: "such an
//! exploration should also take into account the interconnect cost
//! associated with dataflow flexibility").
//!
//! CiM primitives tiled along K must merge their partial outputs, and
//! inputs must be multicast to primitives tiled along N. We model a
//! mesh NoC over the primitive array: per-element-per-hop energy, with
//! a binary reduction tree across the `k_prims` groups and a multicast
//! tree across `n_prims` groups.

use crate::mapping::loopnest::Dim;
use crate::mapping::Mapping;

/// Mesh NoC parameters.
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Energy per INT-8 element per hop (pJ). Calibrated to on-chip
    /// wire energy at 45 nm (~0.1 pJ/byte/mm, primitive pitch < 1 mm).
    pub hop_pj: f64,
}

impl Default for Interconnect {
    fn default() -> Self {
        Interconnect { hop_pj: 0.06 }
    }
}

impl Interconnect {
    /// Total interconnect energy (pJ) for executing `mapping` once:
    /// * partial-sum reduction: each output element produced per weight
    ///   residency crosses a log2(k_prims)-deep tree (4-byte partials);
    /// * input multicast: each input element fans out across n_prims
    ///   (log2 tree) — one extra copy per tree level.
    pub fn energy_pj(&self, mapping: &Mapping) -> f64 {
        let s = &mapping.spatial;
        let g = &mapping.gemm;
        let reduction_hops = (s.k_prims as f64).log2().ceil().max(0.0);
        let multicast_hops = (s.n_prims as f64).log2().ceil().max(0.0);

        // Output elements emitted per full execution: every (m, n)
        // element once per K residency (in-primitive reduction covers
        // K0; cross-primitive merging covers k_prims groups).
        let n_res_k = g.k.div_ceil(mapping.k0()) as f64;
        let z_transfers = (g.m * g.n) as f64 * n_res_k * 4.0; // int32 partials
        // Input elements streamed: M×K per N-residency sweep.
        let n_res_n = g.n.div_ceil(mapping.n0()) as f64;
        let a_transfers = (g.m * g.k) as f64 * n_res_n;

        self.hop_pj * (z_transfers * reduction_hops + a_transfers * multicast_hops)
    }

    /// Interconnect energy as a fraction of a given base energy.
    pub fn overhead_fraction(&self, mapping: &Mapping, base_energy_pj: f64) -> f64 {
        self.energy_pj(mapping) / base_energy_pj
    }

    /// Latency overhead in cycles: the reduction tree adds pipeline
    /// depth, negligible against CiM pass latency unless k_prims is
    /// large; modelled as log2(k_prims) cycles per residency sweep.
    pub fn extra_cycles(&self, mapping: &Mapping) -> u64 {
        let s = &mapping.spatial;
        let sweeps: u64 = mapping.nest.blocks[..2]
            .iter()
            .flat_map(|b| b.loops.iter())
            .map(|l| l.factor)
            .product();
        let m1 = mapping.nest.blocks[2].dim_factor(Dim::M);
        sweeps * m1 * (s.k_prims as f64).log2().ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, CimSystem, MemLevel, SmemConfig};
    use crate::cim::CimPrimitive;
    use crate::cost::CostModel;
    use crate::mapping::PriorityMapper;
    use crate::workload::Gemm;

    fn mapping(g: Gemm, smem: bool) -> (CimSystem, Mapping) {
        let arch = Architecture::default_sm();
        let sys = if smem {
            CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB)
        } else {
            CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile)
        };
        let m = PriorityMapper::new(&sys).map(&g);
        (sys, m)
    }

    #[test]
    fn single_primitive_has_no_noc_cost() {
        let (_, m) = mapping(Gemm::new(64, 16, 256), false);
        assert_eq!(m.spatial.prims_used(), 1);
        assert_eq!(Interconnect::default().energy_pj(&m), 0.0);
    }

    #[test]
    fn deeper_trees_cost_more_per_transfer() {
        // Same residency structure, deeper trees: scaling hop energy is
        // linear, and a K-split mapping pays reduction energy a pure
        // N-split does not.
        let (_, m) = mapping(Gemm::new(512, 1024, 1024), true); // configB, kp>1
        assert!(m.spatial.k_prims > 1, "{:?}", m.spatial);
        let cheap = Interconnect { hop_pj: 0.01 };
        let dear = Interconnect { hop_pj: 0.02 };
        let (e1, e2) = (cheap.energy_pj(&m), dear.energy_pj(&m));
        assert!(e1 > 0.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-6 * e2, "linear in hop energy");
    }

    #[test]
    fn overhead_is_minor_for_rf_integration() {
        // Sanity: the NoC does not overturn the paper's conclusions at
        // RF scale (few primitives, short trees).
        let g = Gemm::new(512, 1024, 1024);
        let (sys, m) = mapping(g, false);
        let base = CostModel::new(&sys).evaluate(&g, &m).energy_pj;
        let frac = Interconnect::default().overhead_fraction(&m, base);
        assert!(frac < 0.25, "NoC overhead {frac}");
    }

    #[test]
    fn extra_cycles_zero_without_k_split() {
        let (_, m) = mapping(Gemm::new(64, 16, 256), false);
        assert_eq!(m.spatial.k_prims, 1);
        assert_eq!(Interconnect::default().extra_cycles(&m), 0);
    }
}
