//! Per-access energy costs (paper Table III, INT-8, 45 nm, from
//! Accelergy [38]). Units: pJ per INT-8 element access.

use super::memory::MemLevel;

/// Energy table of the modelled SM.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    pub dram_access_pj: f64,
    pub smem_access_pj: f64,
    pub rf_access_pj: f64,
    pub pe_buffer_access_pj: f64,
    /// Baseline tensor-core MAC (INT-8).
    pub mac_pj: f64,
    /// Temporal (partial-sum) reduction, per addition (§V-D).
    pub reduction_pj: f64,
}

impl EnergyTable {
    /// Table III verbatim.
    pub fn table_iii() -> Self {
        EnergyTable {
            dram_access_pj: 512.0,
            smem_access_pj: 124.69,
            rf_access_pj: 11.47,
            pe_buffer_access_pj: 0.02,
            mac_pj: 0.26,
            reduction_pj: 0.05,
        }
    }

    /// Access energy for a given hierarchy level (per transaction).
    pub fn access_pj(&self, lvl: MemLevel) -> f64 {
        match lvl {
            MemLevel::Dram => self.dram_access_pj,
            MemLevel::Smem => self.smem_access_pj,
            MemLevel::RegisterFile => self.rf_access_pj,
            MemLevel::PeBuffer => self.pe_buffer_access_pj,
        }
    }

    /// Access energy per INT-8 *element*. Table III costs are per
    /// coalesced access transaction of [`COALESCE_BYTES`] — the paper
    /// "assumes all memory accesses are coalesced" (§VI-D). The width
    /// is calibrated against the paper's own numbers: GPT-J's
    /// (1,4096,4096) GEMV at 0.03 TOPS/W is DRAM-dominated by its one
    /// 16.8M-element weight fetch, implying ≈64 pJ/element = 512 pJ per
    /// 8-byte transaction (and BERT's ≈1.7–1.9 TOPS/W confirms it).
    pub fn elem_pj(&self, lvl: MemLevel) -> f64 {
        self.access_pj(lvl) / COALESCE_BYTES as f64
    }
}

/// Bytes per coalesced memory transaction (see [`EnergyTable::elem_pj`]).
pub const COALESCE_BYTES: u64 = 8;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iii_constants() {
        let e = EnergyTable::table_iii();
        assert_eq!(e.access_pj(MemLevel::Dram), 512.0);
        assert_eq!(e.access_pj(MemLevel::Smem), 124.69);
        assert_eq!(e.access_pj(MemLevel::RegisterFile), 11.47);
        assert_eq!(e.access_pj(MemLevel::PeBuffer), 0.02);
        assert_eq!(e.mac_pj, 0.26);
        assert_eq!(e.reduction_pj, 0.05);
    }

    #[test]
    fn hierarchy_energy_is_monotone() {
        // The memory wall: each level outward costs more per access.
        let e = EnergyTable::table_iii();
        assert!(e.dram_access_pj > e.smem_access_pj);
        assert!(e.smem_access_pj > e.rf_access_pj);
        assert!(e.rf_access_pj > e.pe_buffer_access_pj);
    }
}
