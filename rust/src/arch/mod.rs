//! Architecture model: the tensor-core-like streaming multiprocessor
//! (SM) of the paper's §V-A, its memory hierarchy, and the description
//! of a CiM-integrated variant ([`CimSystem`]).
//!
//! Baseline (paper §V-A): one SM with 4 sub-cores, each a 16×16 PE
//! tensor-core-like grid; register file 4×4 KB, shared memory 256 KB;
//! SMEM bandwidth 42 B/cycle, DRAM 32 B/cycle; INT-8 precision, 45 nm,
//! 1 GHz. Energy per access from Table III (Accelergy).

pub mod baseline;
pub mod energy;
pub mod interconnect;
pub mod memory;
pub mod multi_sm;

pub use baseline::TensorCore;
pub use interconnect::Interconnect;
pub use multi_sm::MultiSm;
pub use energy::EnergyTable;
pub use memory::{MemLevel, MemoryLevelSpec};

use crate::cim::{isoarea, CimPrimitive};

/// Operating frequency of the modelled SM (cycles <-> ns conversion).
pub const FREQ_GHZ: f64 = 1.0;

/// Bytes per INT-8 element; the whole evaluation is INT-8 (§V-A).
pub const BYTES_PER_ELEM: u64 = 1;

/// The modelled architecture: memory hierarchy + baseline compute.
#[derive(Debug, Clone)]
pub struct Architecture {
    /// Hierarchy ordered outer -> inner: DRAM, SMEM, RF, PE buffer.
    pub levels: Vec<MemoryLevelSpec>,
    pub energy: EnergyTable,
    pub tensor_core: TensorCore,
}

impl Architecture {
    /// The paper's baseline SM (§V-A, Table III).
    pub fn default_sm() -> Self {
        Architecture {
            levels: vec![
                MemoryLevelSpec::dram(),
                MemoryLevelSpec::smem(),
                MemoryLevelSpec::rf(),
                MemoryLevelSpec::pe_buffer(),
            ],
            energy: EnergyTable::table_iii(),
            tensor_core: TensorCore::default_sm(),
        }
    }

    /// Spec of a given hierarchy level.
    pub fn level(&self, lvl: MemLevel) -> &MemoryLevelSpec {
        self.levels
            .iter()
            .find(|l| l.level == lvl)
            // lint: allow(R4): every Architecture constructor installs all four levels; a miss is a construction bug
            .expect("level missing from architecture")
    }

    /// Capacity of `lvl` in bytes.
    pub fn capacity(&self, lvl: MemLevel) -> u64 {
        self.level(lvl).capacity_bytes
    }
}

/// SMEM integration configurations of §VI-C.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SmemConfig {
    /// configA: same number of CiM primitives as the RF integration
    /// (compute parity), remaining SMEM capacity stays plain storage.
    ConfigA,
    /// configB: all CiM primitives that fit in SMEM under iso-area.
    ConfigB,
}

/// A CiM-integrated SM: `count` copies of `primitive` replace the
/// storage of `level` under iso-area constraints (§VI intro).
#[derive(Debug, Clone)]
pub struct CimSystem {
    pub arch: Architecture,
    pub primitive: CimPrimitive,
    pub level: MemLevel,
    /// Number of CiM primitives integrated (iso-area rule).
    pub count: u64,
    pub smem_config: Option<SmemConfig>,
}

impl CimSystem {
    /// Integrate `primitive` at `level` with the iso-area primitive count.
    /// For SMEM, defaults to configB (all that fit).
    pub fn at_level(arch: &Architecture, primitive: CimPrimitive, level: MemLevel) -> Self {
        match level {
            MemLevel::RegisterFile => {
                let count = isoarea::primitives_fitting(arch.capacity(level), &primitive);
                CimSystem {
                    arch: arch.clone(),
                    primitive,
                    level,
                    count,
                    smem_config: None,
                }
            }
            MemLevel::Smem => Self::at_smem(arch, primitive, SmemConfig::ConfigB),
            // lint: allow(R4): callers pick the level from a fixed RF/SMEM menu; the paper models no other integration point
            other => panic!("CiM integration modelled at RF/SMEM only, got {other:?}"),
        }
    }

    /// Integrate at SMEM with an explicit §VI-C configuration.
    pub fn at_smem(arch: &Architecture, primitive: CimPrimitive, cfg: SmemConfig) -> Self {
        let count = match cfg {
            SmemConfig::ConfigA => {
                isoarea::primitives_fitting(arch.capacity(MemLevel::RegisterFile), &primitive)
            }
            SmemConfig::ConfigB => {
                isoarea::primitives_fitting(arch.capacity(MemLevel::Smem), &primitive)
            }
        };
        CimSystem {
            arch: arch.clone(),
            primitive,
            level: MemLevel::Smem,
            count,
            smem_config: Some(cfg),
        }
    }

    /// Total weight-storage capacity across all integrated primitives,
    /// in INT-8 elements.
    pub fn weight_capacity_elems(&self) -> u64 {
        self.count * self.primitive.weight_rows() * self.primitive.weight_cols()
    }

    /// Peak compute throughput in GOPS (Appendix B):
    /// `2 * Rp * Cp * count / latency_ns`.
    pub fn peak_gops(&self) -> f64 {
        let p = &self.primitive;
        2.0 * (p.rp * p.cp * self.count) as f64 / p.latency_ns
    }

    /// The staging level that feeds the CiM level (inputs held there for
    /// reuse): SMEM when CiM sits in the RF, DRAM when CiM sits in SMEM.
    pub fn staging_level(&self) -> MemLevel {
        match self.level {
            MemLevel::RegisterFile => MemLevel::Smem,
            MemLevel::Smem => MemLevel::Dram,
            // lint: allow(R4): CimSystem construction only ever sets level to RF or SMEM (see at_level)
            other => panic!("no staging level for {other:?}"),
        }
    }

    /// Human-readable system name for reports.
    pub fn label(&self) -> String {
        let cfg = match self.smem_config {
            Some(SmemConfig::ConfigA) => "/configA",
            Some(SmemConfig::ConfigB) => "/configB",
            None => "",
        };
        format!(
            "{}@{}{} x{}",
            self.primitive.name,
            self.level.short_name(),
            cfg,
            self.count
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::CimPrimitive;

    #[test]
    fn default_sm_matches_paper_constants() {
        let a = Architecture::default_sm();
        assert_eq!(a.capacity(MemLevel::RegisterFile), 4 * 4 * 1024);
        assert_eq!(a.capacity(MemLevel::Smem), 256 * 1024);
        assert_eq!(a.level(MemLevel::Smem).bandwidth_bytes_per_cycle, 42.0);
        assert_eq!(a.level(MemLevel::Dram).bandwidth_bytes_per_cycle, 32.0);
        // SMEM capacity is 16x the RF capacity (§VI-C).
        assert_eq!(
            a.capacity(MemLevel::Smem),
            16 * a.capacity(MemLevel::RegisterFile)
        );
    }

    #[test]
    fn rf_digital6t_fits_three_primitives() {
        // Appendix B: "3 instances of Digital6T ... at register file level".
        let sys = CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        );
        assert_eq!(sys.count, 3);
    }

    #[test]
    fn rf_digital6t_peak_matches_appendix_b() {
        // peak = 2*256*16*3/18ns = 1365 GOPS.
        let sys = CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        );
        assert!((sys.peak_gops() - 1365.33).abs() < 1.0, "{}", sys.peak_gops());
    }

    #[test]
    fn smem_configs() {
        let arch = Architecture::default_sm();
        let a = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigA);
        let b = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        assert_eq!(a.count, 3); // parity with RF
        // §VI-C: configB has 16x the primitives of configA.
        assert_eq!(b.count, 46); // round(256/(4*1.4)) — ≈16x configA
    }

    #[test]
    fn staging_levels() {
        let arch = Architecture::default_sm();
        let rf = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        assert_eq!(rf.staging_level(), MemLevel::Smem);
        let sm = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        assert_eq!(sm.staging_level(), MemLevel::Dram);
    }

    #[test]
    fn weight_capacity() {
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        // 3 primitives x 256 rows x 16 cols = 12288 INT8 weights.
        assert_eq!(sys.weight_capacity_elems(), 3 * 256 * 16);
    }

    #[test]
    fn label_is_informative() {
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        let l = sys.label();
        assert!(l.contains("Digital-6T") && l.contains("RF") && l.contains("x3"), "{l}");
    }
}
