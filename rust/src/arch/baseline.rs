//! Baseline tensor-core compute model (paper §V-A).
//!
//! One SM with 4 sub-cores, each a 16×16 grid of processing elements
//! performing one INT-8 MAC per cycle — "tensor-core-like operations".
//! Unlike the CiM primitives, the baseline is *not* weight-stationary
//! constrained: its mapper may pick any loop order (§VI-C "Comparison
//! with baseline").

/// Static description of the baseline SM compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorCore {
    pub subcores: u64,
    pub pe_rows: u64,
    pub pe_cols: u64,
}

impl TensorCore {
    /// The paper's SM: 4 sub-cores × 16×16 PEs.
    pub fn default_sm() -> Self {
        TensorCore {
            subcores: 4,
            pe_rows: 16,
            pe_cols: 16,
        }
    }

    /// MAC operations retired per cycle at full utilization.
    pub fn macs_per_cycle(&self) -> u64 {
        self.subcores * self.pe_rows * self.pe_cols
    }

    /// Peak throughput in GOPS at 1 GHz (2 ops per MAC).
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.macs_per_cycle() as f64 * super::FREQ_GHZ
    }

    /// PE-grid tile dimensions available to one GEMM call:
    /// the M×N output tile computed in parallel each cycle across
    /// sub-cores. Sub-cores extend the N dimension (channel-parallel),
    /// matching how GEMM tiles are spread over sub-cores in GPUs.
    pub fn tile_m(&self) -> u64 {
        self.pe_rows
    }

    pub fn tile_n(&self) -> u64 {
        self.pe_cols * self.subcores
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sm_peak() {
        let tc = TensorCore::default_sm();
        assert_eq!(tc.macs_per_cycle(), 1024);
        assert_eq!(tc.peak_gops(), 2048.0);
    }

    #[test]
    fn tiles_cover_pe_grid() {
        let tc = TensorCore::default_sm();
        assert_eq!(tc.tile_m() * tc.tile_n(), tc.macs_per_cycle());
    }
}
