//! Multi-SM scaling model (extension).
//!
//! The paper evaluates a single SM and notes that "a GPU consists of
//! hundreds of such SMs, resulting in overall peak performance of the
//! order of PFLOPS" (§V-A). This module scales the single-SM results to
//! `n` SMs sharing the DRAM interface: compute scales linearly (the
//! output matrix is partitioned across SMs), while the aggregate DRAM
//! traffic contends for one memory interface whose bandwidth grows
//! sub-linearly — exposing the memory wall the paper's intro leads
//! with.

use crate::cost::Metrics;

/// Multi-SM scaling configuration.
#[derive(Debug, Clone, Copy)]
pub struct MultiSm {
    /// Number of streaming multiprocessors.
    pub sm_count: u64,
    /// DRAM bandwidth scaling exponent: aggregate bandwidth =
    /// single-SM bandwidth × sm_count^beta. beta = 1 is ideal
    /// (never realistic); GPUs land around 0.4–0.6 once HBM channel
    /// counts stop tracking SM counts.
    pub bandwidth_beta: f64,
}

impl MultiSm {
    pub fn new(sm_count: u64) -> Self {
        MultiSm {
            sm_count,
            bandwidth_beta: 0.5,
        }
    }

    /// Aggregate DRAM bandwidth relative to one SM's share.
    pub fn bandwidth_scale(&self) -> f64 {
        (self.sm_count as f64).powf(self.bandwidth_beta)
    }

    /// Scale single-SM metrics to this configuration. The GEMM is
    /// partitioned output-parallel across SMs (each SM sees 1/n of the
    /// compute *and* of the per-SM traffic, but weights are broadcast —
    /// we conservatively keep per-SM traffic equal to the single-SM
    /// evaluation of its slice, i.e. total traffic grows ~n^0 for
    /// activations and up to n for shared weights; the simple model
    /// here replays total traffic = single-SM traffic, compute time /
    /// n, memory time / bandwidth_scale).
    pub fn scale(&self, single: &Metrics) -> Metrics {
        let n = self.sm_count as f64;
        let compute_cycles = (single.compute_cycles as f64 / n).ceil() as u64;
        let dram_cycles =
            (single.dram_cycles as f64 / self.bandwidth_scale()).ceil() as u64;
        let smem_cycles = (single.smem_cycles as f64 / n).ceil() as u64;
        let total_cycles = compute_cycles.max(dram_cycles).max(smem_cycles).max(1);
        Metrics {
            total_cycles,
            compute_cycles,
            dram_cycles,
            smem_cycles,
            gflops: single.ops as f64 / total_cycles as f64,
            // Energy is workload energy — unchanged by parallelism
            // (same accesses, same MACs), so TOPS/W carries over.
            ..*single
        }
    }

    /// The SM count at which this workload stops scaling (compute time
    /// dips below memory time): the knee of the scaling curve.
    pub fn scaling_knee(&self, single: &Metrics) -> u64 {
        let mut n = 1u64;
        while n < 4096 {
            let m = MultiSm {
                sm_count: n * 2,
                ..*self
            }
            .scale(single);
            if m.memory_bound() {
                return n;
            }
            n *= 2;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, CimSystem, MemLevel};
    use crate::cim::CimPrimitive;
    use crate::cost::CostModel;
    use crate::mapping::PriorityMapper;
    use crate::workload::Gemm;

    fn single() -> Metrics {
        let arch = Architecture::default_sm();
        let sys =
            CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        let g = Gemm::new(2048, 4096, 4096);
        CostModel::new(&sys).evaluate(&g, &PriorityMapper::new(&sys).map(&g))
    }

    #[test]
    fn one_sm_is_identity() {
        let s = single();
        let scaled = MultiSm::new(1).scale(&s);
        assert_eq!(scaled.total_cycles, s.total_cycles);
        assert_eq!(scaled.gflops, s.gflops);
    }

    #[test]
    fn throughput_grows_then_saturates() {
        let s = single();
        let f2 = MultiSm::new(2).scale(&s).gflops;
        let f16 = MultiSm::new(16).scale(&s).gflops;
        let f1024 = MultiSm::new(1024).scale(&s).gflops;
        assert!(f2 > s.gflops);
        assert!(f16 > f2);
        // far out, DRAM bandwidth dominates: sublinear
        assert!(f1024 < 1024.0 / 2.0 * s.gflops);
    }

    #[test]
    fn memory_wall_emerges() {
        let s = single();
        let big = MultiSm::new(2048).scale(&s);
        assert!(big.memory_bound(), "2048 SMs must be DRAM-bound");
    }

    #[test]
    fn knee_is_finite_and_sane() {
        let s = single();
        let knee = MultiSm::new(1).scaling_knee(&s);
        assert!(knee >= 1 && knee <= 4096);
        // at the knee, still compute bound
        assert!(!MultiSm::new(knee).scale(&s).memory_bound());
    }

    #[test]
    fn ideal_bandwidth_never_saturates_compute() {
        let s = single();
        let ideal = MultiSm {
            sm_count: 256,
            bandwidth_beta: 1.0,
        };
        let scaled = ideal.scale(&s);
        // with bandwidth scaling as fast as compute, boundedness class
        // is preserved from the single-SM evaluation
        assert_eq!(scaled.memory_bound(), s.memory_bound());
    }
}
