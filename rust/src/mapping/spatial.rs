//! Spatial assignment of the weight matrix across CiM primitives.
//!
//! The stationary weight tile spans `k_prims × n_prims` primitives;
//! within each primitive, `ku × nu` weight positions are occupied
//! (`ku ≤ Rp·Rh` rows, `nu ≤ Cp·Ch` columns). The paper's §IV-B gives
//! priority to *parallelism* — weights spread across primitives before
//! filling a primitive's sequential (hold) positions.

use crate::arch::CimSystem;

/// Spatial weight placement across the integrated primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CimSpatial {
    /// Primitives tiled along the reduction dimension K.
    pub k_prims: u64,
    /// Primitives tiled along the output dimension N.
    pub n_prims: u64,
    /// Weight rows used per primitive (K direction, ≤ Rp·Rh).
    pub ku: u64,
    /// Weight columns used per primitive (N direction, ≤ Cp·Ch).
    pub nu: u64,
    /// Weight-duplication factor: copies of the stationary tile across
    /// primitive groups, each processing a disjoint slice of M in
    /// parallel (the paper's §IV-B future-work extension; 1 = off).
    pub m_prims: u64,
}

impl CimSpatial {
    /// Primitives actually holding weights (duplication included).
    pub fn prims_used(&self) -> u64 {
        self.k_prims * self.n_prims * self.m_prims
    }

    /// Stationary tile extent along K (clamped to the GEMM's K).
    pub fn k0(&self, k: u64) -> u64 {
        (self.k_prims * self.ku).min(k)
    }

    /// Stationary tile extent along N (clamped to the GEMM's N).
    pub fn n0(&self, n: u64) -> u64 {
        (self.n_prims * self.nu).min(n)
    }

    /// Sequential primitive passes needed per input row: each pass
    /// covers `Rp × Cp` parallel MACs; the held (sequential) positions
    /// multiply passes (§IV-A).
    pub fn passes_per_row(&self, sys: &CimSystem) -> u64 {
        let p = &sys.primitive;
        self.ku.div_ceil(p.rp) * self.nu.div_ceil(p.cp)
    }

    /// Compute-hardware utilization (§V-D): occupied MAC positions over
    /// the total positions of all integrated primitives (each CiM unit
    /// contributes `Rh × Ch` MAC units).
    pub fn utilization(&self, sys: &CimSystem) -> f64 {
        let p = &sys.primitive;
        let total = (sys.count * p.weight_rows() * p.weight_cols()) as f64;
        (self.prims_used() * self.ku * self.nu) as f64 / total
    }

    /// Validity against the system: fits the primitive grid and the
    /// integrated primitive count.
    pub fn validate(&self, sys: &CimSystem) -> Result<(), String> {
        let p = &sys.primitive;
        if self.ku == 0
            || self.nu == 0
            || self.k_prims == 0
            || self.n_prims == 0
            || self.m_prims == 0
        {
            return Err("spatial extents must be positive".into());
        }
        if self.ku > p.weight_rows() {
            return Err(format!("ku {} > rows {}", self.ku, p.weight_rows()));
        }
        if self.nu > p.weight_cols() {
            return Err(format!("nu {} > cols {}", self.nu, p.weight_cols()));
        }
        if self.prims_used() > sys.count {
            return Err(format!(
                "uses {} primitives > integrated {}",
                self.prims_used(),
                sys.count
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, MemLevel};
    use crate::cim::CimPrimitive;

    fn d1_rf() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn extents_and_clamping() {
        let s = CimSpatial {
            k_prims: 2,
            n_prims: 1,
            ku: 256,
            nu: 16,
            m_prims: 1,
        };
        assert_eq!(s.k0(1024), 512);
        assert_eq!(s.k0(300), 300); // clamped to GEMM K
        assert_eq!(s.n0(1024), 16);
        assert_eq!(s.prims_used(), 2);
    }

    #[test]
    fn passes_fully_parallel_primitive() {
        // Digital-6T has Rh=Ch=1: a full grid is one pass.
        let sys = d1_rf();
        let s = CimSpatial {
            k_prims: 1,
            n_prims: 1,
            ku: 256,
            nu: 16,
            m_prims: 1,
        };
        assert_eq!(s.passes_per_row(&sys), 1);
    }

    #[test]
    fn passes_with_holds() {
        // Analog-6T: Rp=64, Cp=4, Ch=16 -> full 64x64 grid takes 16
        // column-hold passes.
        let sys = CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::analog_6t(),
            MemLevel::RegisterFile,
        );
        let s = CimSpatial {
            k_prims: 1,
            n_prims: 1,
            ku: 64,
            nu: 64,
            m_prims: 1,
        };
        assert_eq!(s.passes_per_row(&sys), 16);
        // Half the columns -> half the passes.
        let s = CimSpatial { nu: 32, ..s };
        assert_eq!(s.passes_per_row(&sys), 8);
    }

    #[test]
    fn utilization_full_and_partial() {
        let sys = d1_rf(); // 3 primitives of 256x16
        let full = CimSpatial {
            k_prims: 3,
            n_prims: 1,
            ku: 256,
            nu: 16,
            m_prims: 1,
        };
        assert!((full.utilization(&sys) - 1.0).abs() < 1e-12);
        let third = CimSpatial {
            k_prims: 1,
            n_prims: 1,
            ku: 256,
            nu: 16,
            m_prims: 1,
        };
        assert!((third.utilization(&sys) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn validation() {
        let sys = d1_rf();
        let ok = CimSpatial {
            k_prims: 1,
            n_prims: 3,
            ku: 256,
            nu: 16,
            m_prims: 1,
        };
        assert!(ok.validate(&sys).is_ok());
        let too_many = CimSpatial { n_prims: 4, ..ok };
        assert!(too_many.validate(&sys).is_err());
        let too_tall = CimSpatial { ku: 257, ..ok };
        assert!(too_tall.validate(&sys).is_err());
    }
}
