//! GEMM-to-CiM mapping (paper §IV-B).
//!
//! A [`Mapping`] = a spatial assignment of the weight matrix onto the
//! CiM primitives ([`CimSpatial`]) + a temporal [`LoopNest`] describing
//! the tiled dataflow across DRAM / staging memory / the CiM level.
//!
//! Three mappers are provided:
//! * [`PriorityMapper`] — the paper's contribution: weight-stationary,
//!   utilization-first, then reuse (Algo 1), greedy loop order.
//! * [`HeuristicMapper`] — the comparator: random search that stops
//!   after 100 000 consecutive invalid samples (Fig 7, Table II).
//! * [`ExhaustiveMapper`] — the yardstick: the true optimum over the
//!   discretized map-space.
//!
//! Mappings have a canonical, bit-exact serialized form ([`canonical`])
//! so the sweep cache can persist `(Mapping, Metrics)` pairs across
//! processes.

pub mod canonical;
pub mod exhaustive;
pub mod heuristic;
pub mod loopnest;
pub mod priority;
pub mod spatial;

pub use exhaustive::{ExhaustiveMapper, Objective};
pub use heuristic::HeuristicMapper;
pub use loopnest::{distinct_tiles, refetches, Block, Dim, Loop, LoopNest, Tensor};
pub use priority::PriorityMapper;
pub use spatial::CimSpatial;

use crate::workload::Gemm;

/// Version of the mapping algorithms. Bump this whenever any mapper's
/// produced [`Mapping`] can change for the same (system, GEMM) —
/// tiling rules, loop ordering, spatial assignment, search behavior.
/// It is embedded in every mapper fingerprint
/// ([`crate::sweep::MapperChoice::fingerprint`]), which in turn forms
/// the design-point cache keys persisted by `--cache`
/// ([`crate::sweep::persist`]) — so metrics computed by an older
/// mapper implementation can never be served for a newer one.
pub const MAPPER_VERSION: u32 = 1;

/// A complete schedule of one GEMM onto a CiM-integrated system.
#[derive(Debug, Clone, PartialEq)]
pub struct Mapping {
    pub gemm: Gemm,
    pub spatial: CimSpatial,
    /// Compute-hardware occupancy of the spatial placement
    /// ([`CimSpatial::utilization`]), recorded at map time so post-hoc
    /// consumers of persisted mappings ([`crate::sweep::persist`]) can
    /// read it without re-instantiating the system. Always finite.
    pub occupancy: f64,
    pub nest: LoopNest,
}

impl Mapping {
    /// Rebuild this mapping with a fixed DRAM-level loop order (the
    /// `ablation-order` axis): block 0 is replaced by `order`, each
    /// dimension carrying its existing block-0 factor. Inner blocks and
    /// the spatial placement are untouched.
    pub fn with_dram_order(&self, order: [Dim; 3]) -> Mapping {
        let b0 = &self.nest.blocks[0];
        let loops: Vec<Loop> = order
            .iter()
            .map(|&d| Loop::new(d, b0.dim_factor(d)))
            .collect();
        let mut blocks = self.nest.blocks.clone();
        blocks[0] = Block::new(blocks[0].mem, loops);
        Mapping {
            gemm: self.gemm,
            spatial: self.spatial,
            occupancy: self.occupancy,
            nest: LoopNest::new(self.gemm, blocks),
        }
    }

    /// Mapped weight-tile extent along K (rows across primitives).
    pub fn k0(&self) -> u64 {
        self.spatial.k0(self.gemm.k)
    }

    /// Mapped weight-tile extent along N (columns across primitives).
    pub fn n0(&self) -> u64 {
        self.spatial.n0(self.gemm.n)
    }

    /// Short human-readable description for logs (`repro evaluate
    /// --verbose`).
    pub fn describe(&self) -> String {
        format!(
            "{} -> prims {}x{} (K0={} N0={}, occ {:.1}%), nest {:?}",
            self.gemm,
            self.spatial.k_prims,
            self.spatial.n_prims,
            self.k0(),
            self.n0(),
            100.0 * self.occupancy,
            self.nest
                .blocks
                .iter()
                .map(|b| {
                    b.loops
                        .iter()
                        .map(|l| format!("{}{}", l.dim.name(), l.factor))
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .collect::<Vec<_>>()
        )
    }
}
