//! GEMM-to-CiM mapping (paper §IV-B).
//!
//! A [`Mapping`] = a spatial assignment of the weight matrix onto the
//! CiM primitives ([`CimSpatial`]) + a temporal [`LoopNest`] describing
//! the tiled dataflow across DRAM / staging memory / the CiM level.
//!
//! Two mappers are provided:
//! * [`PriorityMapper`] — the paper's contribution: weight-stationary,
//!   utilization-first, then reuse (Algo 1), greedy loop order.
//! * [`HeuristicMapper`] — the comparator: random search that stops
//!   after 100 000 consecutive invalid samples (Fig 7, Table II).

pub mod exhaustive;
pub mod heuristic;
pub mod loopnest;
pub mod priority;
pub mod spatial;

pub use exhaustive::{ExhaustiveMapper, Objective};
pub use heuristic::HeuristicMapper;
pub use loopnest::{distinct_tiles, refetches, Block, Dim, Loop, LoopNest, Tensor};
pub use priority::PriorityMapper;
pub use spatial::CimSpatial;

use crate::workload::Gemm;

/// Version of the mapping algorithms. Bump this whenever any mapper's
/// produced [`Mapping`] can change for the same (system, GEMM) —
/// tiling rules, loop ordering, spatial assignment, search behavior.
/// It is embedded in every mapper fingerprint
/// ([`crate::sweep::MapperChoice::fingerprint`]), which in turn forms
/// the design-point cache keys persisted by `--cache`
/// ([`crate::sweep::persist`]) — so metrics computed by an older
/// mapper implementation can never be served for a newer one.
pub const MAPPER_VERSION: u32 = 1;

/// A complete schedule of one GEMM onto a CiM-integrated system.
#[derive(Debug, Clone, PartialEq)]
pub struct Mapping {
    pub gemm: Gemm,
    pub spatial: CimSpatial,
    pub nest: LoopNest,
}

impl Mapping {
    /// Mapped weight-tile extent along K (rows across primitives).
    pub fn k0(&self) -> u64 {
        self.spatial.k0(self.gemm.k)
    }

    /// Mapped weight-tile extent along N (columns across primitives).
    pub fn n0(&self) -> u64 {
        self.spatial.n0(self.gemm.n)
    }

    /// Short human-readable description for logs.
    pub fn describe(&self) -> String {
        format!(
            "{} -> prims {}x{} (K0={} N0={}), nest {:?}",
            self.gemm,
            self.spatial.k_prims,
            self.spatial.n_prims,
            self.k0(),
            self.n0(),
            self.nest
                .blocks
                .iter()
                .map(|b| {
                    b.loops
                        .iter()
                        .map(|l| format!("{}{}", l.dim.name(), l.factor))
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .collect::<Vec<_>>()
        )
    }
}
