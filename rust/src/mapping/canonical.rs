//! Canonical, bit-exact serialized form of a [`Mapping`].
//!
//! Cache entries now persist the mapping alongside the metrics
//! ([`crate::sweep::persist`]), so a `Mapping` needs a stable textual
//! form with an exact round trip: `serialize → persist → load →
//! re-serialize` must be byte-identical. Integers serialize in decimal;
//! the one float field (`occupancy`) serializes as its IEEE-754 bit
//! pattern in hex — the same discipline as the cache-key fingerprints
//! ([`crate::sweep::cache::f64_bits_hex`]).
//!
//! The format is a single line with no whitespace or tabs (it embeds in
//! the tab-separated cache file and in JSON strings):
//!
//! ```text
//! g=512x32x256;s=1,2,256,16,1;occ=3fe5555555555555;n=DRAM[M4,K2]/SMEM[N2]/RF[N16,K64,M8]
//! ```
//!
//! * `g`   — the GEMM as `MxNxK`;
//! * `s`   — the spatial split `k_prims,n_prims,ku,nu,m_prims`;
//! * `occ` — the occupancy bit pattern (16 hex digits);
//! * `n`   — the loop nest, blocks outermost first, `/`-separated:
//!   `LEVEL[loops]` with each loop `<dim><factor>` (factor-1 loops were
//!   already dropped at construction).
//!
//! [`Mapping::fingerprint`] hashes the canonical form with the stable
//! FNV-1a ([`crate::util::hash`]) and folds in
//! [`super::MAPPER_VERSION`], so a mapper-algorithm change retires the
//! fingerprints of every previously produced mapping.

use anyhow::{bail, Context, Result};

use crate::arch::MemLevel;
use crate::util::hash::fnv1a;
use crate::workload::Gemm;

use super::loopnest::{Block, Dim, Loop, LoopNest};
use super::spatial::CimSpatial;
use super::{Mapping, MAPPER_VERSION};

impl Mapping {
    /// The canonical serialized form (see the module docs). Contains no
    /// whitespace, tabs or quotes; equal mappings produce equal strings
    /// and distinct mappings distinct strings (the fields written are
    /// exactly the fields of the struct).
    pub fn canonical(&self) -> String {
        let mut out = String::with_capacity(96);
        out.push_str(&format!("g={}x{}x{}", self.gemm.m, self.gemm.n, self.gemm.k));
        let s = &self.spatial;
        out.push_str(&format!(
            ";s={},{},{},{},{}",
            s.k_prims, s.n_prims, s.ku, s.nu, s.m_prims
        ));
        out.push_str(&format!(";occ={:016x}", self.occupancy.to_bits()));
        out.push_str(";n=");
        for (i, b) in self.nest.blocks.iter().enumerate() {
            if i > 0 {
                out.push('/');
            }
            out.push_str(b.mem.short_name());
            out.push('[');
            for (j, l) in b.loops.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(l.dim.name());
                out.push_str(&l.factor.to_string());
            }
            out.push(']');
        }
        out
    }

    /// Parse a canonical form back into a `Mapping`. The inverse of
    /// [`Mapping::canonical`]: `from_canonical(m.canonical()) == m`
    /// bit-for-bit. Corrupt input fails with an error (never panics or
    /// half-parses) so a damaged cache file is discarded, not trusted.
    pub fn from_canonical(text: &str) -> Result<Mapping> {
        let mut gemm: Option<Gemm> = None;
        let mut spatial: Option<CimSpatial> = None;
        let mut occupancy: Option<f64> = None;
        let mut blocks: Option<Vec<Block>> = None;
        for seg in text.split(';') {
            let (key, val) = seg
                .split_once('=')
                .with_context(|| format!("mapping segment {seg:?} lacks '='"))?;
            match key {
                "g" => gemm = Some(parse_gemm(val)?),
                "s" => spatial = Some(parse_spatial(val)?),
                "occ" => occupancy = Some(parse_occupancy(val)?),
                "n" => blocks = Some(parse_nest(val)?),
                other => bail!("unknown mapping segment {other:?}"),
            }
        }
        let gemm = gemm.context("mapping lacks the 'g' segment")?;
        let nest = LoopNest {
            gemm,
            blocks: blocks.context("mapping lacks the 'n' segment")?,
        };
        if let Err(e) = nest.validate() {
            bail!("persisted mapping does not tile its GEMM: {e}");
        }
        Ok(Mapping {
            gemm,
            spatial: spatial.context("mapping lacks the 's' segment")?,
            occupancy: occupancy.context("mapping lacks the 'occ' segment")?,
            nest,
        })
    }

    /// Stable fingerprint of this mapping: FNV-1a over the canonical
    /// form, prefixed with [`MAPPER_VERSION`] — any change to any field
    /// changes the digest, and a mapper-algorithm version bump retires
    /// every older fingerprint.
    pub fn fingerprint(&self) -> String {
        let desc = format!("v{}:{}", MAPPER_VERSION, self.canonical());
        format!("{:016x}", fnv1a(desc.as_bytes()))
    }
}

fn parse_u64_pos(s: &str, what: &str) -> Result<u64> {
    match s.parse::<u64>() {
        Ok(v) if v >= 1 => Ok(v),
        // Zero falls through the guard and is as corrupt as a parse
        // failure; spelled exhaustively (lint R5).
        Ok(_) | Err(_) => bail!("{what}: want a positive integer, got {s:?}"),
    }
}

fn parse_gemm(val: &str) -> Result<Gemm> {
    let dims: Vec<&str> = val.split('x').collect();
    if dims.len() != 3 {
        bail!("mapping GEMM {val:?}: want MxNxK");
    }
    Ok(Gemm::new(
        parse_u64_pos(dims[0], "gemm M")?,
        parse_u64_pos(dims[1], "gemm N")?,
        parse_u64_pos(dims[2], "gemm K")?,
    ))
}

fn parse_spatial(val: &str) -> Result<CimSpatial> {
    let f: Vec<&str> = val.split(',').collect();
    if f.len() != 5 {
        bail!("mapping spatial {val:?}: want k_prims,n_prims,ku,nu,m_prims");
    }
    Ok(CimSpatial {
        k_prims: parse_u64_pos(f[0], "k_prims")?,
        n_prims: parse_u64_pos(f[1], "n_prims")?,
        ku: parse_u64_pos(f[2], "ku")?,
        nu: parse_u64_pos(f[3], "nu")?,
        m_prims: parse_u64_pos(f[4], "m_prims")?,
    })
}

fn parse_occupancy(val: &str) -> Result<f64> {
    let bits = u64::from_str_radix(val, 16)
        .with_context(|| format!("mapping occupancy {val:?}: bad bit pattern"))?;
    let x = f64::from_bits(bits);
    if !x.is_finite() {
        bail!("mapping occupancy {val:?} is not finite");
    }
    Ok(x)
}

fn parse_nest(val: &str) -> Result<Vec<Block>> {
    let mut blocks = Vec::new();
    for part in val.split('/') {
        let (level, rest) = part
            .split_once('[')
            .with_context(|| format!("mapping block {part:?} lacks '['"))?;
        let loops_str = rest
            .strip_suffix(']')
            .with_context(|| format!("mapping block {part:?} lacks ']'"))?;
        let mem = MemLevel::parse(level)
            .with_context(|| format!("mapping block level {level:?} unknown"))?;
        let mut loops = Vec::new();
        if !loops_str.is_empty() {
            for l in loops_str.split(',') {
                // The dim tag is a single ASCII letter, so `l[1..]` is
                // a char boundary; anything else (including an empty or
                // multi-byte-leading corrupt token) errors here first.
                let dim = match l.chars().next() {
                    Some('M') => Dim::M,
                    Some('N') => Dim::N,
                    Some('K') => Dim::K,
                    Some(_) | None => bail!("mapping loop {l:?}: want <M|N|K><factor>"),
                };
                loops.push(Loop::new(dim, parse_u64_pos(&l[1..], "loop factor")?));
            }
        }
        blocks.push(Block::new(mem, loops));
    }
    if blocks.is_empty() {
        bail!("mapping nest has no blocks");
    }
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, CimSystem, SmemConfig};
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn sample(g: Gemm) -> Mapping {
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_level(&arch, CimPrimitive::digital_6t(), MemLevel::RegisterFile);
        PriorityMapper::new(&sys).map(&g)
    }

    #[test]
    fn round_trip_is_bit_exact() {
        for g in [
            Gemm::new(512, 1024, 1024),
            Gemm::new(1, 4096, 4096),
            Gemm::new(12544, 64, 147),
            Gemm::new(3, 5, 7),
        ] {
            let m = sample(g);
            let text = m.canonical();
            let back = Mapping::from_canonical(&text).unwrap();
            assert_eq!(back, m, "{g}");
            assert_eq!(back.canonical(), text, "{g}: re-serialization drifted");
            assert_eq!(back.occupancy.to_bits(), m.occupancy.to_bits(), "{g}");
        }
    }

    #[test]
    fn canonical_has_no_forbidden_characters() {
        // The form embeds in tab-separated cache lines and JSON strings.
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_smem(&arch, CimPrimitive::analog_6t(), SmemConfig::ConfigB);
        let m = PriorityMapper::new(&sys).map(&Gemm::new(4096, 512, 512));
        let text = m.canonical();
        assert!(!text.contains('\t') && !text.contains('\n'));
        assert!(!text.contains('"') && !text.contains('\\'));
        assert!(!text.contains(' '));
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let m = sample(Gemm::new(512, 1024, 1024));
        let base = m.fingerprint();
        assert_eq!(base, sample(Gemm::new(512, 1024, 1024)).fingerprint());

        let mut g = m.clone();
        g.gemm = Gemm::new(513, 1024, 1024);
        assert_ne!(base, g.fingerprint(), "gemm dim");
        let mut s = m.clone();
        s.spatial.m_prims += 1;
        assert_ne!(base, s.fingerprint(), "spatial split");
        let mut o = m.clone();
        o.occupancy = f64::from_bits(o.occupancy.to_bits() + 1);
        assert_ne!(base, o.fingerprint(), "occupancy ulp");
        let mut n = m.clone();
        n.nest.blocks[0].loops.push(Loop::new(Dim::M, 2));
        assert_ne!(base, n.fingerprint(), "extra loop");
    }

    #[test]
    fn corrupt_forms_error_cleanly() {
        let m = sample(Gemm::new(64, 64, 64));
        let good = m.canonical();
        for bad in [
            "",
            "g=64x64",
            "g=64x64x64",                                     // missing segments
            "g=0x64x64;s=1,1,64,16,1;occ=0;n=DRAM[]",         // zero dim
            "g=64x64x64;s=1,1;occ=0;n=DRAM[]",                // short spatial
            "g=64x64x64;s=1,1,64,16,1;occ=zz;n=DRAM[]",       // bad hex
            "g=64x64x64;s=1,1,64,16,1;occ=7ff8000000000000;n=DRAM[M64,K64,N64]", // NaN occ
            "g=64x64x64;s=1,1,64,16,1;occ=0;n=L9[M64]",       // unknown level
            "g=64x64x64;s=1,1,64,16,1;occ=0;n=DRAM[Q64]",     // unknown dim
            "g=64x64x64;s=1,1,64,16,1;occ=0;n=DRAM[M2]",      // under-tiled
            &good[..good.len() - 1],                          // truncated tail
        ] {
            assert!(
                Mapping::from_canonical(bad).is_err(),
                "{bad:?} must not parse"
            );
        }
        assert!(Mapping::from_canonical(&good).is_ok());
    }

    #[test]
    fn empty_loop_blocks_round_trip() {
        // CiM@SMEM mappings commonly have an empty DRAM block.
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        let m = PriorityMapper::new(&sys).map(&Gemm::new(4096, 512, 512));
        assert!(m.nest.blocks[0].loops.is_empty(), "{}", m.canonical());
        let back = Mapping::from_canonical(&m.canonical()).unwrap();
        assert_eq!(back, m);
    }
}
