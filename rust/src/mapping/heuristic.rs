//! Heuristic mapping search — the comparator of Fig 7 / Table II.
//!
//! Mirrors the Timeloop-style random mapper the paper compares against
//! (§IV-B "Comparison with Heuristic Mapping"): sample mapping
//! candidates uniformly from a space that includes invalid points,
//! evaluate the valid ones, and stop after a budget of valid samples
//! **or after 100 000 consecutive invalid samples** — the stopping rule
//! quoted in Fig 7's caption. Unlike the priority mapper it is
//! "agnostic of the inherent reuse opportunities present in a CiM
//! primitive", which is precisely why it loses.

use super::loopnest::{Block, Dim, Loop, LoopNest};
use super::spatial::CimSpatial;
use super::Mapping;
use crate::arch::{CimSystem, MemLevel};
use crate::cost::CostModel;
use crate::util::rng::Rng;
use crate::workload::Gemm;

/// Search statistics (Table II's runtime story).
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    pub sampled: u64,
    pub valid: u64,
    pub invalid: u64,
    pub max_consecutive_invalid: u64,
}

/// Random mapping search over the CiM map-space.
#[derive(Debug, Clone)]
pub struct HeuristicMapper<'a> {
    sys: &'a CimSystem,
    /// Stop after this many *valid* candidates have been scored.
    pub valid_budget: u64,
    /// The paper's stopping rule: quit after this many consecutive
    /// invalid samples.
    pub invalid_limit: u64,
}

impl<'a> HeuristicMapper<'a> {
    pub fn new(sys: &'a CimSystem) -> Self {
        HeuristicMapper {
            sys,
            valid_budget: 500,
            invalid_limit: 100_000,
        }
    }

    /// Search for the best mapping (minimum energy-delay product).
    /// Always returns some mapping: if the random search finds nothing
    /// valid (possible for degenerate shapes), it falls back to the
    /// trivial one-primitive mapping so callers need no special case.
    pub fn map(&self, gemm: &Gemm, rng: &mut Rng) -> (Mapping, SearchStats) {
        let mut stats = SearchStats::default();
        let mut best: Option<(f64, Mapping)> = None;
        let cost = CostModel::new(self.sys);
        let mut consecutive = 0u64;

        while stats.valid < self.valid_budget && consecutive < self.invalid_limit {
            stats.sampled += 1;
            match self.sample(gemm, rng) {
                Some(mapping) => {
                    stats.valid += 1;
                    consecutive = 0;
                    let m = cost.evaluate(gemm, &mapping);
                    let edp = m.energy_pj * m.total_cycles as f64;
                    if best.as_ref().map_or(true, |(b, _)| edp < *b) {
                        best = Some((edp, mapping));
                    }
                }
                None => {
                    stats.invalid += 1;
                    consecutive += 1;
                    stats.max_consecutive_invalid =
                        stats.max_consecutive_invalid.max(consecutive);
                }
            }
        }

        let mapping = best.map(|(_, m)| m).unwrap_or_else(|| self.fallback(gemm));
        (mapping, stats)
    }

    /// Draw one candidate; `None` if it violates a constraint.
    fn sample(&self, gemm: &Gemm, rng: &mut Rng) -> Option<Mapping> {
        let sys = self.sys;
        let p = &sys.primitive;

        // Sample from ranges twice the feasible caps so that invalid
        // candidates occur, as in an unguided map-space search.
        let ku = rng.gen_range(1, 2 * p.weight_rows().min(gemm.k) + 1);
        let nu = rng.gen_range(1, 2 * p.weight_cols().min(gemm.n) + 1);
        let k_prims = rng.gen_range(1, sys.count + 1);
        let n_prims = rng.gen_range(1, sys.count + 1);
        let spatial = CimSpatial {
            k_prims,
            n_prims,
            ku,
            nu,
            m_prims: 1,
        };
        spatial.validate(sys).ok()?;
        // Reject placements that overshoot the GEMM (wasted primitives
        // are an invalid candidate, matching "invalid mapping" counts).
        if spatial.k0(u64::MAX) > gemm.k.next_multiple_of(ku)
            || spatial.n0(u64::MAX) > gemm.n.next_multiple_of(nu)
        {
            return None;
        }

        let k0 = spatial.k0(gemm.k);
        let n0 = spatial.n0(gemm.n);
        let k_tiles = gemm.k.div_ceil(k0);
        let n_tiles = gemm.n.div_ceil(n0);

        let staging = sys.staging_level();
        let capacity = match staging {
            MemLevel::Dram => u64::MAX,
            lvl => sys.arch.capacity(lvl),
        };

        let m1 = rng.gen_range(1, gemm.m + 1);
        let k1 = rng.gen_range(1, k_tiles + 1);
        let n1 = rng.gen_range(1, n_tiles + 1);
        if capacity != u64::MAX && m1.saturating_mul(k1 * k0 + n1 * n0) > capacity {
            return None; // staging overflow
        }

        let m2 = gemm.m.div_ceil(m1);
        let k2 = k_tiles.div_ceil(k1);
        let n2 = n_tiles.div_ceil(n1);

        let mut outer = vec![
            Loop::new(Dim::M, m2),
            Loop::new(Dim::K, k2),
            Loop::new(Dim::N, n2),
        ];
        rng.shuffle(&mut outer);
        let mut staged = vec![Loop::new(Dim::K, k1), Loop::new(Dim::N, n1)];
        rng.shuffle(&mut staged);

        let nest = LoopNest::new(
            *gemm,
            vec![
                Block::new(MemLevel::Dram, outer),
                Block::new(staging, staged),
                Block::new(
                    sys.level,
                    vec![
                        Loop::new(Dim::N, n0),
                        Loop::new(Dim::K, k0),
                        Loop::new(Dim::M, m1),
                    ],
                ),
            ],
        );
        Some(Mapping {
            gemm: *gemm,
            spatial,
            occupancy: spatial.utilization(sys),
            nest,
        })
    }

    /// Minimal always-valid mapping: one primitive, one row of M.
    fn fallback(&self, gemm: &Gemm) -> Mapping {
        let p = &self.sys.primitive;
        let spatial = CimSpatial {
            k_prims: 1,
            n_prims: 1,
            ku: gemm.k.min(p.weight_rows()),
            nu: gemm.n.min(p.weight_cols()),
            m_prims: 1,
        };
        let k0 = spatial.k0(gemm.k);
        let n0 = spatial.n0(gemm.n);
        let nest = LoopNest::new(
            *gemm,
            vec![
                Block::new(
                    MemLevel::Dram,
                    vec![
                        Loop::new(Dim::M, gemm.m),
                        Loop::new(Dim::K, gemm.k.div_ceil(k0)),
                        Loop::new(Dim::N, gemm.n.div_ceil(n0)),
                    ],
                ),
                Block::new(self.sys.staging_level(), vec![]),
                Block::new(
                    self.sys.level,
                    vec![Loop::new(Dim::N, n0), Loop::new(Dim::K, k0)],
                ),
            ],
        );
        Mapping {
            gemm: *gemm,
            spatial,
            occupancy: spatial.utilization(self.sys),
            nest,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn sys() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn search_returns_valid_mapping() {
        let sys = sys();
        let h = HeuristicMapper::new(&sys);
        let mut rng = Rng::new(1);
        for g in [Gemm::new(512, 1024, 1024), Gemm::new(1, 64, 256)] {
            let (m, stats) = h.map(&g, &mut rng);
            assert!(m.nest.validate().is_ok());
            assert!(m.spatial.validate(&sys).is_ok());
            assert!(stats.valid > 0);
            assert!(stats.invalid > 0, "search space should contain invalid points");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let sys = sys();
        let h = HeuristicMapper::new(&sys);
        let g = Gemm::new(256, 512, 512);
        let (m1, _) = h.map(&g, &mut Rng::new(99));
        let (m2, _) = h.map(&g, &mut Rng::new(99));
        assert_eq!(m1, m2);
    }

    #[test]
    fn priority_mapper_not_worse_on_edp() {
        // Fig 7: the priority mapper consistently beats the heuristic.
        // Here: never worse by more than 10% EDP on a sample of shapes
        // with a modest search budget.
        let sys = sys();
        let mut h = HeuristicMapper::new(&sys);
        h.valid_budget = 200;
        let cost = CostModel::new(&sys);
        let mut rng = Rng::new(7);
        for g in [
            Gemm::new(512, 1024, 1024),
            Gemm::new(3136, 64, 576),
            Gemm::new(1, 4096, 4096),
        ] {
            let ours = PriorityMapper::new(&sys).map(&g);
            let (theirs, _) = h.map(&g, &mut rng);
            let edp = |m: &Mapping| {
                let x = cost.evaluate(&g, m);
                x.energy_pj * x.total_cycles as f64
            };
            assert!(
                edp(&ours) <= edp(&theirs) * 1.10,
                "{g}: ours {} vs heuristic {}",
                edp(&ours),
                edp(&theirs)
            );
        }
    }

    #[test]
    fn fallback_is_valid() {
        let sys = sys();
        let h = HeuristicMapper::new(&sys);
        let g = Gemm::new(3, 5, 7);
        let fb = h.fallback(&g);
        assert!(fb.nest.validate().is_ok());
        assert!(fb.spatial.validate(&sys).is_ok());
    }
}
