//! The paper's priority-based mapping algorithm (§IV-B, Algo 1).
//!
//! Priorities, in order:
//! 1. **Weight-stationary**: K maps to primitive rows, N to columns;
//!    hold factors fill only after the parallel positions.
//! 2. **Parallelism first**: weights spread across primitives before
//!    using a primitive's sequential (`Rh×Ch`) positions, with the
//!    balance threshold (K:N primitive-expansion ratio ≤ 4) of Fig 6.
//! 3. **Input reuse**: the largest `M1` input tile that fits the
//!    staging memory (SMEM when CiM sits in the RF), then Algo-1-style
//!    incremental growth of the K and N factors at that level.
//! 4. **Greedy loop order**: per level, the dimension with the
//!    *smallest* loop factor goes outermost, minimizing the product of
//!    access multipliers (the Fig 4 rule).

use super::loopnest::{Block, Dim, Loop, LoopNest};
use super::spatial::CimSpatial;
use super::Mapping;
use crate::arch::{CimSystem, MemLevel};
use crate::workload::Gemm;

/// Balance threshold for expanding across primitives (§IV-B: "the
/// ratio of larger dimension to smaller dimension should be less than
/// a threshold (= 4 for our experiments)").
pub const BALANCE_THRESHOLD: u64 = 4;

/// The priority-based mapper for a given CiM system.
#[derive(Debug, Clone)]
pub struct PriorityMapper<'a> {
    sys: &'a CimSystem,
    threshold: u64,
    weight_duplication: bool,
}

impl<'a> PriorityMapper<'a> {
    pub fn new(sys: &'a CimSystem) -> Self {
        PriorityMapper {
            sys,
            threshold: BALANCE_THRESHOLD,
            weight_duplication: false,
        }
    }

    /// Enable the weight-duplication extension (map M across idle
    /// primitives by replicating the stationary weight tile).
    pub fn with_weight_duplication(mut self) -> Self {
        self.weight_duplication = true;
        self
    }

    /// Override the balance threshold (ablation experiments).
    pub fn with_threshold(sys: &'a CimSystem, threshold: u64) -> Self {
        assert!(threshold >= 1);
        PriorityMapper {
            sys,
            threshold,
            weight_duplication: false,
        }
    }

    /// Map a GEMM. Always returns a valid mapping (§IV-B: "our
    /// algorithm always provides a valid mapping").
    pub fn map(&self, gemm: &Gemm) -> Mapping {
        let spatial = self.spatial(gemm);
        let nest = self.temporal(gemm, &spatial);
        Mapping {
            gemm: *gemm,
            spatial,
            occupancy: spatial.utilization(self.sys),
            nest,
        }
    }

    /// Priority 1+2: weight placement across primitives.
    fn spatial(&self, gemm: &Gemm) -> CimSpatial {
        let p = &self.sys.primitive;
        // Fill one primitive's grid first (K rows, N columns).
        let ku = gemm.k.min(p.weight_rows());
        let nu = gemm.n.min(p.weight_cols());
        // Tiles still needed to cover the weight matrix.
        let k_tiles = gemm.k.div_ceil(ku);
        let n_tiles = gemm.n.div_ceil(nu);

        // Parallelism first: expand across primitives greedily toward
        // the direction with the larger remaining deficit, keeping the
        // expansion balanced (ratio of primitive counts ≤ threshold).
        let (mut kp, mut np) = (1u64, 1u64);
        loop {
            let can_k = kp < k_tiles && (kp + 1) * np <= self.sys.count;
            let can_n = np < n_tiles && kp * (np + 1) <= self.sys.count;
            if !can_k && !can_n {
                break;
            }
            let deficit_k = k_tiles.div_ceil(kp);
            let deficit_n = n_tiles.div_ceil(np);
            // ratio after the candidate expansion
            let ratio = |a: u64, b: u64| a.max(b) / a.min(b).max(1);
            let k_ok = can_k && ratio(kp + 1, np) <= self.threshold;
            let n_ok = can_n && ratio(kp, np + 1) <= self.threshold;
            match (k_ok, n_ok) {
                (true, true) => {
                    if deficit_k >= deficit_n {
                        kp += 1;
                    } else {
                        np += 1;
                    }
                }
                (true, false) => kp += 1,
                (false, true) => np += 1,
                (false, false) => break, // any expansion would skew past the threshold
            }
        }
        // Weight duplication (§IV-B future work, implemented as an
        // opt-in extension): when the weight matrix is fully spread and
        // primitives remain idle, replicate the stationary tile across
        // groups that each process a disjoint slice of M in parallel.
        let mut m_prims = 1u64;
        if self.weight_duplication {
            let used = kp * np;
            let idle_groups = self.sys.count / used;
            m_prims = idle_groups.min(gemm.m).max(1);
        }
        CimSpatial {
            k_prims: kp,
            n_prims: np,
            ku,
            nu,
            m_prims,
        }
    }

    /// Priority 3+4: staging-level factors (Algo 1) and greedy orders.
    fn temporal(&self, gemm: &Gemm, spatial: &CimSpatial) -> LoopNest {
        let k0 = spatial.k0(gemm.k);
        let n0 = spatial.n0(gemm.n);
        let k_tiles = gemm.k.div_ceil(k0); // weight residencies along K
        let n_tiles = gemm.n.div_ceil(n0);

        // Staging capacity in INT-8 elements. CiM at SMEM has no
        // intermediate on-chip staging level: tiles come from DRAM
        // ("absence of an intermediate on-chip memory level", §VI-C).
        let staging = self.sys.staging_level();
        let capacity = match staging {
            MemLevel::Dram => u64::MAX,
            lvl => self.sys.arch.capacity(lvl),
        };

        // Largest M1 input tile that fits: A(M1×K0) + Z(M1×N0) —
        // then balanced across the M iterations so a near-miss does
        // not leave a nearly-empty trailing tile (e.g. M=1024 with
        // M1max=862 becomes 2×512 rather than 862+162).
        let m1 = if capacity == u64::MAX {
            gemm.m
        } else {
            let m1_max = (capacity / (k0 + n0)).clamp(1, gemm.m);
            gemm.m.div_ceil(gemm.m.div_ceil(m1_max))
        };

        // Algo 1: incrementally grow the K then N factors held at the
        // staging level while A + Z fit. Growth is by the smallest
        // prime factor of the remaining tile count so the final factor
        // divides it exactly.
        let fits = |k1: u64, n1: u64| m1 * (k1 * k0 + n1 * n0) <= capacity;
        let mut k1 = 1u64;
        // Input-reuse priority: grow the A tile (K) before the Z tile (N).
        while k1 < k_tiles {
            let f = min_factor(k_tiles / k1);
            match f {
                Some(f) if fits(k1 * f, 1) => k1 *= f,
                _ => break,
            }
        }
        let mut n1 = 1u64;
        while n1 < n_tiles {
            let f = min_factor(n_tiles / n1);
            match f {
                Some(f) if fits(k1, n1 * f) => n1 *= f,
                _ => break,
            }
        }

        // DRAM-level remainders.
        let m2 = gemm.m.div_ceil(m1);
        let k2 = k_tiles / k1;
        let n2 = n_tiles / n1;

        // Staging block order is fixed N-outer / K-inner: "by changing
        // K faster than N, we prioritize reducing the output partial
        // sums in the CiM primitive before moving to a different
        // partial sum" (§IV-B) — K1-inner lets the output buffer
        // accumulate across weight reloads, at the price of re-reading
        // the staged input tile per N1 iteration.
        let block1 = Block::new(
            staging,
            vec![Loop::new(Dim::N, n1), Loop::new(Dim::K, k1)],
        );
        // Innermost (CiM residency) block: fixed compute order
        // M < K < N, M innermost (§IV-B "Deciding loop order").
        let block2 = Block::new(
            self.sys.level,
            vec![
                Loop::new(Dim::N, n0),
                Loop::new(Dim::K, k0),
                Loop::new(Dim::M, m1),
            ],
        );

        // DRAM-level loop order: greedy access minimization (§IV-B).
        // The outermost level has at most three loops, so the local
        // optimum is found exactly: evaluate every permutation with
        // the full cost model and keep the cheapest.
        let dram_loops = [
            Loop::new(Dim::M, m2),
            Loop::new(Dim::K, k2),
            Loop::new(Dim::N, n2),
        ];
        // Unit-factor loops are dropped by `Block::new`, so
        // permutations that only reorder them are identical; skip the
        // duplicates (the common m2=1 case needs 2 evaluations, fully
        // tiled cases need 1 — §Perf).
        let n_nontrivial = dram_loops.iter().filter(|l| l.factor > 1).count();
        let perms: &[[usize; 3]] = match n_nontrivial {
            0 | 1 => &[[0, 1, 2]],
            _ => &permutations3(),
        };
        let occupancy = spatial.utilization(self.sys);
        let mut best: Option<(f64, Mapping)> = None;
        let mut seen: Vec<Vec<Loop>> = Vec::with_capacity(perms.len());
        for perm in perms {
            let ordered: Vec<Loop> = perm
                .iter()
                .map(|&i| dram_loops[i])
                .filter(|l| l.factor > 1)
                .collect();
            if seen.contains(&ordered) {
                continue;
            }
            seen.push(ordered.clone());
            let block0 = Block {
                mem: MemLevel::Dram,
                loops: ordered,
            };
            let nest = LoopNest::new(*gemm, vec![block0, block1.clone(), block2.clone()]);
            let mapping = Mapping {
                gemm: *gemm,
                spatial: *spatial,
                occupancy,
                nest,
            };
            let e = crate::cost::CostModel::new(self.sys)
                .evaluate(gemm, &mapping)
                .energy_pj;
            if best.as_ref().map_or(true, |(b, _)| e < *b) {
                best = Some((e, mapping));
            }
        }
        // lint: allow(R4): the loop above iterates the fixed six-element permutation table, so best is always set
        best.expect("at least one permutation").1.nest
    }
}

/// The six permutations of three loop slots.
fn permutations3() -> [[usize; 3]; 6] {
    [
        [0, 1, 2],
        [0, 2, 1],
        [1, 0, 2],
        [1, 2, 0],
        [2, 0, 1],
        [2, 1, 0],
    ]
}

/// Greedy loop order (§IV-B): smallest factor outermost minimizes the
/// access multipliers at the level (the paper's own Fig 4 example —
/// the outermost factor multiplies every tensor's accesses). Ties are
/// broken M-before-K-before-N for determinism.
pub fn greedy_order(mut loops: Vec<Loop>) -> Vec<Loop> {
    let rank = |d: Dim| match d {
        Dim::M => 0u8,
        Dim::K => 1,
        Dim::N => 2,
    };
    loops.sort_by_key(|l| (l.factor, rank(l.dim)));
    loops
}

/// Smallest prime factor of `x` (`None` for x <= 1). Trial division is
/// ample: tile counts are small.
pub fn min_factor(x: u64) -> Option<u64> {
    if x <= 1 {
        return None;
    }
    if x % 2 == 0 {
        return Some(2);
    }
    let mut f = 3;
    while f * f <= x {
        if x % f == 0 {
            return Some(f);
        }
        f += 2;
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Architecture, SmemConfig};
    use crate::cim::CimPrimitive;

    fn d1_rf() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn min_factor_basics() {
        assert_eq!(min_factor(1), None);
        assert_eq!(min_factor(2), Some(2));
        assert_eq!(min_factor(15), Some(3));
        assert_eq!(min_factor(49), Some(7));
        assert_eq!(min_factor(97), Some(97)); // prime
    }

    #[test]
    fn greedy_puts_smallest_outermost() {
        let ordered = greedy_order(vec![
            Loop::new(Dim::M, 8),
            Loop::new(Dim::K, 2),
            Loop::new(Dim::N, 4),
        ]);
        let factors: Vec<u64> = ordered.iter().map(|l| l.factor).collect();
        assert_eq!(factors, vec![2, 4, 8]);
    }

    #[test]
    fn mapping_is_always_valid() {
        let sys = d1_rf();
        let mapper = PriorityMapper::new(&sys);
        for gemm in [
            Gemm::new(512, 1024, 1024),
            Gemm::new(1, 4096, 4096),
            Gemm::new(12544, 64, 147),
            Gemm::new(16, 16, 16),
            Gemm::new(8192, 8192, 8192),
            Gemm::new(1, 64, 256),
            Gemm::new(3, 5, 7),
        ] {
            let m = mapper.map(&gemm);
            assert!(m.nest.validate().is_ok(), "{gemm}: {:?}", m.nest.validate());
            assert!(m.spatial.validate(&sys).is_ok(), "{gemm}");
        }
    }

    #[test]
    fn small_weights_fill_one_primitive() {
        let sys = d1_rf();
        let m = PriorityMapper::new(&sys).map(&Gemm::new(64, 16, 128));
        assert_eq!(m.spatial.prims_used(), 1);
        assert_eq!(m.spatial.ku, 128);
        assert_eq!(m.spatial.nu, 16);
    }

    #[test]
    fn fig10_k256_n32_uses_two_primitives() {
        // Fig 10(a) narrative: K=256, N=32 engages "2 out of 3" D-1
        // primitives (one full K tile, two N tiles).
        let sys = d1_rf();
        let m = PriorityMapper::new(&sys).map(&Gemm::new(512, 32, 256));
        assert_eq!(m.spatial.k_prims, 1);
        assert_eq!(m.spatial.n_prims, 2);
        assert_eq!(m.k0(), 256);
        assert_eq!(m.n0(), 32);
    }

    #[test]
    fn large_weights_use_all_primitives() {
        let sys = d1_rf();
        let m = PriorityMapper::new(&sys).map(&Gemm::new(512, 1024, 1024));
        assert_eq!(m.spatial.prims_used(), 3);
    }

    #[test]
    fn smem_m_sweet_spot_fig10a() {
        // Fig 10(a): for a 512x512 weight matrix, TOPS/W drops as M
        // grows 256 -> 512. Mechanism: at M=256 the whole reduction
        // dimension K is staged in SMEM (no DRAM partial-sum traffic);
        // at M=512 the input tile crowds SMEM, K splits at the DRAM
        // level and partial sums spill.
        let sys = d1_rf();
        let mapper = PriorityMapper::new(&sys);
        let m256 = mapper.map(&Gemm::new(256, 512, 512));
        let m512 = mapper.map(&Gemm::new(512, 512, 512));
        let k_at_dram = |m: &Mapping| m.nest.blocks[0].dim_factor(Dim::K);
        assert_eq!(k_at_dram(&m256), 1, "{}", m256.describe());
        assert!(k_at_dram(&m512) > 1, "{}", m512.describe());
    }

    #[test]
    fn balance_threshold_limits_skew() {
        // With a huge primitive pool (SMEM configB), expansion must stay
        // balanced within the threshold.
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        let m = PriorityMapper::new(&sys).map(&Gemm::new(512, 8192, 8192));
        let (kp, np) = (m.spatial.k_prims, m.spatial.n_prims);
        assert!(kp.max(np) / kp.min(np) <= BALANCE_THRESHOLD, "kp={kp} np={np}");
        assert!(m.spatial.prims_used() <= sys.count);
        // and it should use most of the pool for a huge GEMM
        assert!(m.spatial.prims_used() >= sys.count / 2, "{}", m.spatial.prims_used());
    }

    #[test]
    fn gemv_maps_single_input_row() {
        let sys = d1_rf();
        let m = PriorityMapper::new(&sys).map(&Gemm::new(1, 4096, 4096));
        assert_eq!(m.nest.blocks[2].dim_factor(Dim::M), 1);
        assert!(m.nest.validate().is_ok());
    }

    #[test]
    fn cim_at_smem_stages_everything() {
        // No intermediate level: M1 covers all of M.
        let arch = Architecture::default_sm();
        let sys = CimSystem::at_smem(&arch, CimPrimitive::digital_6t(), SmemConfig::ConfigB);
        let m = PriorityMapper::new(&sys).map(&Gemm::new(4096, 512, 512));
        assert_eq!(m.nest.blocks[2].dim_factor(Dim::M), 4096);
        assert_eq!(m.nest.blocks[0].loops.len(), 0, "no DRAM-level remainder loops");
    }
}
