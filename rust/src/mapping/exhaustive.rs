//! Exhaustive mapper: enumerate the *entire* (discretized) map-space
//! and return the true optimum — the yardstick that quantifies how far
//! the priority mapper's greedy choices are from optimal.
//!
//! Neither the paper's algorithm nor its heuristic comparator can say
//! how close to optimal they land; this module can, for tractable
//! spaces. The space is discretized the same way both mappers build
//! nests: spatial splits over primitives × power-of-two-ish staging
//! factors × DRAM-level loop orders.

use super::loopnest::{Block, Dim, Loop, LoopNest};
use super::spatial::CimSpatial;
use super::Mapping;
use crate::arch::{CimSystem, MemLevel};
use crate::cost::CostModel;
use crate::workload::Gemm;

/// Objective to optimize over the map-space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize total energy (maximize TOPS/W).
    Energy,
    /// Minimize total cycles (maximize GFLOPS).
    Delay,
    /// Minimize energy × delay.
    Edp,
}

impl Objective {
    fn score(self, m: &crate::cost::Metrics) -> f64 {
        match self {
            Objective::Energy => m.energy_pj,
            Objective::Delay => m.total_cycles as f64,
            Objective::Edp => m.energy_pj * m.total_cycles as f64,
        }
    }

    /// Stable lower-case name, used in mapper fingerprints
    /// ([`crate::sweep::MapperChoice::fingerprint`]) and CLI parsing.
    pub fn name(self) -> &'static str {
        match self {
            Objective::Energy => "energy",
            Objective::Delay => "delay",
            Objective::Edp => "edp",
        }
    }

    /// Parse a lower-case objective name (inverse of [`Self::name`]).
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "energy" => Some(Objective::Energy),
            "delay" => Some(Objective::Delay),
            "edp" => Some(Objective::Edp),
            _ => None,
        }
    }
}

/// Exhaustive search result.
#[derive(Debug, Clone)]
pub struct ExhaustiveResult {
    pub mapping: Mapping,
    pub metrics: crate::cost::Metrics,
    /// Number of candidate mappings scored.
    pub candidates: u64,
}

/// Exhaustive mapper over the discretized space.
#[derive(Debug, Clone)]
pub struct ExhaustiveMapper<'a> {
    sys: &'a CimSystem,
    pub objective: Objective,
}

impl<'a> ExhaustiveMapper<'a> {
    pub fn new(sys: &'a CimSystem, objective: Objective) -> Self {
        ExhaustiveMapper { sys, objective }
    }

    /// Enumerate and score every candidate; returns the optimum.
    pub fn map(&self, gemm: &Gemm) -> ExhaustiveResult {
        let cost = CostModel::new(self.sys);
        let mut best: Option<(f64, Mapping, crate::cost::Metrics)> = None;
        let mut candidates = 0u64;
        self.for_each_candidate(gemm, |mapping| {
            let m = cost.evaluate(gemm, &mapping);
            let s = self.objective.score(&m);
            candidates += 1;
            if best.as_ref().map_or(true, |(b, _, _)| s < *b) {
                best = Some((s, mapping, m));
            }
        });
        // lint: allow(R4): for_each_candidate always yields the trivial all-ones tiling, so best is never None
        let (_, mapping, metrics) = best.expect("space contains at least the trivial mapping");
        ExhaustiveResult {
            mapping,
            metrics,
            candidates,
        }
    }

    /// Size of the discretized map-space for `gemm` — the number of
    /// candidates [`Self::map`] scores. Shares the spatial enumeration
    /// with `map` and counts the temporal combinations arithmetically
    /// (candidate validity is decided *before* a nest is built, so no
    /// `Mapping` is allocated per candidate) — cheap enough to
    /// recompute when the expensive search itself is served from a
    /// cache. The `count_matches_scored_candidates` test pins it
    /// against `map`'s actual tally.
    pub fn count_candidates(&self, gemm: &Gemm) -> u64 {
        let mut n = 0u64;
        self.for_each_spatial(gemm, |spatial| n += self.count_temporal(gemm, spatial));
        n
    }

    /// Walk every valid candidate mapping of the discretized space, in
    /// deterministic enumeration order.
    fn for_each_candidate<F: FnMut(Mapping)>(&self, gemm: &Gemm, mut f: F) {
        self.for_each_spatial(gemm, |spatial| {
            self.enumerate_temporal(gemm, spatial, &mut f);
        });
    }

    /// Walk every valid spatial split of the discretized space.
    fn for_each_spatial<F: FnMut(&CimSpatial)>(&self, gemm: &Gemm, mut f: F) {
        let sys = self.sys;
        let p = &sys.primitive;
        let ku_max = gemm.k.min(p.weight_rows());
        let nu_max = gemm.n.min(p.weight_cols());
        for ku in pow2_upto(ku_max) {
            for nu in pow2_upto(nu_max) {
                for k_prims in 1..=sys.count {
                    for n_prims in 1..=(sys.count / k_prims) {
                        let spatial = CimSpatial {
                            k_prims,
                            n_prims,
                            ku,
                            nu,
                            m_prims: 1,
                        };
                        if spatial.validate(sys).is_err() {
                            continue;
                        }
                        // Skip placements that overshoot the weight matrix.
                        if (k_prims - 1) * ku >= gemm.k || (n_prims - 1) * nu >= gemm.n {
                            continue;
                        }
                        f(&spatial);
                    }
                }
            }
        }
    }

    /// Temporal bounds shared by [`Self::enumerate_temporal`] and
    /// [`Self::count_temporal`]: `(k_tiles, n_tiles, staging, capacity,
    /// k0, n0)`.
    fn temporal_bounds(
        &self,
        gemm: &Gemm,
        spatial: &CimSpatial,
    ) -> (u64, u64, MemLevel, u64, u64, u64) {
        let sys = self.sys;
        let k0 = spatial.k0(gemm.k);
        let n0 = spatial.n0(gemm.n);
        let k_tiles = gemm.k.div_ceil(k0);
        let n_tiles = gemm.n.div_ceil(n0);
        let staging = sys.staging_level();
        let capacity = match staging {
            MemLevel::Dram => u64::MAX,
            lvl => sys.arch.capacity(lvl),
        };
        (k_tiles, n_tiles, staging, capacity, k0, n0)
    }

    /// Number of candidates [`Self::enumerate_temporal`] emits for one
    /// spatial split: every (m1, k1, n1) combination surviving the
    /// capacity filter contributes 6 DRAM orders × 2 staging orders —
    /// counted without building a single nest.
    fn count_temporal(&self, gemm: &Gemm, spatial: &CimSpatial) -> u64 {
        let (k_tiles, n_tiles, _, capacity, k0, n0) = self.temporal_bounds(gemm, spatial);
        let mut n = 0u64;
        for m1 in pow2_upto(gemm.m) {
            for k1 in pow2_upto(k_tiles) {
                for n1 in pow2_upto(n_tiles) {
                    if capacity != u64::MAX
                        && m1.saturating_mul(k1 * k0 + n1 * n0) > capacity
                    {
                        continue;
                    }
                    n += (PERMS3.len() as u64) * 2;
                }
            }
        }
        n
    }

    fn enumerate_temporal<F: FnMut(Mapping)>(
        &self,
        gemm: &Gemm,
        spatial: &CimSpatial,
        f: &mut F,
    ) {
        let sys = self.sys;
        let occupancy = spatial.utilization(sys);
        let (k_tiles, n_tiles, staging, capacity, k0, n0) = self.temporal_bounds(gemm, spatial);

        for m1 in pow2_upto(gemm.m) {
            for k1 in pow2_upto(k_tiles) {
                for n1 in pow2_upto(n_tiles) {
                    if capacity != u64::MAX
                        && m1.saturating_mul(k1 * k0 + n1 * n0) > capacity
                    {
                        continue;
                    }
                    let m2 = gemm.m.div_ceil(m1);
                    let k2 = k_tiles.div_ceil(k1);
                    let n2 = n_tiles.div_ceil(n1);
                    let dram = [
                        Loop::new(Dim::M, m2),
                        Loop::new(Dim::K, k2),
                        Loop::new(Dim::N, n2),
                    ];
                    for perm in PERMS3 {
                        for stage_order in [[Dim::N, Dim::K], [Dim::K, Dim::N]] {
                            let block0 = Block::new(
                                MemLevel::Dram,
                                perm.iter().map(|&i| dram[i]).collect(),
                            );
                            let stage_loops = stage_order
                                .iter()
                                .map(|&d| {
                                    Loop::new(d, if d == Dim::K { k1 } else { n1 })
                                })
                                .collect();
                            let block1 = Block::new(staging, stage_loops);
                            let block2 = Block::new(
                                sys.level,
                                vec![
                                    Loop::new(Dim::N, n0),
                                    Loop::new(Dim::K, k0),
                                    Loop::new(Dim::M, m1),
                                ],
                            );
                            let nest =
                                LoopNest::new(*gemm, vec![block0, block1, block2]);
                            f(Mapping {
                                gemm: *gemm,
                                spatial: *spatial,
                                occupancy,
                                nest,
                            });
                        }
                    }
                }
            }
        }
    }
}

const PERMS3: [[usize; 3]; 6] = [
    [0, 1, 2],
    [0, 2, 1],
    [1, 0, 2],
    [1, 2, 0],
    [2, 0, 1],
    [2, 1, 0],
];

/// Powers of two up to and including `x` (and `x` itself if not a
/// power of two) — the discretization grid.
fn pow2_upto(x: u64) -> Vec<u64> {
    let mut v: Vec<u64> = (0..)
        .map(|e| 1u64 << e)
        .take_while(|&p| p < x)
        .collect();
    v.push(x);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn sys() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn pow2_grid() {
        assert_eq!(pow2_upto(8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_upto(10), vec![1, 2, 4, 8, 10]);
        assert_eq!(pow2_upto(1), vec![1]);
    }

    #[test]
    fn optimum_dominates_priority_mapper() {
        // The exhaustive optimum is, by definition, at least as good as
        // the greedy algorithm on the same discretized space.
        let sys = sys();
        let cost = CostModel::new(&sys);
        // Shapes kept small: these spaces are enumerated in debug mode.
        for g in [
            Gemm::new(64, 64, 256),
            Gemm::new(32, 128, 512),
            Gemm::new(1, 256, 512),
        ] {
            let exact = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
            let ours = cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g));
            assert!(
                exact.metrics.energy_pj <= ours.energy_pj * 1.0001,
                "{g}: exhaustive {} > priority {}",
                exact.metrics.energy_pj,
                ours.energy_pj
            );
            assert!(exact.candidates > 10, "{g}: space too small");
        }
    }

    #[test]
    fn priority_mapper_close_to_optimal_on_regular_shapes() {
        // The headline property (Fig 7's implicit claim): the greedy
        // algorithm is near-optimal for regular GEMMs.
        let sys = sys();
        let cost = CostModel::new(&sys);
        let g = Gemm::new(64, 128, 256);
        let exact = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
        let ours = cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g));
        let gap = ours.energy_pj / exact.metrics.energy_pj;
        assert!(gap < 1.5, "optimality gap {gap}");
    }

    #[test]
    fn count_matches_scored_candidates() {
        // `count_candidates` shares the enumeration with `map`; the
        // totals must agree exactly (the optimality CSV depends on it).
        let sys = sys();
        for g in [Gemm::new(64, 64, 256), Gemm::new(1, 256, 512)] {
            let mapper = ExhaustiveMapper::new(&sys, Objective::Energy);
            assert_eq!(mapper.count_candidates(&g), mapper.map(&g).candidates, "{g}");
        }
    }

    #[test]
    fn objective_names_round_trip() {
        for o in [Objective::Energy, Objective::Delay, Objective::Edp] {
            assert_eq!(Objective::parse(o.name()), Some(o));
        }
        assert_eq!(Objective::parse("speed"), None);
    }

    #[test]
    fn objectives_differ() {
        let sys = sys();
        let g = Gemm::new(64, 64, 256);
        let e = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
        let d = ExhaustiveMapper::new(&sys, Objective::Delay).map(&g);
        assert!(e.metrics.energy_pj <= d.metrics.energy_pj * 1.0001);
        assert!(d.metrics.total_cycles <= e.metrics.total_cycles);
    }
}
