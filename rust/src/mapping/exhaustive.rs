//! Exhaustive mapper: enumerate the *entire* (discretized) map-space
//! and return the true optimum — the yardstick that quantifies how far
//! the priority mapper's greedy choices are from optimal.
//!
//! Neither the paper's algorithm nor its heuristic comparator can say
//! how close to optimal they land; this module can, for tractable
//! spaces. The space is discretized the same way both mappers build
//! nests: spatial splits over primitives × power-of-two-ish staging
//! factors × DRAM-level loop orders.

use super::loopnest::{Block, Dim, Loop, LoopNest};
use super::spatial::CimSpatial;
use super::Mapping;
use crate::arch::{CimSystem, MemLevel};
use crate::cost::CostModel;
use crate::workload::Gemm;

/// Objective to optimize over the map-space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize total energy (maximize TOPS/W).
    Energy,
    /// Minimize total cycles (maximize GFLOPS).
    Delay,
    /// Minimize energy × delay.
    Edp,
}

impl Objective {
    fn score(self, m: &crate::cost::Metrics) -> f64 {
        match self {
            Objective::Energy => m.energy_pj,
            Objective::Delay => m.total_cycles as f64,
            Objective::Edp => m.energy_pj * m.total_cycles as f64,
        }
    }
}

/// Exhaustive search result.
#[derive(Debug, Clone)]
pub struct ExhaustiveResult {
    pub mapping: Mapping,
    pub metrics: crate::cost::Metrics,
    /// Number of candidate mappings scored.
    pub candidates: u64,
}

/// Exhaustive mapper over the discretized space.
#[derive(Debug, Clone)]
pub struct ExhaustiveMapper<'a> {
    sys: &'a CimSystem,
    pub objective: Objective,
}

impl<'a> ExhaustiveMapper<'a> {
    pub fn new(sys: &'a CimSystem, objective: Objective) -> Self {
        ExhaustiveMapper { sys, objective }
    }

    /// Enumerate and score every candidate; returns the optimum.
    pub fn map(&self, gemm: &Gemm) -> ExhaustiveResult {
        let sys = self.sys;
        let p = &sys.primitive;
        let cost = CostModel::new(sys);
        let mut best: Option<(f64, Mapping, crate::cost::Metrics)> = None;
        let mut candidates = 0u64;

        let ku_max = gemm.k.min(p.weight_rows());
        let nu_max = gemm.n.min(p.weight_cols());
        for ku in pow2_upto(ku_max) {
            for nu in pow2_upto(nu_max) {
                for k_prims in 1..=sys.count {
                    for n_prims in 1..=(sys.count / k_prims) {
                        let spatial = CimSpatial {
                            k_prims,
                            n_prims,
                            ku,
                            nu,
                            m_prims: 1,
                        };
                        if spatial.validate(sys).is_err() {
                            continue;
                        }
                        // Skip placements that overshoot the weight matrix.
                        if (k_prims - 1) * ku >= gemm.k || (n_prims - 1) * nu >= gemm.n {
                            continue;
                        }
                        self.enumerate_temporal(gemm, &spatial, &cost, &mut best, &mut candidates);
                    }
                }
            }
        }
        let (_, mapping, metrics) = best.expect("space contains at least the trivial mapping");
        ExhaustiveResult {
            mapping,
            metrics,
            candidates,
        }
    }

    fn enumerate_temporal(
        &self,
        gemm: &Gemm,
        spatial: &CimSpatial,
        cost: &CostModel,
        best: &mut Option<(f64, Mapping, crate::cost::Metrics)>,
        candidates: &mut u64,
    ) {
        let sys = self.sys;
        let k0 = spatial.k0(gemm.k);
        let n0 = spatial.n0(gemm.n);
        let k_tiles = gemm.k.div_ceil(k0);
        let n_tiles = gemm.n.div_ceil(n0);
        let staging = sys.staging_level();
        let capacity = match staging {
            MemLevel::Dram => u64::MAX,
            lvl => sys.arch.capacity(lvl),
        };

        for m1 in pow2_upto(gemm.m) {
            for k1 in pow2_upto(k_tiles) {
                for n1 in pow2_upto(n_tiles) {
                    if capacity != u64::MAX
                        && m1.saturating_mul(k1 * k0 + n1 * n0) > capacity
                    {
                        continue;
                    }
                    let m2 = gemm.m.div_ceil(m1);
                    let k2 = k_tiles.div_ceil(k1);
                    let n2 = n_tiles.div_ceil(n1);
                    let dram = [
                        Loop::new(Dim::M, m2),
                        Loop::new(Dim::K, k2),
                        Loop::new(Dim::N, n2),
                    ];
                    for perm in PERMS3 {
                        for stage_order in [[Dim::N, Dim::K], [Dim::K, Dim::N]] {
                            let block0 = Block::new(
                                MemLevel::Dram,
                                perm.iter().map(|&i| dram[i]).collect(),
                            );
                            let stage_loops = stage_order
                                .iter()
                                .map(|&d| {
                                    Loop::new(d, if d == Dim::K { k1 } else { n1 })
                                })
                                .collect();
                            let block1 = Block::new(staging, stage_loops);
                            let block2 = Block::new(
                                sys.level,
                                vec![
                                    Loop::new(Dim::N, n0),
                                    Loop::new(Dim::K, k0),
                                    Loop::new(Dim::M, m1),
                                ],
                            );
                            let nest =
                                LoopNest::new(*gemm, vec![block0, block1, block2]);
                            let mapping = Mapping {
                                gemm: *gemm,
                                spatial: *spatial,
                                nest,
                            };
                            let m = cost.evaluate(gemm, &mapping);
                            let s = self.objective.score(&m);
                            *candidates += 1;
                            if best.as_ref().map_or(true, |(b, _, _)| s < *b) {
                                *best = Some((s, mapping, m));
                            }
                        }
                    }
                }
            }
        }
    }
}

const PERMS3: [[usize; 3]; 6] = [
    [0, 1, 2],
    [0, 2, 1],
    [1, 0, 2],
    [1, 2, 0],
    [2, 0, 1],
    [2, 1, 0],
];

/// Powers of two up to and including `x` (and `x` itself if not a
/// power of two) — the discretization grid.
fn pow2_upto(x: u64) -> Vec<u64> {
    let mut v: Vec<u64> = (0..)
        .map(|e| 1u64 << e)
        .take_while(|&p| p < x)
        .collect();
    v.push(x);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Architecture;
    use crate::cim::CimPrimitive;
    use crate::mapping::PriorityMapper;

    fn sys() -> CimSystem {
        CimSystem::at_level(
            &Architecture::default_sm(),
            CimPrimitive::digital_6t(),
            MemLevel::RegisterFile,
        )
    }

    #[test]
    fn pow2_grid() {
        assert_eq!(pow2_upto(8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_upto(10), vec![1, 2, 4, 8, 10]);
        assert_eq!(pow2_upto(1), vec![1]);
    }

    #[test]
    fn optimum_dominates_priority_mapper() {
        // The exhaustive optimum is, by definition, at least as good as
        // the greedy algorithm on the same discretized space.
        let sys = sys();
        let cost = CostModel::new(&sys);
        // Shapes kept small: these spaces are enumerated in debug mode.
        for g in [
            Gemm::new(64, 64, 256),
            Gemm::new(32, 128, 512),
            Gemm::new(1, 256, 512),
        ] {
            let exact = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
            let ours = cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g));
            assert!(
                exact.metrics.energy_pj <= ours.energy_pj * 1.0001,
                "{g}: exhaustive {} > priority {}",
                exact.metrics.energy_pj,
                ours.energy_pj
            );
            assert!(exact.candidates > 10, "{g}: space too small");
        }
    }

    #[test]
    fn priority_mapper_close_to_optimal_on_regular_shapes() {
        // The headline property (Fig 7's implicit claim): the greedy
        // algorithm is near-optimal for regular GEMMs.
        let sys = sys();
        let cost = CostModel::new(&sys);
        let g = Gemm::new(64, 128, 256);
        let exact = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
        let ours = cost.evaluate(&g, &PriorityMapper::new(&sys).map(&g));
        let gap = ours.energy_pj / exact.metrics.energy_pj;
        assert!(gap < 1.5, "optimality gap {gap}");
    }

    #[test]
    fn objectives_differ() {
        let sys = sys();
        let g = Gemm::new(64, 64, 256);
        let e = ExhaustiveMapper::new(&sys, Objective::Energy).map(&g);
        let d = ExhaustiveMapper::new(&sys, Objective::Delay).map(&g);
        assert!(e.metrics.energy_pj <= d.metrics.energy_pj * 1.0001);
        assert!(d.metrics.total_cycles <= e.metrics.total_cycles);
    }
}
